"""Cross-process fleet chaos suite: exactly-once serving across real
process death.

The oracle is the same as ``test_fault_tolerance``: a fault-free greedy
run. Worker SIGKILL, transport partitions, graceful SIGTERM drains and
supervisor crashes (with journal replay) must change WHEN tokens are
computed, never WHAT they are — every test asserts zero drops, terminal
statuses from the glossary, and bitwise parity of both outcome tokens
and the streamed-token view (``on_token`` + ``on_replay``) against the
oracle. Worker processes live in real time, so these tests use the real
clock with small backoffs; the journal/transport unit tests are pure.

CI re-runs this file under several CHAOS_SEED values; the seed moves the
kill coordinate so the suite sweeps kill-mid-prefill vs kill-mid-decode
without losing determinism per seed.
"""
import dataclasses
import os
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.models import LM
from repro.serve import (Engine, FaultPlan, Journal, JournalCorruptionError,
                         Request, ServeConfig, Supervisor, SupervisorConfig,
                         SupervisorCrash, VirtualClock, WorkerSpec,
                         model_config_from_dict, model_config_to_dict,
                         replay_state)
from repro.serve.journal import encode_record, scan_records
from repro.serve.transport import (FramedConnection, TransportError,
                                   encode_frame)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                head_dim=32, d_ff=128, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


def _requests(lens=(3, 9, 5, 14, 7), new=None, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, 128, l).astype(np.int32),
                    max_new_tokens=(new or 4 + i), id=i, **kw)
            for i, l in enumerate(lens)]


@pytest.fixture(scope="module")
def spec():
    return WorkerSpec(model=model_config_to_dict(_tiny_cfg()),
                      serve=ServeConfig(max_slots=2, max_seq=32).to_dict(),
                      seed=0, prefill_chunk=4)


@pytest.fixture(scope="module")
def oracle(key):
    """Fault-free greedy ground truth (one in-process engine, one slot)."""
    model = LM(_tiny_cfg())
    params = model.init(key)
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
    return {r.id: eng.generate([r])[0].tokens for r in _requests()}


def _sup_cfg(**over):
    kw = dict(replicas=2, prefill_chunk=4, backoff_base_s=0.01,
              backoff_jitter=0.0, partition_tolerance_s=5.0)
    kw.update(over)
    return SupervisorConfig(**kw)


class _Streams:
    """Client-side view of the token streams: on_token appends,
    on_replay resets to the journaled prefix after a recovery."""

    def __init__(self):
        self.toks = {}
        self.events = []

    def on_token(self, rid, tok, done):
        self.toks.setdefault(rid, []).append(tok)
        self.events.append((rid, tok))

    def on_replay(self, rid, prefix):
        self.toks[rid] = list(prefix)


def _assert_parity(report, oracle, streams=None, statuses=("ok",)):
    assert report.zero_drops, report.status_counts()
    for o in report.outcomes:
        assert o.status in ("ok", "timeout", "rejected", "failed")
        assert o.status in statuses, (o.id, o.status)
        if o.status == "ok":
            assert o.tokens == oracle[o.id], (o.id, o.tokens, oracle[o.id])
            if streams is not None:
                assert streams.toks[o.id] == oracle[o.id], o.id


# ======================================================== journal (pure)
class TestJournal:
    def test_roundtrip_and_replay(self, tmp_path):
        p = tmp_path / "wal.journal"
        j = Journal(p)
        j.append({"t": "admit", "id": 0, "prompt": [3, 4], "new": 3,
                  "dl": None, "arr": 0.0})
        j.append({"t": "emit", "id": 0, "i": 0, "toks": [7, 8]})
        j.flush()
        j.append({"t": "emit", "id": 0, "i": 2, "toks": [9]})
        j.append({"t": "term", "id": 0, "st": "ok"})
        j.flush()
        j.seal()
        j.close()
        j2 = Journal(p)
        assert j2.records == 4 and j2.truncated_bytes == 0
        state = replay_state(j2.recovered)
        assert state[0].emitted == [7, 8, 9]
        assert state[0].status == "ok"
        assert state[0].prompt == [3, 4]

    def test_torn_tail_truncated(self, tmp_path):
        p = tmp_path / "wal.journal"
        j = Journal(p)
        j.append({"t": "admit", "id": 0, "prompt": [3], "new": 2,
                  "dl": None, "arr": 0.0})
        j.flush()
        j.close()
        with open(p, "ab") as f:
            f.write(encode_record({"t": "emit", "id": 0, "i": 0,
                                   "toks": [5]})[:-3])  # torn mid-record
        j2 = Journal(p)
        assert j2.records == 1 and j2.truncated_bytes > 0
        # recovery rewrote the file: a third open sees a clean tail
        assert Journal(p).truncated_bytes == 0

    def test_crc_corruption_in_sealed_prefix_raises(self, tmp_path):
        p = tmp_path / "wal.journal"
        j = Journal(p)
        j.append({"t": "admit", "id": 0, "prompt": [3], "new": 2,
                  "dl": None, "arr": 0.0})
        j.flush()
        j.seal()
        j.close()
        raw = bytearray(p.read_bytes())
        raw[-1] ^= 0xFF
        p.write_bytes(bytes(raw))
        with pytest.raises(JournalCorruptionError):
            Journal(p)

    def test_unsealed_mid_corruption_truncates_not_raises(self, tmp_path):
        p = tmp_path / "wal.journal"
        j = Journal(p)
        j.append({"t": "admit", "id": 0, "prompt": [3], "new": 2,
                  "dl": None, "arr": 0.0})
        j.append({"t": "term", "id": 0, "st": "ok"})
        j.close(seal=False)     # writer died before sealing
        data = p.read_bytes()
        recs, _ = scan_records(data)
        assert len(recs) == 2
        first_len = len(encode_record(recs[0]))
        raw = bytearray(data)
        raw[first_len + 9] ^= 0xFF  # corrupt the second record
        p.write_bytes(bytes(raw))
        j2 = Journal(p)  # no manifest: bad tail is truncated, not fatal
        assert j2.records == 1 and j2.truncated_bytes > 0

    def test_empty_journal(self, tmp_path):
        j = Journal(tmp_path / "wal.journal")
        assert j.records == 0 and j.recovered == []
        assert replay_state([]) == {}

    def test_replay_rejects_gap_and_mismatch(self):
        admit = {"t": "admit", "id": 1, "prompt": [2], "new": 4,
                 "dl": None, "arr": 0.0}
        with pytest.raises(JournalCorruptionError):
            replay_state([admit, {"t": "emit", "id": 1, "i": 2,
                                  "toks": [9]}])
        with pytest.raises(JournalCorruptionError):
            replay_state([admit,
                          {"t": "emit", "id": 1, "i": 0, "toks": [5, 6]},
                          {"t": "emit", "id": 1, "i": 1, "toks": [7]}])
        with pytest.raises(JournalCorruptionError):
            replay_state([{"t": "emit", "id": 9, "i": 0, "toks": [1]}])

    def test_replay_accepts_idempotent_overlap(self):
        state = replay_state([
            {"t": "admit", "id": 1, "prompt": [2], "new": 4, "dl": None,
             "arr": 0.0},
            {"t": "emit", "id": 1, "i": 0, "toks": [5, 6]},
            {"t": "emit", "id": 1, "i": 1, "toks": [6, 7]}])
        assert state[1].emitted == [5, 6, 7]


# ====================================================== transport (pure)
class TestTransport:
    def _pipe(self):
        """(write_fd, receiving FramedConnection) — raw bytes in, frames
        out."""
        r, w = os.pipe()
        return w, FramedConnection(read_fd=r, write_fd=w)

    def test_roundtrip(self):
        w, conn = self._pipe()
        conn.send({"m": "ping", "n": [1, 2, 3]})
        assert conn.recv(timeout=1.0) == {"m": "ping", "n": [1, 2, 3]}

    def test_crc_reject_is_fatal(self):
        w, conn = self._pipe()
        frame = bytearray(encode_frame({"m": "x"}))
        frame[-1] ^= 0xFF
        os.write(w, bytes(frame))
        with pytest.raises(TransportError) as ei:
            conn.recv(timeout=1.0)
        assert not ei.value.retryable

    def test_timeout_is_retryable_and_resyncs(self):
        w, conn = self._pipe()
        frame = encode_frame({"m": "x"})
        os.write(w, frame[:5])  # partial header+payload
        with pytest.raises(TransportError) as ei:
            conn.recv(timeout=0.05)
        assert ei.value.retryable
        os.write(w, frame[5:])  # the rest arrives later
        assert conn.recv(timeout=1.0) == {"m": "x"}

    def test_eof_is_fatal(self):
        w, conn = self._pipe()
        os.close(w)
        with pytest.raises(TransportError) as ei:
            conn.recv(timeout=1.0)
        assert not ei.value.retryable


# ============================================================ spec (pure)
def test_worker_spec_roundtrip(spec):
    again = WorkerSpec.from_json(spec.to_json())
    # JSON list-ifies tuples inside the model dict: compare semantically
    assert model_config_from_dict(again.model) == _tiny_cfg()
    assert dataclasses.replace(again, model={}) == \
        dataclasses.replace(spec, model={})
    scfg = ServeConfig.from_dict(again.serve)
    assert scfg.cache.max_seq == 32 and scfg.cache.max_slots == 2


# =============================================== process fleet (slow-ish)
class TestProcessFleet:
    def _serve(self, spec, reqs, plan=None, journal=None, streams=None,
               **cfg_over):
        streams = streams if streams is not None else _Streams()
        sup = Supervisor(
            cfg=_sup_cfg(**cfg_over), fleet="procs", worker_spec=spec,
            on_token=streams.on_token, on_replay=streams.on_replay,
            journal=journal,
            fault_plan=FaultPlan.parse(plan) if plan else None)
        with sup:
            report = sup.serve(reqs)
        return report, streams

    def test_no_fault_parity(self, spec, oracle):
        report, streams = self._serve(spec, _requests())
        _assert_parity(report, oracle, streams)
        assert report.frames_retried == 0
        assert report.restarts == {0: 0, 1: 0}

    def test_sigkill_mid_decode(self, spec, oracle):
        # seed moves the kill coordinate: mid-prefill at low steps,
        # mid-decode later — determinism per seed either way
        step = 3 + (CHAOS_SEED % 7)
        report, streams = self._serve(
            spec, _requests(), plan=f"sigkill@{step}:step:0")
        _assert_parity(report, oracle, streams)
        assert report.restarts[0] >= 1
        assert report.wasted_compute_tokens > 0
        # no token was streamed twice: the raw on_token sequence per
        # request IS the oracle (replayed tokens ride the resume prompt)
        for o in report.outcomes:
            assert [t for rid, t in streams.events if rid == o.id] == \
                oracle[o.id]

    def test_sigkill_mid_prefill(self, spec, oracle):
        report, streams = self._serve(
            spec, _requests(), plan="sigkill@1:step:0")
        _assert_parity(report, oracle, streams)
        assert report.restarts[0] >= 1

    def test_partition_then_heal_no_duplicates(self, spec, oracle):
        report, streams = self._serve(
            spec, _requests(), plan="partition@4:transport:0:4")
        _assert_parity(report, oracle, streams)
        assert report.frames_retried > 0
        # healed partition: retries, not failures — workers never died
        assert report.restarts == {0: 0, 1: 0}
        for rid, toks in streams.toks.items():
            assert toks == oracle[rid]  # exactly-once despite retransmits

    def test_sigterm_graceful_drain(self, spec, oracle):
        report, streams = self._serve(
            spec, _requests(), plan="sigterm@2:step:0")
        _assert_parity(report, oracle, streams)
        # a drain is not a failure: no salvage, no restart, no replay
        assert report.restarts == {0: 0, 1: 0}
        assert report.failures == []
        assert all(o.replays == 0 for o in report.outcomes)

    def test_supervisor_crash_then_resume_exactly_once(
            self, spec, oracle, tmp_path):
        jp = tmp_path / "wal.journal"
        streams = _Streams()
        with pytest.raises(SupervisorCrash):
            self._serve(spec, _requests(), journal=Journal(jp),
                        plan="sigkill@3:step:0,supervisor_crash@8",
                        streams=streams)
        sup2 = Supervisor(cfg=_sup_cfg(), fleet="procs", worker_spec=spec,
                          on_token=streams.on_token,
                          on_replay=streams.on_replay, journal=Journal(jp))
        with sup2:
            report = sup2.resume()
        _assert_parity(report, oracle, streams)
        assert report.journal_replayed > 0
        # sealed journal now holds the complete story
        state = replay_state(Journal(jp).recovered)
        for o in report.outcomes:
            assert state[o.id].status == "ok"
            assert state[o.id].emitted == oracle[o.id]

    def test_double_supervisor_crash(self, spec, oracle, tmp_path):
        jp = tmp_path / "wal.journal"
        streams = _Streams()
        with pytest.raises(SupervisorCrash):
            self._serve(spec, _requests(), journal=Journal(jp),
                        plan="supervisor_crash@6", streams=streams)
        sup2 = Supervisor(cfg=_sup_cfg(), fleet="procs", worker_spec=spec,
                          on_token=streams.on_token,
                          on_replay=streams.on_replay, journal=Journal(jp),
                          fault_plan=FaultPlan.parse("supervisor_crash@3"))
        with pytest.raises(SupervisorCrash):
            with sup2:
                sup2.resume()
        sup3 = Supervisor(cfg=_sup_cfg(), fleet="procs", worker_spec=spec,
                          on_token=streams.on_token,
                          on_replay=streams.on_replay, journal=Journal(jp))
        with sup3:
            report = sup3.resume()
        _assert_parity(report, oracle, streams)

    def test_resume_survives_torn_tail(self, spec, oracle, tmp_path):
        jp = tmp_path / "wal.journal"
        streams = _Streams()
        with pytest.raises(SupervisorCrash):
            self._serve(spec, _requests(), journal=Journal(jp),
                        plan="supervisor_crash@7", streams=streams)
        with open(jp, "ab") as f:  # the crash tore the last record
            f.write(encode_record({"t": "emit", "id": 0, "i": 99,
                                   "toks": [1]})[:-2])
        j = Journal(jp)
        assert j.truncated_bytes > 0
        sup2 = Supervisor(cfg=_sup_cfg(), fleet="procs", worker_spec=spec,
                          on_token=streams.on_token,
                          on_replay=streams.on_replay, journal=j)
        with sup2:
            report = sup2.resume()
        _assert_parity(report, oracle, streams)

    def test_procs_reject_virtual_clock_and_missing_spec(self, spec):
        with pytest.raises(ValueError):
            Supervisor(cfg=_sup_cfg(), fleet="procs", worker_spec=spec,
                       clock=VirtualClock())
        with pytest.raises(ValueError):
            Supervisor(cfg=_sup_cfg(), fleet="procs")
        with pytest.raises(ValueError):
            Supervisor(lambda: None, _sup_cfg(), fleet="bogus")


# ========================================== in-process fleet (fast, exact)
class TestInprocSplitAccounting:
    def _sup(self, tiny, plan, **kw):
        model, params = tiny

        def factory():
            return Engine(model, params, ServeConfig(max_slots=2,
                                                     max_seq=32))
        return Supervisor(
            factory, SupervisorConfig(replicas=2, prefill_chunk=4,
                                      step_cost_s=0.01),
            fault_plan=FaultPlan.parse(plan) if plan else None,
            clock=VirtualClock(), **kw)

    @pytest.fixture(scope="class")
    def tiny(self, key):
        model = LM(_tiny_cfg())
        return model, model.init(key)

    def test_wasted_split_sums_to_legacy_total(self, tiny, oracle):
        sup = self._sup(tiny, "exception@3:decode:0")
        report = sup.serve(_requests())
        _assert_parity(report, oracle)
        assert report.failures, "fault coordinate never fired"
        assert report.wasted_compute_tokens > 0
        assert report.replayed_emitted_tokens >= 0
        assert report.wasted_tokens == report.wasted_compute_tokens + \
            report.replayed_emitted_tokens
        total = report.wasted_tokens + report.useful_tokens
        assert report.wasted_token_fraction == report.wasted_tokens / total
        assert abs(report.wasted_compute_fraction +
                   report.replayed_emitted_fraction -
                   report.wasted_token_fraction) < 1e-12

    def test_inproc_sigkill_maps_to_hard_failure(self, tiny, oracle):
        sup = self._sup(tiny, "sigkill@5:step:0")
        report = sup.serve(_requests())
        _assert_parity(report, oracle)
        assert report.restarts[0] >= 1
        assert any("sigkill" in msg for _, msg in report.failures)

    def test_inproc_journal_records_complete_story(self, tiny, oracle,
                                                   tmp_path):
        jp = tmp_path / "wal.journal"
        sup = self._sup(tiny, "exception@3:decode:0", journal=Journal(jp))
        report = sup.serve(_requests())
        _assert_parity(report, oracle)
        assert report.journal_records > 0 and report.journal_fsyncs > 0
        state = replay_state(Journal(jp).recovered)
        for o in report.outcomes:
            assert state[o.id].emitted == o.tokens
            assert state[o.id].status == o.status

    def test_inproc_rejects_transport_faults(self, tiny):
        sup = self._sup(tiny, "partition@4:transport:0:4")
        with pytest.raises(ValueError, match="process fleet"):
            sup.serve(_requests())
