"""The quantized serving runtime: backend dispatch (ref|fused|auto),
T-block selection for decode-shaped kernel calls, the lane-stacked kernel,
scan-over-stacked-layers decode, end-to-end engine parity, and weight-stack
donation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig, quantize_matrix, quantize_stack
from repro.kernels import ops, ref
from repro.models import LM
from repro.quant import qtensor
from repro.quant.apply import (
    apply_lowrank_separate,
    backend_scope,
    clear_dispatch_log,
    dispatch,
    dispatch_log,
    dispatch_report,
    kernel_supported,
    resolve_backend,
)
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                head_dim=32, d_ff=256, vocab=256, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


@pytest.fixture(scope="module")
def tiny_quantized(key):
    model = LM(_tiny_cfg())
    params = model.init(key)
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=8))
    return model, params, qparams


@pytest.fixture(scope="module")
def qt_w4(key):
    w = jax.random.normal(key, (128, 256)) * 0.05
    qt, _ = quantize_matrix(w, None, FLRQConfig(bits=4, blc_epochs=1,
                                                max_rank=8), key)
    return qt


# ------------------------------------------------------- T-block selection
def test_t_blocking_selection():
    """bt must divide padded T and stay sublane-aligned (8) — the seed bug
    computed bt and never passed it, so decode-shaped T took whatever
    min(128, T) degenerate block the kernel defaulted to."""
    assert ops._t_blocking(1) == (8, 8)
    assert ops._t_blocking(7) == (8, 8)
    assert ops._t_blocking(8) == (8, 8)
    assert ops._t_blocking(100) == (104, 104)
    assert ops._t_blocking(128) == (128, 128)
    assert ops._t_blocking(200) == (128, 256)


@pytest.mark.parametrize("t", [1, 7, 8, 200])
def test_quant_matmul_small_t(qt_w4, t):
    """Decode-shaped (T=slots) and padded-T calls hit the kernel and match
    the oracle exactly at every regime boundary."""
    x = jax.random.normal(jax.random.PRNGKey(t), (t, 256))
    y = ops.quant_matmul(qt_w4, x, interpret=True)
    y_r = ref.quant_matmul_ref(x, qt_w4.packed, qt_w4.scale, qt_w4.zp,
                               qt_w4.u, qt_w4.v, qt_w4.act_scale_inv, bits=4)
    assert y.shape == (t, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


def test_quant_matmul_decode_shape(qt_w4):
    """(slots, 1, n) — the engine's decode call shape — routes through the
    kernel with lead dims preserved."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1, 256))
    y = ops.quant_matmul(qt_w4, x, interpret=True)
    y_r = apply_lowrank_separate(qt_w4, x)
    assert y.shape == (4, 1, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r, np.float32),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------ lane-stacked kernel
@pytest.mark.parametrize("bits", [4, 8])
def test_lane_stacked_kernel_matches_ref(bits, key):
    ws = jax.random.normal(key, (3, 128, 256)) * 0.05
    qts, _ = quantize_stack(ws, None, FLRQConfig(bits=bits, blc_epochs=1,
                                                 max_rank=8), key=key)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 256))
    y = ops.quant_matmul(qts, x, interpret=True)
    y_r = apply_lowrank_separate(qts, x)  # vmapped jnp oracle
    assert y.shape == (3, 5, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_lane_stacked_kernel_bits3_ref_fallback(key):
    ws = jax.random.normal(key, (2, 128, 256)) * 0.05
    qts, _ = quantize_stack(ws, None, FLRQConfig(bits=3, blc_epochs=1,
                                                 max_rank=4), key=key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 256))
    y = ops.quant_matmul(qts, x, interpret=True)
    y_r = apply_lowrank_separate(qts, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_stack_qtensors_lane_roundtrip(key):
    ws = jax.random.normal(key, (4, 128, 256)) * 0.05
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    per_layer = []
    for i in range(4):
        qt, _ = quantize_matrix(ws[i], None, cfg, jax.random.PRNGKey(i))
        per_layer.append(qt)
    stacked = qtensor.stack_qtensors(per_layer)
    assert qtensor.is_stacked(stacked) and qtensor.num_lanes(stacked) == 4
    assert not qtensor.is_stacked(per_layer[0])
    x = jax.random.normal(jax.random.PRNGKey(9), (6, 256))
    for i in range(4):
        li = qtensor.lane(stacked, i)
        y_lane = apply_lowrank_separate(li, x)
        y_orig = apply_lowrank_separate(per_layer[i], x)
        np.testing.assert_allclose(np.asarray(y_lane, np.float32),
                                   np.asarray(y_orig, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # slice_stack of the full range is the identity on lanes
    sl = qtensor.slice_stack(stacked, 1, 3)
    np.testing.assert_array_equal(np.asarray(sl.packed),
                                  np.asarray(stacked.packed[1:3]))


# --------------------------------------------------------- backend dispatch
def test_kernel_supported_envelope(qt_w4):
    ok, _ = kernel_supported(qt_w4)
    assert ok
    bad_rank = dataclasses.replace(
        qt_w4, u=jnp.zeros((128, 200), jnp.bfloat16),
        v=jnp.zeros((200, 256), jnp.bfloat16))
    ok, why = kernel_supported(bad_rank)
    assert not ok and "rank" in why
    bad_m = dataclasses.replace(qt_w4, m=200)
    ok, why = kernel_supported(bad_m)
    assert not ok and "m=200" in why


def test_bits3_fused_fallback_is_surfaced(key):
    """bits=3 routes to the jnp reference inside the fused path — the
    dispatch report must SAY so (the seed buried it in kernels.ops)."""
    w = jax.random.normal(key, (128, 256)) * 0.05
    qt3, _ = quantize_matrix(w, None, FLRQConfig(bits=3, blc_epochs=1,
                                                 max_rank=4), key)
    x = jax.random.normal(key, (4, 256))
    clear_dispatch_log()
    y = dispatch(qt3, x, backend="fused")
    log = dispatch_log()
    assert len(log) == 1
    d = log[0]
    assert d.requested == "fused" and d.chosen == "ref"
    assert "bits=3" in d.reason
    assert "bits=3" in dispatch_report()
    y_r = apply_lowrank_separate(qt3, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_auto_backend_off_tpu_is_ref(qt_w4):
    chosen, reason = resolve_backend("auto", qt_w4)
    if jax.default_backend() == "tpu":
        assert chosen == "fused"
    else:
        assert chosen == "ref" and "TPU" in reason


def test_fused_interpret_false_off_tpu_falls_back(qt_w4):
    """fused + interpret explicitly disabled must not hand a real TPU
    pallas_call to a CPU lowering — it serves ref and says why."""
    chosen, reason = resolve_backend("fused", qt_w4, interpret=False)
    if jax.default_backend() == "tpu":
        assert chosen == "fused"
    else:
        assert chosen == "ref" and "TPU" in reason
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    y = dispatch(qt_w4, x, backend="fused", interpret=False)  # must not raise
    y_r = apply_lowrank_separate(qt_w4, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_stacked_rank_property(key):
    """Stacked tensors report the padded rank (u's LAST axis), not m —
    kernel_supported on a stack must not misclassify on a bogus rank."""
    ws = jax.random.normal(key, (2, 256, 256)) * 0.05
    qts, _ = quantize_stack(ws, None, FLRQConfig(bits=4, blc_epochs=1,
                                                 max_rank=8), key=key)
    assert qts.rank <= 8
    ok, why = kernel_supported(qts)
    assert ok, why


def test_backend_scope_controls_mm(qt_w4):
    from repro.models.layers import mm
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    clear_dispatch_log()
    with backend_scope("fused", interpret=True):
        y_f = mm(x, qt_w4)
    with backend_scope("ref"):
        y_r = mm(x, qt_w4)
    chosen = [d.chosen for d in dispatch_log()]
    assert chosen == ["fused-interpret", "ref"]
    np.testing.assert_allclose(np.asarray(y_f, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------- scan-over-stacked decode
def test_scanned_decode_compiles_one_layer_body(tiny_quantized):
    """The scanned decode jaxpr carries ONE layer body (a single scan over
    the stacked quantized weights); the unrolled variant re-emits the body
    per layer. Verified on the traced jaxpr, not by convention."""
    model, _, qparams = tiny_quantized
    b, s = 2, 32
    cache = model.init_cache(b, s)
    tok = jnp.ones((b, 1), jnp.int32)
    length = jnp.int32(4)

    def count_dots(m, q):
        jaxpr = jax.make_jaxpr(m.decode_step)(q, tok, cache, length)
        txt = str(jaxpr)
        return txt.count("dot_general"), txt.count("scan")

    dots_scan, scans = count_dots(model, qparams)
    dots_unroll, _ = count_dots(model.with_scan(False), qparams)
    assert scans >= 1, "scanned decode lost its lax.scan"
    # L=2 unrolled re-emits the quantized layer body per layer; the scanned
    # jaxpr contains it once (plus the shared unembed outside the stack).
    assert dots_unroll > dots_scan * 1.5, (dots_scan, dots_unroll)


def test_scan_and_unroll_decode_agree(tiny_quantized):
    model, _, qparams = tiny_quantized
    b, s = 2, 32
    prompts = jnp.asarray(np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % 200 + 2)
    logits_s, cache_s = model.prefill(qparams, prompts)
    logits_u, cache_u = model.with_scan(False).prefill(qparams, prompts)
    # scan vs unroll give XLA different fusion freedom — f32 round-off
    # only; greedy decisions must be identical
    np.testing.assert_allclose(np.asarray(logits_s), np.asarray(logits_u),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_s[:, -1]), -1),
        np.argmax(np.asarray(logits_u[:, -1]), -1))
    tok = jnp.argmax(logits_s[:, -1], axis=-1).astype(jnp.int32)
    d_s, _ = model.decode_step(qparams, tok, cache_s, jnp.int32(8))
    d_u, _ = model.with_scan(False).decode_step(qparams, tok, cache_u,
                                                jnp.int32(8))
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_u),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------ end-to-end engine
def _requests(n=3, vocab=256):
    return [Request(np.arange(5, dtype=np.int32) % (vocab - 2) + 2,
                    max_new_tokens=4, id=i) for i in range(n)]


def test_engine_auto_bitwise_matches_ref(tiny_quantized):
    """Acceptance: backend="auto" must produce bit-identical tokens to the
    reference path (off-TPU auto resolves to ref; on TPU this asserts the
    kernel path agrees)."""
    model, _, qparams = tiny_quantized
    scfg = dict(max_slots=2, max_seq=32)
    toks = {}
    for be in ("ref", "auto"):
        eng = Engine(model, qparams, ServeConfig(backend=be, **scfg))
        toks[be] = [r.tokens for r in eng.generate(_requests())]
    assert toks["auto"] == toks["ref"]


@pytest.mark.parametrize("bits,group", [(4, 128), (8, 64)])
def test_engine_parity_fused_vs_ref(bits, group, key):
    """End-to-end serve.Engine parity: fused(interpret) and ref backends
    produce IDENTICAL tokens through prefill + decode, across bits and
    group sizes."""
    model = LM(_tiny_cfg())
    params = model.init(key)
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=bits, group_size=group, blc_epochs=1,
                                 max_rank=4))
    scfg = dict(max_slots=2, max_seq=32)
    eng_ref = Engine(model, qparams, ServeConfig(backend="ref", **scfg))
    eng_fused = Engine(model, qparams, ServeConfig(
        backend="fused", interpret=True, **scfg))
    reqs = _requests()
    toks_ref = [r.tokens for r in eng_ref.generate(reqs)]
    toks_fused = [r.tokens for r in eng_fused.generate(reqs)]
    assert toks_ref == toks_fused, (bits, group)


# --------------------------------------------------------- stack donation
def test_quantize_stack_donate_bitwise_parity(key):
    ws = jax.random.normal(key, (3, 128, 256)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 256))
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    for calib in (None, x):
        q_plain, _ = quantize_stack(jnp.array(ws), calib, cfg, key=key)
        q_don, _ = quantize_stack(jnp.array(ws), calib, cfg, key=key,
                                  donate=True)
        for a, b in zip(jax.tree.leaves(q_plain), jax.tree.leaves(q_don)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_donation_alias_covers_stack():
    """The donating launch must actually consume the stack: the compiled
    input→output alias covers the full (L, m, n) f32 slab (multi-partition
    buffer_donor is audited in benchmarks.memory_sweep)."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.memory_sweep import donation_audit

    rep = donation_audit(L=2, m=128, n=256,
                         cfg=FLRQConfig(bits=4, blc_epochs=1, max_rank=4))
    if rep["alias_bytes"] is None:
        pytest.skip("backend exposes no compiled memory analysis")
    assert rep["alias_bytes"] == rep["stack_bytes"]
