"""Integration tests: training loop fault tolerance, checkpoint/restore/
elastic re-mesh, serving engine, whole-model quantization, data pipeline."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(
        PAPER_PROXIES["opt-proxy-25m"], n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=512)
    return LM(cfg)


@pytest.fixture(scope="module")
def data():
    return SyntheticCorpus(DataConfig(vocab=512, seq_len=64, global_batch=4))


def test_data_pipeline_deterministic_and_seekable(data):
    b1 = data.batch_at(7)
    b2 = data.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding partitions the global batch
    h0 = data.batch_at(7, host=0, n_hosts=2)
    h1 = data.batch_at(7, host=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_training_reduces_loss(tiny, data, key):
    state = init_train_state(tiny, key)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(tiny, opt))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3


def test_checkpoint_restart_bitexact(tiny, data, key, tmp_path):
    opt = AdamWConfig(lr=1e-3, total_steps=20)
    step = jax.jit(make_train_step(tiny, opt))
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    # uninterrupted run
    s_ref = init_train_state(tiny, key)
    for i in range(10):
        s_ref, _ = step(s_ref, batch_at(i))

    # interrupted at 5 + resumed
    ck = Checkpointer(str(tmp_path / "ck"), keep=2)
    s = init_train_state(tiny, key)
    for i in range(5):
        s, _ = step(s, batch_at(i))
    ck.save(5, s, blocking=True)
    restored, at = ck.restore(jax.eval_shape(lambda: s))
    assert at == 5
    for i in range(5, 10):
        restored, _ = step(restored, batch_at(i))

    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)


def test_checkpoint_atomicity(tiny, key, tmp_path):
    ck = Checkpointer(str(tmp_path / "ck"))
    s = init_train_state(tiny, key)
    ck.save(3, s, blocking=True)
    # a partial (uncommitted) later step must be ignored
    d = tmp_path / "ck" / "step_000000007"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ck.latest_step() == 3


def test_train_loop_preemption_and_resume(tiny, data, key, tmp_path):
    opt = AdamWConfig(lr=1e-3, total_steps=30)
    step = jax.jit(make_train_step(tiny, opt))
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
    ck = Checkpointer(str(tmp_path / "loop"), keep=2)
    state = init_train_state(tiny, key)

    # preempt after 7 steps
    calls = {"n": 0}

    def preempt():
        calls["n"] += 1
        return calls["n"] >= 7

    res = train_loop(step, state, batch_at, ck, LoopConfig(total_steps=30,
                     ckpt_every=100, log_every=5), preempt_flag=preempt)
    assert res.preempted and res.final_step == 7
    assert ck.latest_step() == 7
    # resume finishes the run
    res2 = train_loop(step, state, batch_at, ck,
                      LoopConfig(total_steps=30, ckpt_every=10, log_every=10))
    assert res2.resumed_from == 7 and res2.final_step == 30


def test_elastic_restore_to_different_mesh(tiny, key, tmp_path):
    """512→256-style re-mesh, scaled to local devices (1 -> 1 with a
    different mesh axis layout)."""
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    ck = Checkpointer(str(tmp_path / "el"))
    s = init_train_state(tiny, key)
    ck.save(1, s, blocking=True)
    mesh = make_host_mesh()
    p_sh = shd.param_shardings(tiny.cfg, jax.eval_shape(lambda: s.params), mesh)
    st_sh = type(s)(params=p_sh, opt=type(s.opt)(
        step=shd.replicated(mesh),
        mu=shd.param_shardings(tiny.cfg, jax.eval_shape(lambda: s.opt.mu), mesh),
        nu=shd.param_shardings(tiny.cfg, jax.eval_shape(lambda: s.opt.nu), mesh)))
    restored, at = ck.restore(jax.eval_shape(lambda: s), shardings=st_sh)
    for a, b in zip(jax.tree.leaves(s.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_watchdog(tiny, data, key):
    opt = AdamWConfig(lr=1e-3, total_steps=10)
    step_fn = jax.jit(make_train_step(tiny, opt))
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
    state = init_train_state(tiny, key)
    cfg = LoopConfig(total_steps=10, step_timeout_s=0.0, max_slow_steps=2,
                     ckpt_every=100)
    with pytest.raises(TimeoutError):
        train_loop(step_fn, state, batch_at, None, cfg)


def test_serving_engine_fp_and_quantized(tiny, key):
    params = tiny.init(key)
    eng = Engine(tiny, params, ServeConfig(max_slots=2, max_seq=64))
    reqs = [Request(np.arange(5, dtype=np.int32) + 2, max_new_tokens=4, id=i)
            for i in range(3)]
    res = eng.generate(reqs)
    assert len(res) == 3 and all(len(r.tokens) <= 4 for r in res)

    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=8))
    eng_q = Engine(tiny, qparams, ServeConfig(max_slots=2, max_seq=64))
    res_q = eng_q.generate(reqs)
    assert len(res_q) == 3 and all(len(r.tokens) <= 4 for r in res_q)

    # A random-init proxy's top1–top2 logit gap (~0.2) is smaller than the
    # inherent 4-bit no-calibration perturbation (~0.8 max over the vocab),
    # so exact greedy-argmax agreement is a coin flip — not an engine
    # property. The stable contract is top-k containment: the quantized
    # model's greedy token must sit inside the fp model's top-k set (and
    # vice versa) at the final prompt position (prefill's only logits).
    prompts = jnp.stack([jnp.asarray(r.prompt) for r in reqs])
    logits_fp, _ = tiny.prefill(params, prompts)
    logits_q, _ = tiny.prefill(qparams, prompts)
    k = 5
    topk_fp = np.asarray(jax.lax.top_k(logits_fp[:, -1], k)[1])
    topk_q = np.asarray(jax.lax.top_k(logits_q[:, -1], k)[1])
    top1_fp = topk_fp[:, 0]
    top1_q = topk_q[:, 0]
    for b in range(len(reqs)):
        assert top1_q[b] in topk_fp[b], (b, top1_q[b], topk_fp[b])
        assert top1_fp[b] in topk_q[b], (b, top1_fp[b], topk_q[b])


def test_quantize_model_stacked_reduces_storage(tiny, key):
    params = tiny.init(key)
    qparams, stats = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=8))

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    assert nbytes(qparams) < nbytes(params) * 0.7
    assert stats  # at least one tensor quantized


def test_health_monitor_failure_and_straggler_detection():
    from repro.distributed.fault import HealthMonitor

    mon = HealthMonitor(n_hosts=32, timeout_s=10.0, straggler_factor=2.0)
    t = 100.0
    for i in range(32):
        mon.heartbeat(i, step_time_s=1.0, now=t)
    assert mon.check(now=t + 5).action == "none"
    # host 7 goes slow
    mon.heartbeat(7, step_time_s=5.0, now=t + 6)
    plan = mon.check(now=t + 8)
    assert plan.action == "mitigate_stragglers" and plan.straggler_hosts == [7]
    # hosts 16..31 die (a pod) -> remesh to the single-pod survivor mesh
    for i in range(16):
        mon.heartbeat(i, step_time_s=1.0, now=t + 25)
    plan = mon.check(now=t + 31)  # 16..31 silent for >25s > timeout
    assert plan.action == "remesh"
    assert set(plan.dead_hosts) == set(range(16, 32))
    assert plan.new_mesh_shape == (16, 16)


def test_run_with_retries():
    from repro.distributed.fault import run_with_retries

    calls = {"n": 0}

    def flaky(attempt):
        calls["n"] += 1
        if attempt < 2:
            raise TimeoutError("straggler abort")
        return "done"

    attempts, res = run_with_retries(flaky, max_restarts=3)
    assert res == "done" and attempts == 2 and calls["n"] == 3


def test_flash_decode_kernel_in_engine_path(key):
    """flash_decode_gqa == decode_attention_gqa on the engine's shapes."""
    from repro.kernels.decode_attention import flash_decode_gqa
    from repro.models.layers import decode_attention_gqa
    q = jax.random.normal(key, (2, 1, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 2, 64))
    o1 = flash_decode_gqa(q, k, v, jnp.int32(300), interpret=True)
    o2 = decode_attention_gqa(q, k, v, jnp.int32(300))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)
