"""layer_chunk'd stack driver (bit-identical to the whole-stack launch),
the per-lane calibration gather, and the MoE expert dispatch routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flrq import FLRQConfig, quantize_stack
from repro.quant.stacked import _group_calib, quantize_model_stacked

QT_FIELDS = ("packed", "scale", "zp", "u", "v", "act_scale_inv")


def _mk_stack(seed, L, m, n, scale=0.5):
    base = jax.random.normal(jax.random.PRNGKey(seed), (L, m, n)) * 0.02
    layers = []
    for i in range(L):
        r = 4 + 2 * i
        sv = 2.0 ** -jnp.arange(r)
        u = jax.random.normal(jax.random.PRNGKey(seed + 10 + i), (m, r))
        v = jax.random.normal(jax.random.PRNGKey(seed + 40 + i), (r, n))
        layers.append(base[i] + (u * sv) @ v * scale)
    return jnp.stack(layers)


def _assert_qt_equal(qa, qb, msg=""):
    for f in QT_FIELDS:
        a, b = np.asarray(getattr(qa, f)), np.asarray(getattr(qb, f))
        assert a.shape == b.shape, (msg, f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f"{msg}:{f}")


@pytest.fixture(scope="module")
def stack4():
    return _mk_stack(0, 4, 256, 512)


@pytest.fixture(scope="module")
def xcal():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 512))
    outlier = 1 + 5.0 * (jax.random.uniform(jax.random.PRNGKey(4),
                                            (512,)) < 0.02)
    return x * outlier


# ------------------------------------------------------- layer chunking
@pytest.mark.parametrize("chunk", [1, 3, 4])
def test_layer_chunk_bitwise_identical(stack4, xcal, chunk):
    """layer_chunk ∈ {1, non-divisor (tail chunk), L} — bit-identical
    QTensors and ranks to the whole-stack launch. The PRNG chain is
    per-lane, so chunk boundaries cannot shift any lane's keys."""
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    qt0, st0 = quantize_stack(stack4, xcal, cfg, jax.random.PRNGKey(0))
    qtk, stk = quantize_stack(stack4, xcal, cfg, jax.random.PRNGKey(0),
                              layer_chunk=chunk)
    _assert_qt_equal(qt0, qtk, f"chunk={chunk}")
    assert [s.rank for s in st0] == [s.rank for s in stk]


def test_layer_chunk_no_calib_and_donate(stack4):
    """Chunking composes with the Frobenius objective and with donation
    (each chunk's transposed slice is consumed as it is quantized)."""
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    qt0, _ = quantize_stack(stack4, None, cfg, jax.random.PRNGKey(0))
    qtk, _ = quantize_stack(stack4 * 1.0, None, cfg, jax.random.PRNGKey(0),
                            layer_chunk=2, donate=True)
    _assert_qt_equal(qt0, qtk, "chunk+donate")


def test_layer_chunk_with_mesh(stack4, xcal):
    """chunked + sharded (1-device mesh machinery path) == plain."""
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    mesh = jax.make_mesh((1,), ("stack",))
    qt0, _ = quantize_stack(stack4, xcal, cfg, jax.random.PRNGKey(0))
    qtk, _ = quantize_stack(stack4, xcal, cfg, jax.random.PRNGKey(0),
                            mesh=mesh, layer_chunk=3)
    _assert_qt_equal(qt0, qtk, "chunk+mesh")


def test_layer_chunk_through_fused_driver(stack4, xcal):
    """Driver-level: fusion + layer_chunk == plain driver, bit for bit
    (the sharded+fused combination rides the same _quantize_substack)."""
    params = {"layers": {"wq": jnp.swapaxes(stack4, -1, -2),
                         "wk": jnp.swapaxes(_mk_stack(100, 4, 256, 512),
                                            -1, -2)}}
    calib = {"['layers']['wq']": xcal, "['layers']['wk']": xcal * 1.3}
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    q0, s0 = quantize_model_stacked(params, calib, cfg)
    qk, sk = quantize_model_stacked(params, calib, cfg, layer_chunk=3)
    for (pa, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(q0)[0],
                               jax.tree_util.tree_flatten_with_path(qk)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pa))
    for k in s0:
        assert [s.rank for s in s0[k]] == [s.rank for s in sk[k]]


def test_layer_chunk_rejects_sequential_engine(stack4, xcal):
    with pytest.raises(ValueError):
        quantize_model_stacked({"layers": {}}, None,
                               FLRQConfig(), engine="sequential",
                               layer_chunk=2)


# --------------------------------------------- per-lane calib gather
def test_group_calib_unique_plus_index():
    """Differing member batches produce a (U, tokens, n) unique stack and
    a lane index — never the ΣL-lane broadcast; value-equal batches from
    different loads share one unique slot."""
    from repro.quant.stacked import _StackEntry
    x1 = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    leaf = jnp.zeros((3, 64, 64))
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    mk = lambda xc: _StackEntry("p", leaf, xc, keys)

    x, idx = _group_calib([mk(x1), mk(x2), mk(jnp.array(x1))])
    assert x.shape == (2, 16, 64)  # unique batches only
    np.testing.assert_array_equal(
        np.asarray(idx), np.repeat(np.asarray([0, 1, 0], np.int32), 3))

    x, idx = _group_calib([mk(x1), mk(jnp.array(x1))])
    assert x.shape == (16, 64) and idx is None  # shared → no index

    x, idx = _group_calib([mk(None), mk(None)])
    assert x is None and idx is None


def test_x_index_matches_materialized_per_lane(stack4, xcal):
    """quantize_stack(x_index=...) == the materialized (L, tokens, n)
    per-lane batch, bit for bit — incl. chunked and 1-device-mesh runs."""
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    xs = [xcal, xcal * 1.3]
    x_mat = jnp.concatenate(
        [jnp.broadcast_to(xi, (2,) + xi.shape) for xi in xs])
    x_uniq = jnp.stack(xs)
    idx = jnp.asarray([0, 0, 1, 1], jnp.int32)
    qa, _ = quantize_stack(stack4, x_mat, cfg, jax.random.PRNGKey(0))
    qb, _ = quantize_stack(stack4, x_uniq, cfg, jax.random.PRNGKey(0),
                           x_index=idx)
    _assert_qt_equal(qa, qb, "x_index")
    qc, _ = quantize_stack(stack4, x_uniq, cfg, jax.random.PRNGKey(0),
                           x_index=idx, layer_chunk=3)
    _assert_qt_equal(qa, qc, "x_index+chunk")
    mesh = jax.make_mesh((1,), ("stack",))
    qd, _ = quantize_stack(stack4, x_uniq, cfg, jax.random.PRNGKey(0),
                           x_index=idx, mesh=mesh)
    _assert_qt_equal(qa, qd, "x_index+mesh")


# ------------------------------------------------- MoE expert dispatch
def test_expert_mm_routes_through_dispatch(stack4):
    """Quantized expert weights go through quant.apply.dispatch: ref
    backend reproduces the old vmapped apply exactly and the decision is
    recorded in the dispatch log (never-silent contract)."""
    from repro.core.flrq import layer_key_chain
    from repro.models.moe import _expert_mm
    from repro.quant.apply import (apply_lowrank_separate,
                                   clear_dispatch_log, dispatch_log)

    E, d_in, d_out = 4, 512, 256
    w_model = jnp.swapaxes(_mk_stack(7, E, d_out, d_in), -1, -2)
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    keys, _ = layer_key_chain(jax.random.PRNGKey(0), E)
    qt, _ = quantize_stack(jnp.swapaxes(w_model, -1, -2), None, cfg,
                           keys=keys)

    xg = jax.random.normal(jax.random.PRNGKey(1), (E, 8, d_in))
    clear_dispatch_log()
    y = _expert_mm(xg, qt, "ecd,edf->ecf")
    assert y.shape == (E, 8, d_out)
    y_ref = apply_lowrank_separate(qt, xg, out_dtype=xg.dtype)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    log = dispatch_log()
    assert len(log) == 1 and log[0].shape == (d_out, d_in)

    # batched-rows layout (B, E, c, D): lane axis is moved, result moved back
    xg4 = jax.random.normal(jax.random.PRNGKey(2), (2, E, 8, d_in))
    y4 = _expert_mm(xg4, qt, "becd,edf->becf")
    assert y4.shape == (2, E, 8, d_out)
    y4_ref = jnp.swapaxes(
        apply_lowrank_separate(qt, jnp.swapaxes(xg4, 0, 1),
                                      out_dtype=xg4.dtype), 0, 1)
    np.testing.assert_array_equal(np.asarray(y4), np.asarray(y4_ref))


def test_expert_mm_fused_interpret_close_to_ref(stack4):
    """The experts' fused-kernel route (interpret mode off-TPU) agrees
    with the ref path through the same dispatch entry point."""
    from repro.core.flrq import layer_key_chain
    from repro.models.moe import _expert_mm
    from repro.quant.apply import backend_scope

    E, d_in, d_out = 2, 512, 256
    w = _mk_stack(11, E, d_out, d_in)
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    keys, _ = layer_key_chain(jax.random.PRNGKey(0), E)
    qt, _ = quantize_stack(w, None, cfg, keys=keys)
    xg = jax.random.normal(jax.random.PRNGKey(1), (E, 8, d_in))
    with backend_scope("ref"):
        y_ref = _expert_mm(xg, qt, "ecd,edf->ecf")
    with backend_scope("fused", interpret=True):
        y_fused = _expert_mm(xg, qt, "ecd,edf->ecf")
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------- end-to-end slow smoke
@pytest.mark.slow
def test_chunked_quantize_smoke_1k():
    """(L=8, 1k, 1k) end-to-end chunked quantization — the production-
    shape smoke: chunked, donating, Frobenius objective; finite outputs
    and the layer_chunk==whole-stack parity on a 1k-wide tensor."""
    L, m, n = 8, 1024, 1024
    w = _mk_stack(20, L, m, n, scale=0.3)
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    qt, stats = quantize_stack(w * 1.0, None, cfg, jax.random.PRNGKey(0),
                               layer_chunk=3, donate=True)
    assert qt.packed.shape[:2] == (L, m)
    assert len(stats) == L
    for st in stats:
        assert np.isfinite(st.err_after)
        assert st.err_after <= st.err_before + 1e-6
