"""Paged KV cache + radix prefix reuse behind the CacheBackend API.

The paged backend's contract is *bitwise* parity with the dense oracle
under greedy sampling — same tokens across fp/quantized models, kv8/fp16
caches, GQA/MHA attention and scan/unrolled stacks — plus the paging
semantics on top: prefix sharing actually skips prefill work,
copy-on-write isolates divergent continuations, page exhaustion is a
typed admission outcome (never a crash), and a supervisor restart
rebuilds page tables and re-pins shared prefixes.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.kernels.decode_attention import (flash_decode_gqa,
                                            flash_decode_gqa_paged)
from repro.models import LM
from repro.models.layers import flash_attention
from repro.quant.stacked import quantize_model_stacked
from repro.serve import (CacheConfig, DenseCacheBackend, PagedCacheBackend,
                         PageExhaustionError, Supervisor, SupervisorConfig,
                         VirtualClock)
from repro.serve.engine import Engine, Request, ServeConfig
from repro.serve.faults import FaultPlan
from repro.serve.scheduler import ContinuousScheduler


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                head_dim=32, d_ff=128, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


@pytest.fixture(scope="module")
def tiny_fp(key):
    model = LM(_tiny_cfg())
    return model, model.init(key)


@pytest.fixture(scope="module")
def tiny_quant(tiny_fp):
    model, params = tiny_fp
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=4))
    return model, qparams


@pytest.fixture(scope="module")
def tiny_gqa(key):
    model = LM(_tiny_cfg(n_heads=4, n_kv_heads=2, head_dim=16,
                         grouped_decode_attn=True))
    return model, model.init(key)


def _mixed_requests(lens=(3, 9, 5, 14, 7), vocab=128, new=None, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, vocab, l).astype(np.int32),
                    max_new_tokens=(new or 4 + i), id=i)
            for i, l in enumerate(lens)]


def _prefix_requests(n=5, prefix_len=16, tail_lens=(3, 5, 2, 7, 4),
                     new=4, seed=3):
    """Same-system-prompt workload: every request shares the first
    ``prefix_len`` tokens (>= 2 full pages at page_size=8)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(2, 128, prefix_len).astype(np.int32)
    return [Request(np.concatenate(
        [prefix, rng.integers(2, 128, tail_lens[i % len(tail_lens)])
         .astype(np.int32)]), max_new_tokens=new, id=i)
        for i in range(n)]


def _serve(model, params, reqs, cache=None, slots=3, chunk=4, max_seq=32,
           arrivals=None, **scfg):
    if cache is None:
        cfg = ServeConfig(max_slots=slots, max_seq=max_seq, **scfg)
    else:
        cfg = ServeConfig(cache=cache, **scfg)
    eng = Engine(model, params, cfg)
    sched = ContinuousScheduler(eng, prefill_chunk=chunk)
    res = sched.run(reqs, arrivals)
    return {r.id: (r.tokens, r.status) for r in res}, eng


def _paged(slots=3, max_seq=32, page=8, **kw):
    return CacheConfig(backend="paged", max_slots=slots, max_seq=max_seq,
                       page_size=page, **kw)


# --------------------------------------------- paged vs dense bitwise parity
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "unroll"])
def test_paged_matches_dense_fp(tiny_fp, scan):
    model, params = tiny_fp
    model = model.with_scan(scan)
    reqs = _mixed_requests()
    dense, _ = _serve(model, params, reqs)
    paged, eng = _serve(model, params, reqs, cache=_paged())
    assert paged == dense
    assert isinstance(eng.cache_backend, PagedCacheBackend)


def test_paged_matches_dense_gqa(tiny_gqa):
    model, params = tiny_gqa
    reqs = _mixed_requests()
    dense, _ = _serve(model, params, reqs)
    paged, _ = _serve(model, params, reqs, cache=_paged())
    assert paged == dense


def test_paged_matches_dense_quant(tiny_quant):
    model, params = tiny_quant
    reqs = _mixed_requests()
    dense, _ = _serve(model, params, reqs)
    paged, _ = _serve(model, params, reqs, cache=_paged())
    assert paged == dense


def test_paged_matches_dense_kv8(tiny_fp):
    """int8 KV cache forced through CacheConfig on BOTH backends: the
    paged pool carries codes + scales leaves and stays bitwise-equal."""
    model, params = tiny_fp
    reqs = _mixed_requests()
    dense, deng = _serve(model, params, reqs,
                         cache=CacheConfig(max_slots=3, max_seq=32,
                                           kv_cache_bits=8))
    paged, peng = _serve(model, params, reqs,
                         cache=_paged(kv_cache_bits=8))
    assert paged == dense
    assert deng.model.cfg.kv_cache_bits == 8
    pools = peng.cache_backend.device_state
    code_dtypes = {v.dtype for k, v in pools.items() if "scale" not in k}
    assert code_dtypes == {np.dtype(np.int8)}, pools.keys()


def test_paged_matches_dense_per_slot_fallback(tiny_fp):
    """batched_prefill=False routes through prefill_chunk (the per-slot
    gather/scatter path) and must stay on the same tokens."""
    model, params = tiny_fp
    reqs = _mixed_requests()
    dense, _ = _serve(model, params, reqs)
    paged, eng = _serve(model, params, reqs, cache=_paged(),
                        batched_prefill=False)
    assert paged == dense
    assert eng.cache_backend.stats()["prefill_launches"] > 0


# ----------------------------------------------------------- prefix sharing
def test_prefix_sharing_skips_prefill_work(tiny_fp):
    model, params = tiny_fp
    reqs = _prefix_requests()
    dense, deng = _serve(model, params, reqs, slots=2)
    paged, peng = _serve(model, params, reqs, cache=_paged(slots=2))
    assert paged == dense
    dstats = deng.cache_backend.stats()
    pstats = peng.cache_backend.stats()
    assert pstats["prefix_hit_rate"] > 0.0
    assert pstats["hit_tokens"] > 0
    # shared-prefix pages prefill once, not once per request
    assert pstats["prefill_tokens"] < dstats["prefill_tokens"]
    assert pstats["pages_resident"] > 0


def test_prefix_cache_off_still_matches(tiny_fp):
    model, params = tiny_fp
    reqs = _prefix_requests()
    dense, _ = _serve(model, params, reqs, slots=2)
    paged, eng = _serve(model, params, reqs,
                        cache=_paged(slots=2, prefix_cache=False))
    assert paged == dense
    assert eng.cache_backend.stats()["prefix_hit_rate"] == 0.0


def test_cow_divergent_page_isolation(tiny_fp):
    """A then B (diverging mid-page) then A again, one slot at a time:
    B's copy-on-write page must not leak into either A's tokens, and the
    divergence must actually take the CoW path."""
    model, params = tiny_fp
    rng = np.random.default_rng(11)
    base = rng.integers(2, 128, 20).astype(np.int32)   # 2 FULL pages @ 8
    divergent = base.copy()
    divergent[10] = (divergent[10] + 1) % 126 + 2   # mid page 1 (page=8)
    reqs = [Request(base, max_new_tokens=5, id=0),
            Request(divergent, max_new_tokens=5, id=1),
            Request(base.copy(), max_new_tokens=5, id=2)]
    dense, _ = _serve(model, params, reqs, slots=1)
    paged, eng = _serve(model, params, reqs, cache=_paged(slots=1))
    assert paged == dense
    stats = eng.cache_backend.stats()
    assert stats["cow_copies"] >= 1
    assert paged[0][0] == paged[2][0]    # same prompt, same greedy tokens


# -------------------------------------------------------- admission control
def test_page_exhaustion_permanent_rejects_cleanly(tiny_fp):
    """A request that can NEVER fit the pool retires ``rejected`` (typed
    admission outcome, not a crash); everything else still serves."""
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(3, 20, 5), new=4)
    cache = _paged(slots=2, page=4, num_pages=4)   # 16-token pool
    res, _ = _serve(model, params, reqs, cache=cache)
    assert res[1][1] == "rejected" and res[1][0] == []
    dense, _ = _serve(model, params,
                      [r for r in reqs if r.id != 1], slots=2)
    assert {i: res[i] for i in (0, 2)} == dense


def test_page_exhaustion_transient_waits_for_free_pages(tiny_fp):
    """Two requests that fit the pool one-at-a-time but not together:
    the second stays QUEUED through the transient exhaustion and
    completes bitwise-correct once the first retires its pages."""
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(9, 10), new=4, seed=5)
    cache = _paged(slots=2, page=4, num_pages=4,   # 16 tokens: one req max
                   prefix_cache=False)
    res, eng = _serve(model, params, reqs, cache=cache)
    dense, _ = _serve(model, params, reqs, slots=2)
    assert res == dense
    assert all(s == "ok" for _, s in res.values())
    # the pool really was the constraint: all pages recycled at drain
    assert eng.cache_backend.stats()["page_utilization"] == 0.0


def test_alloc_free_recycles_pages(tiny_fp):
    """Direct backend-level accounting: alloc takes pages from the free
    list, free returns every non-trie page."""
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(cache=_paged(slots=2)))
    be = eng.cache_backend
    be.start()
    free0 = len(be._free)
    prompt = np.arange(2, 13, dtype=np.int32)
    matched = be.alloc(0, prompt, 4)
    assert matched == 0                     # cold trie: full miss
    assert len(be._free) == free0 - 2       # ceil((11+4)/8) pages taken
    with pytest.raises(PageExhaustionError) as ei:
        be.alloc(1, prompt, 10 ** 6)
    assert ei.value.permanent
    be.free(0)
    assert len(be._free) == free0
    assert (be._table == be._scratch).all()


def test_matched_pages_pinned_before_eviction(tiny_fp):
    """Regression: a matched trie leaf with no live readers must NOT be
    an eviction victim for the very alloc that matched it — pre-fix it
    was evicted to the free list and immediately recycled as the same
    request's fresh writable page, so prefill clobbered the shared
    prefix. The alloc must instead raise a *transient* exhaustion with
    the trie (and refcounts) left intact."""
    model, params = tiny_fp
    eng = Engine(model, params,
                 ServeConfig(cache=_paged(slots=3, page=4, num_pages=6)))
    be = eng.cache_backend
    be.start()
    a = np.arange(2, 10, dtype=np.int32)          # 2 full pages @ 4
    be.alloc(0, a, 4)                             # 3 pages
    be.register_prompt(0, a)                      # pages 0,1 -> trie
    p0, p1 = int(be._table[0, 0]), int(be._table[0, 1])
    be.free(0)                                    # trie pages stay out of
    assert be._ref[p0] == 0 and be._ref[p1] == 0  # the free list, ref=0
    c = np.full(5, 100, np.int32)                 # no trie overlap
    be.alloc(2, c, 3)                             # 2 pages -> 2 free left
    b = np.concatenate([a, np.arange(50, 54, dtype=np.int32)])
    with pytest.raises(PageExhaustionError) as ei:
        be.alloc(1, b, 8)      # needs 5: matches 2, 3 fresh > 2 free
    assert not ei.value.permanent
    # the matched leaf p1 was the only ref==0 trie leaf — it must have
    # been pinned, not evicted and recycled
    assert p1 in be._trie_pages and p1 not in be._free
    assert be._ref[p0] == 0 and be._ref[p1] == 0  # unpinned on the raise
    be.free(2)                                    # pages return; retry fits
    assert be.alloc(1, b, 8) == 2 * 4             # full 2-page prefix hit
    live = [int(p) for p in be._table[1] if int(p) != be._scratch]
    assert len(live) == len(set(live)) == 5       # no page mapped twice
    assert be._ref[p1] == 1


def test_cow_source_stays_evictable_under_pressure(tiny_fp):
    """Counterpart to the pinning test: the CoW *source* must NOT be
    pinned. It is read exactly once inside alloc (the copy runs before
    any pool write), so an evicted-and-recycled source still holds
    valid bytes — while protecting it would livelock a pool-sized
    request whose only evictable page is its own divergent sibling
    (exactly the CoW-isolation serve test's shape: pool == need)."""
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(cache=_paged(slots=1)))
    be = eng.cache_backend                 # page=8, num_pages=pps=4
    be.start()
    a = np.arange(2, 22, dtype=np.int32)   # 20 tokens: 2 full pages
    be.alloc(0, a, 5)                      # all 4 pages
    be.register_prompt(0, a)
    p0, p1 = int(be._table[0, 0]), int(be._table[0, 1])
    be.free(0)                             # free=2, trie holds p0,p1
    b = a.copy()
    b[10] += 1                             # diverge mid page 1: cp=2
    matched = be.alloc(0, b, 5)            # fresh=3 > free=2: must evict
    assert matched == 8 + 2                # page 0 shared + 2 CoW tokens
    assert be.cow_copies == 1
    assert int(be._table[0, 0]) == p0      # match survived, pinned
    assert be._ref[p0] == 1
    assert p1 not in be._trie_pages        # the source was the victim


# ------------------------------------------------- supervisor + restarts
def test_supervisor_restart_rebuilds_paged_state(tiny_fp):
    """Kill a paged replica mid-decode: the restart rebuilds page tables
    and the prefix trie from scratch and every salvaged request still
    finishes bitwise-identical to the fault-free dense oracle — with the
    shared prefix re-pinned (prefix hits on the re-prefill)."""
    model, params = tiny_fp
    reqs = _prefix_requests(n=6, new=5)
    oracle = {}
    for r in reqs:
        eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
        oracle[r.id] = eng.generate([r])[0].tokens
    sup = Supervisor(
        lambda: Engine(model, params,
                       ServeConfig(cache=_paged(slots=2, max_seq=32))),
        SupervisorConfig(replicas=2, step_cost_s=0.01, prefill_chunk=4),
        fault_plan=FaultPlan.parse("exception@4:decode:0"),
        clock=VirtualClock())
    report = sup.serve(reqs)
    assert report.zero_drops
    assert set(report.status_counts()) == {"ok"}
    for o in report.outcomes:
        assert o.tokens == oracle[o.id], \
            f"request {o.id} diverged after paged restart"
    assert report.restarts[0] >= 1


# ------------------------------------------------------------- CacheConfig
def test_cache_config_mirrors_serve_config():
    cfg = ServeConfig(cache=CacheConfig(backend="paged", max_slots=2,
                                        max_seq=64, page_size=16))
    assert cfg.max_slots == 2 and cfg.max_seq == 64
    legacy = ServeConfig(max_slots=5, max_seq=48)
    assert legacy.cache.backend == "dense"
    assert legacy.cache.max_slots == 5 and legacy.cache.max_seq == 48
    assert ServeConfig(donate_cache=True).resolve_donate() is True
    assert CacheConfig(donate_cache=True).resolve_donate() is True


def test_cache_config_page_arithmetic():
    cfg = CacheConfig(backend="paged", max_slots=3, max_seq=33, page_size=8)
    assert cfg.pages_per_slot == 5          # ceil(33 / 8)
    assert cfg.total_pages == 15
    assert CacheConfig(backend="paged", num_pages=7).total_pages == 7
    with pytest.raises(ValueError):
        CacheConfig(backend="flat")
    with pytest.raises(ValueError):
        CacheConfig(backend="paged", page_size=0)


def test_backend_factory_and_stats_shape(tiny_fp):
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32))
    be = eng.cache_backend
    assert isinstance(be, DenseCacheBackend)
    be.start()
    stats = be.stats()
    assert stats["backend"] == "dense"
    assert stats["prefix_hit_rate"] == 0.0
    assert 0.0 <= stats["page_utilization"] <= 1.0


# --------------------------------------------------- shim removal (PR 8)
def test_deprecated_engine_cache_shims_removed(tiny_fp):
    """The PR 7 deprecation cycle is complete: the Engine-level cache
    shims are gone — CacheBackend is the only cache surface."""
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32))
    for name in ("new_cache", "prefill_slot_chunk", "decode_slots"):
        assert not hasattr(eng, name), f"Engine.{name} should be removed"
    # the backend path serves clean — no warnings of any kind
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _serve(model, params, _mixed_requests(lens=(3, 5)), slots=2)


# ------------------------------------------------- batched prefill kernel
def test_flash_attention_per_lane_q_offset(key):
    """(B,) q_offset == the per-lane scalar calls it batches (the (B, C)
    prefill launch relies on this)."""
    b, s, kvlen, h, hd = 3, 8, 24, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, kvlen, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, kvlen, h, hd), jnp.float32)
    offs = jnp.asarray([0, 5, 16], jnp.int32)
    batched = flash_attention(q, k, v, causal=True, q_offset=offs)
    for i in range(b):
        one = flash_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                              causal=True, q_offset=int(offs[i]))
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(one[0]))


def _gather_dense(pool, table):
    b, pps = table.shape
    _, page = pool.shape[0], pool.shape[1]
    return pool[table.reshape(-1)].reshape((b, pps * pool.shape[1])
                                           + pool.shape[2:])


def test_paged_decode_kernel_matches_dense(key):
    """flash_decode_gqa_paged (scalar-prefetched block-table kernel) ==
    flash_decode_gqa over the gathered dense view, fp and int8."""
    rng = np.random.default_rng(0)
    b, h, kv, hd, page, pps, p = 3, 4, 2, 16, 8, 4, 14
    s = page * pps
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.float32)
    table = jnp.asarray(rng.permutation(p)[:b * pps].reshape(b, pps),
                        jnp.int32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)

    kp = jnp.asarray(rng.standard_normal((p, page, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((p, page, kv, hd)), jnp.float32)
    out = flash_decode_gqa_paged(q, kp, vp, table, lengths, interpret=True)
    kd, vd = _gather_dense(kp, table), _gather_dense(vp, table)
    ref = jnp.concatenate([
        flash_decode_gqa(q[i:i + 1], kd[i:i + 1], vd[i:i + 1], lengths[i],
                         interpret=True) for i in range(b)], 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)

    k8 = jnp.asarray(rng.integers(-127, 127, (p, page, kv, hd)), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 127, (p, page, kv, hd)), jnp.int8)
    ks8 = jnp.asarray(rng.uniform(0.01, 0.02, (p, page, kv, 1)),
                      jnp.bfloat16)
    vs8 = jnp.asarray(rng.uniform(0.01, 0.02, (p, page, kv, 1)),
                      jnp.bfloat16)
    out8 = flash_decode_gqa_paged(q, k8, v8, table, lengths, ks8, vs8,
                                  interpret=True)
    kd8, vd8 = _gather_dense(k8, table), _gather_dense(v8, table)
    ksd, vsd = _gather_dense(ks8, table), _gather_dense(vs8, table)
    ref8 = jnp.concatenate([
        flash_decode_gqa(q[i:i + 1], kd8[i:i + 1], vd8[i:i + 1], lengths[i],
                         ksd[i:i + 1], vsd[i:i + 1], interpret=True)
        for i in range(b)], 0)
    np.testing.assert_allclose(np.asarray(out8), np.asarray(ref8),
                               atol=2e-6, rtol=2e-6)
