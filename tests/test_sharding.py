"""Sharding rules + small-mesh dry-run (multi-device lowering is exercised
on 8 forced host devices in a subprocess; the full 512-device sweep lives
in launch/dryrun.py)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, all_cells, cell_status, get_config
from repro.distributed import sharding as shd
from repro.distributed.roofline import (
    Roofline,
    analytic_flops,
    collective_stats,
    min_hbm_bytes,
    model_flops_for,
)


def test_param_rules_cover_all_archs():
    """Every parameter of every full-size arch gets a valid spec, and big
    matrices actually shard on both axes."""
    for arch, cfg in ARCHS.items():
        from repro.models import LM
        shapes = jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))

        def visit(path, leaf):
            pstr = jax.tree_util.keystr(path)
            spec = shd.param_spec(pstr, leaf.shape, cfg)
            assert len(spec) == len(leaf.shape), (arch, pstr)
            if leaf.size > 64e6:  # big tensors must shard
                assert any(a is not None for a in spec), (arch, pstr)
            return leaf

        jax.tree_util.tree_map_with_path(visit, shapes)


def test_resolve_spec_divisibility_guard():
    mesh = jax.make_mesh((1,), ("data",))
    # axis not in mesh -> dropped
    assert shd.resolve_spec(P("model"), mesh, (25,)) == P(None)
    # non-divisible dim -> dropped (simulated via a size-1 'data' axis is
    # always divisible, so check the arithmetic directly)
    mesh_sizes = shd._axis_size(mesh, ("data",))
    assert mesh_sizes == 1


def test_cell_enumeration_is_40():
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    # hubert decode_32k+long_500k (2) + 7 other non-sub-quadratic long_500k
    assert len(skips) == 9
    for _, _, ok, why in cells:
        assert ok or why


def test_collective_parser():
    hlo = """
  %all-gather.4 = f32[36,2560,9728]{1,0,2} all-gather(%x), channel_id=55, replica_groups=[16,16]<=[256], dimensions={2}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
"""
    st = collective_stats(hlo, 256)
    assert st.count == 2
    ag = 36 * 2560 * 9728 * 4 * 15 / 16
    ar = 2 * 1024 * 2 * 3 / 4
    assert abs(st.by_kind["all-gather"] - ag) / ag < 1e-6
    assert abs(st.by_kind["all-reduce"] - ar) / ar < 1e-6


def test_analytic_flops_sane():
    """6·N·D within 2× for a dense train cell (attention adds the rest)."""
    cfg = get_config("qwen3-4b")
    shape = SHAPES["train_4k"]
    got = analytic_flops(cfg, shape, include_remat=False)
    approx = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    assert 0.5 < got / approx < 2.0


def test_min_bytes_quantized_smaller():
    cfg = get_config("mistral-nemo-12b")
    shape = SHAPES["decode_32k"]
    assert min_hbm_bytes(cfg, shape, quantized=True) < \
        min_hbm_bytes(cfg, shape, quantized=False)


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=1e15, hbm_bytes=1e12, wire_bytes=1e9, n_devices=256,
                 model_flops=5e14, min_bytes=5e11)
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective > 0
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.roofline_fraction <= 1.01


_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["DRYRUN_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh
import repro.configs as C
import dataclasses
# shrink shapes so an 8-device CPU mesh can lower quickly
C.SHAPES = {
  "train_4k": C.ShapeSpec("train_4k", 128, 8, "train"),
  "decode_32k": C.ShapeSpec("decode_32k", 256, 8, "decode"),
}
mesh = make_mesh((2, 4), ("data", "model"))
out = {}
for arch in ["qwen3-4b", "rwkv6-1.6b"]:
    cfg = C.get_config(arch)
    C.ARCHS[arch] = dataclasses.replace(
        cfg, n_layers=2, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab=2048)
    for shape in ["train_4k", "decode_32k"]:
        lowered, n_dev, _ = lower_cell(arch, shape, mesh=mesh)
        lowered.compile()
        out[f"{arch}/{shape}"] = "OK"
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """Real multi-device (8 forced CPU devices) lower+compile."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(v == "OK" for v in out.values())
