"""Unit tests: quantization primitives, R1-Sketch, R1-FLR, BLC, FLRQ."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLRConfig,
    QuantSpec,
    blc,
    flexible_rank_select,
    flexible_rank_select_py,
    lowrank_error,
    pseudo_quantize,
    rank1_sketch,
    recon_error,
    rsvd,
    sketch_lowrank,
    sketch_lowrank_block,
    truncated_svd,
)
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import awq_scale, channel_mean_abs, search_clip_ratio


# ---------------------------------------------------------------- quantize
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
def test_pseudo_quantize_error_bound(llm_like_matrix, bits, symmetric):
    spec = QuantSpec(bits, 128, symmetric)
    w = llm_like_matrix
    wq = pseudo_quantize(w, spec)
    # max error <= scale/2 per element; scale <= range/levels
    g = np.asarray(w).reshape(256, -1, 128)
    rng = g.max(-1) - g.min(-1)
    if symmetric:
        rng = 2 * np.abs(g).max(-1)
    max_scale = rng / ((1 << bits) - 1) if not symmetric else rng / (2 * ((1 << (bits - 1)) - 1))
    err = np.abs(np.asarray(wq - w)).reshape(256, -1, 128).max(-1)
    assert (err <= max_scale * 0.5 + 1e-6).all()


def test_quantize_monotone_in_bits(llm_like_matrix):
    errs = [float(recon_error(llm_like_matrix,
                              pseudo_quantize(llm_like_matrix, QuantSpec(b, 128))))
            for b in (2, 3, 4, 8)]
    assert errs == sorted(errs, reverse=True)


def test_clip_search_never_worse_than_unclipped(llm_like_matrix, calib_acts):
    spec = QuantSpec(3, 128)
    x = calib_acts.T
    c = search_clip_ratio(llm_like_matrix, x, spec)
    e1 = recon_error(llm_like_matrix, pseudo_quantize(llm_like_matrix, spec, c), x)
    e0 = recon_error(llm_like_matrix, pseudo_quantize(llm_like_matrix, spec, 1.0), x)
    assert float(e1) <= float(e0) + 1e-7


def test_awq_scale_properties(calib_acts):
    alpha = awq_scale(channel_mean_abs(calib_acts))
    assert alpha.shape == (512,)
    assert bool(jnp.all(alpha > 0))
    # geometric mean ~ 1 (magnitude preserving)
    assert abs(float(jnp.mean(jnp.log(alpha)))) < 0.3


# ---------------------------------------------------------------- r1 sketch
def test_rank1_sketch_exact_on_rank1(key):
    u = jax.random.normal(key, (64,))
    v = jax.random.normal(jax.random.PRNGKey(9), (128,))
    a = jnp.outer(u, v)
    u1, v1 = rank1_sketch(a, key, it=2)
    assert float(lowrank_error(a, u1[:, None], v1[None, :])) < 1e-5


def test_sketch_matches_svd_quality(llm_like_matrix, key):
    for r in (4, 8, 16):
        us, vs = sketch_lowrank(llm_like_matrix, key, r, it=2)
        ut, vt = truncated_svd(llm_like_matrix, r)
        e_s = float(lowrank_error(llm_like_matrix, us, vs))
        e_t = float(lowrank_error(llm_like_matrix, ut, vt))
        assert e_s <= e_t * 1.05 + 1e-6  # paper: same accuracy as (R)SVD


def test_block_sketch_matches(llm_like_matrix, key):
    ub, vb = sketch_lowrank_block(llm_like_matrix, key, 16, block=8, it=2)
    ut, vt = truncated_svd(llm_like_matrix, 16)
    assert float(lowrank_error(llm_like_matrix, ub, vb)) <= \
        float(lowrank_error(llm_like_matrix, ut, vt)) * 1.05 + 1e-6


def test_rsvd_matches_svd(llm_like_matrix, key):
    ur, vr = rsvd(llm_like_matrix, key, 16, it=2)
    ut, vt = truncated_svd(llm_like_matrix, 16)
    assert float(lowrank_error(llm_like_matrix, ur, vr)) <= \
        float(lowrank_error(llm_like_matrix, ut, vt)) * 1.02 + 1e-6


def test_sketch_it_convergence(llm_like_matrix, key):
    """Paper Table 7: accuracy improves with it, converged by it≈2."""
    errs = []
    for it in (0, 1, 2, 4):
        u, v = sketch_lowrank(llm_like_matrix, key, 8, it=it)
        errs.append(float(lowrank_error(llm_like_matrix, u, v)))
    assert errs[2] <= errs[0] + 1e-6
    assert abs(errs[3] - errs[2]) < 0.02  # converged at it=2


# ---------------------------------------------------------------- R1-FLR
def test_flr_py_and_lax_agree(llm_like_matrix, key):
    cfg = FLRConfig(bits=4, max_rank=32)
    u1, v1, r1, _ = flexible_rank_select_py(llm_like_matrix, key, cfg)
    res = flexible_rank_select(llm_like_matrix, key, cfg)
    assert r1 == int(res.rank)
    if r1 > 0:
        # different PRNG split orders → slightly different sketch vectors;
        # the *approximation quality* must agree
        e1 = float(jnp.linalg.norm(llm_like_matrix - u1 @ v1))
        e2 = float(jnp.linalg.norm(
            llm_like_matrix - res.u[:, :r1] @ res.v[:r1, :]))
        assert abs(e1 - e2) / e1 < 0.02


def test_flr_respects_memory_budget(llm_like_matrix, key):
    m, n = llm_like_matrix.shape
    for x in (0.05, 0.2, 0.4):
        cfg = FLRConfig(bits=4, x=x, max_rank=64, t=0.0)
        _, _, r, _ = flexible_rank_select_py(llm_like_matrix, key, cfg)
        k = 16 * r * (m + n) / (4 * m * n)
        assert k <= x + 0.05  # paper Eq. 9 budget


def test_flr_rank_grows_with_budget(llm_like_matrix, key):
    ranks = [flexible_rank_select_py(
        llm_like_matrix, key, FLRConfig(bits=2, x=x, max_rank=64, t=0.0))[2]
        for x in (0.05, 0.2, 0.4)]
    assert ranks == sorted(ranks)  # paper Table 19


# ---------------------------------------------------------------- BLC
def test_blc_monotone_best_error(llm_like_matrix, calib_acts, key):
    spec = QuantSpec(2, 128)
    res = blc(llm_like_matrix, calib_acts.T, key, spec, rank=8, epochs=6)
    # best-so-far error: final best <= init
    assert float(res.err) <= float(res.err_trace[0]) + 1e-7


def test_blc_improves_over_no_blc(llm_like_matrix, calib_acts, key):
    """Paper Table 10: BLC helps most at 2-bit."""
    cfg_no = FLRQConfig(bits=2, use_blc=False, max_rank=32)
    cfg_yes = FLRQConfig(bits=2, use_blc=True, blc_epochs=6, max_rank=32)
    _, st_no = quantize_matrix(llm_like_matrix, calib_acts, cfg_no, key)
    _, st_yes = quantize_matrix(llm_like_matrix, calib_acts, cfg_yes, key)
    assert st_yes.err_after <= st_no.err_after + 1e-6


# ---------------------------------------------------------------- FLRQ e2e
@pytest.mark.parametrize("bits", [2, 3, 4])
def test_flrq_beats_rtn(llm_like_matrix, calib_acts, bits, key):
    cfg = FLRQConfig(bits=bits, blc_epochs=2, max_rank=32)
    _, st = quantize_matrix(llm_like_matrix, calib_acts, cfg, key)
    assert st.err_after <= st.err_before + 1e-6
    if bits == 2:
        assert st.err_after < st.err_before * 0.5  # big win at 2-bit


def test_flrq_roundtrip_apply(llm_like_matrix, calib_acts, key):
    from repro.quant import apply as qapply
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=32)
    qt, _ = quantize_matrix(llm_like_matrix, calib_acts, cfg, key)
    x = jax.random.normal(key, (16, 512))
    y = qapply(qt, x)
    y_ref = x @ llm_like_matrix.T
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.05


def test_flrq_gptq_composition_beats_both(llm_like_matrix, calib_acts, key):
    """Beyond-paper: R1-FLR low-rank + GPTQ residual quantization corrects
    orthogonal error modes — composition <= min(FLRQ, GPTQ) error."""
    from repro.core.flrq_gptq import flrq_gptq_quantize
    from repro.core.gptq import gptq_quantize

    cfg = FLRQConfig(bits=3, max_rank=24)
    what_g, _ = gptq_quantize(llm_like_matrix, calib_acts, 3)
    e_gptq = float(recon_error(llm_like_matrix, what_g, calib_acts.T))
    what_c, st = flrq_gptq_quantize(llm_like_matrix, calib_acts, cfg, key)
    assert st.err_after <= e_gptq * 1.02
    assert st.err_after <= st.err_before  # robustness gate holds
