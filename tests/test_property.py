"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.quantize import QuantSpec, pseudo_quantize, compute_qparams, \
    quantize_codes, dequantize_codes
from repro.quant import packing

_SETTINGS = dict(max_examples=25, deadline=None)


@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    m=st.integers(1, 8),
    ng=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**_SETTINGS)
def test_pack_unpack_roundtrip(bits, m, ng, seed):
    """unpack(pack(c)) == c for all code tensors in range."""
    rng = np.random.default_rng(seed)
    n = ng * 128
    codes = rng.integers(0, (1 << bits), size=(m, n)).astype(np.int32)
    packed = packing.pack(jnp.asarray(codes), bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape[-1] == packing.packed_size(n, bits)
    out = packing.unpack(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(out), codes)


@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    symmetric=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
    scale_pow=st.integers(-8, 8),
)
@settings(**_SETTINGS)
def test_quantize_idempotent(bits, symmetric, seed, scale_pow):
    """Quantizing an already-quantized matrix is a fixed point."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((4, 256)) * 2.0**scale_pow,
                    jnp.float32)
    spec = QuantSpec(bits, 128, symmetric)
    w1 = pseudo_quantize(w, spec)
    w2 = pseudo_quantize(w1, spec)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-4, atol=1e-5)


@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**_SETTINGS)
def test_codes_in_range(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    spec = QuantSpec(bits, 128, False)
    scale, zp = compute_qparams(w, spec)
    codes = quantize_codes(w, spec, scale, zp)
    assert int(codes.min()) >= 0 and int(codes.max()) <= (1 << bits) - 1
    # dequant error bounded by scale
    deq = dequantize_codes(codes, spec, scale, zp)
    err = jnp.abs(deq - w).reshape(4, 2, 128)
    bound = scale.reshape(4, 2, 1) * 0.55 + 1e-6
    assert bool(jnp.all(err <= bound))


@given(
    seed=st.integers(0, 2**31 - 1),
    rank=st.integers(1, 6),
    it=st.integers(0, 3),
)
@settings(max_examples=15, deadline=None)
def test_sketch_error_decreases_with_rank(seed, rank, it):
    """Peeling r+1 components never increases residual vs peeling r."""
    from repro.core.r1_sketch import sketch_lowrank
    from repro.core.rsvd import lowrank_error
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (48, 96))
    key2 = jax.random.PRNGKey(seed + 1)
    e_r = float(lowrank_error(a, *sketch_lowrank(a, key2, rank, it=it)))
    e_r1 = float(lowrank_error(a, *sketch_lowrank(a, key2, rank + 1, it=it)))
    assert e_r1 <= e_r + 5e-3


@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([256, 384, 512]))
@settings(max_examples=10, deadline=None)
def test_gradient_compression_bounded_error(seed, n):
    """int8 compression roundtrip error ≤ amax/127 per element."""
    from repro.train.step import compress_grads
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)}
    out = compress_grads(g, "int8", dp_size=16)
    amax = float(jnp.max(jnp.abs(g["w"])))
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= amax / 127 * 0.51 + 1e-9


@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([1, 3, 16]),
)
@settings(max_examples=10, deadline=None)
def test_quant_matmul_kernel_matches_ref(bits, seed, t):
    """Pallas kernel (interpret) == jnp oracle across shapes/bits."""
    from repro.kernels import ops, ref
    from repro.core.flrq import FLRQConfig, quantize_matrix
    key = jax.random.PRNGKey(seed)
    m, n = 128, 256
    w = jax.random.normal(key, (m, n)) * 0.05
    qt, _ = quantize_matrix(w, None, FLRQConfig(
        bits=bits, blc_epochs=1, max_rank=8, use_scaling=False), key)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, n))
    y_k = np.asarray(ops.quant_matmul(qt, x, interpret=True))
    y_r = np.asarray(ref.quant_matmul_ref(
        x, qt.packed, qt.scale, qt.zp, qt.u, qt.v, qt.act_scale_inv,
        bits=bits))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
