"""Mesh-sharded whole-model quantization + same-shape stack fusion.

Parity contract: the shard_map'd engine and the fused launches must be
*bit-identical* to the single-device batched engine — sharding and fusion
are execution-layout changes, never numerics changes. Multi-device checks
run in a subprocess with 8 forced CPU host devices (the main pytest
process is pinned to 1 device; XLA locks the device count at first init).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexible_rank_select_batched, FLRConfig
from repro.core.flrq import FLRQConfig, _pad_lanes, quantize_stack, shard_count
from repro.quant.stacked import quantize_model_stacked

QT_FIELDS = ("packed", "scale", "zp", "u", "v", "act_scale_inv")


def _mk_stack(seed, L, m, n, scale=0.5):
    base = jax.random.normal(jax.random.PRNGKey(seed), (L, m, n)) * 0.02
    layers = []
    for i in range(L):
        r = 4 + 2 * i
        sv = 2.0 ** -jnp.arange(r)
        u = jax.random.normal(jax.random.PRNGKey(seed + 10 + i), (m, r))
        v = jax.random.normal(jax.random.PRNGKey(seed + 40 + i), (r, n))
        layers.append(base[i] + (u * sv) @ v * scale)
    return jnp.stack(layers)


def _assert_qt_equal(qa, qb):
    for f in QT_FIELDS:
        a, b = np.asarray(getattr(qa, f)), np.asarray(getattr(qb, f))
        assert a.shape == b.shape, (f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f)


# ------------------------------------------------------------ lane masking
def test_lane_mask_inactive_lanes_rank_zero():
    stack = _mk_stack(0, 4, 128, 256)
    cfg = FLRConfig(bits=4, max_rank=16)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    mask = jnp.asarray([True, False, True, False])
    res = flexible_rank_select_batched(stack, keys, cfg, lane_mask=mask)
    ref = flexible_rank_select_batched(stack, keys, cfg)
    ranks, ranks_ref = np.asarray(res.rank), np.asarray(ref.rank)
    assert ranks[1] == 0 and ranks[3] == 0
    np.testing.assert_array_equal(np.asarray(res.u[1]), 0.0)
    # active lanes are untouched by other lanes' masking
    assert ranks[0] == ranks_ref[0] and ranks[2] == ranks_ref[2]
    np.testing.assert_array_equal(np.asarray(res.u[2]), np.asarray(ref.u[2]))


def test_pad_lanes_repeats_last():
    a = jnp.arange(6).reshape(3, 2).astype(jnp.float32)
    p = _pad_lanes(a, 5)
    assert p.shape == (5, 2)
    np.testing.assert_array_equal(np.asarray(p[:3]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(p[3]), np.asarray(a[-1]))
    assert _pad_lanes(a, 3) is a


def test_shard_count_resolution():
    mesh = jax.make_mesh((1,), ("stack",))
    assert shard_count(mesh) == (1, "stack")
    assert shard_count(mesh, "stack") == (1, "stack")
    with pytest.raises(ValueError):
        shard_count(mesh, "nope")


# ------------------------------------------------- single-device mesh path
def test_mesh_path_matches_plain_on_one_device():
    """The shard_map path on a 1-device mesh must produce the exact arrays
    of the plain jit path (machinery check; the real multi-device run is
    the subprocess test below)."""
    stack = _mk_stack(3, 3, 128, 256)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 256))
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    mesh = jax.make_mesh((1,), ("stack",))
    qt_ref, st_ref = quantize_stack(stack, x, cfg, jax.random.PRNGKey(0))
    qt_sh, st_sh = quantize_stack(stack, x, cfg, jax.random.PRNGKey(0),
                                  mesh=mesh)
    _assert_qt_equal(qt_ref, qt_sh)
    for a, b in zip(st_ref, st_sh):
        assert a.rank == b.rank


# ----------------------------------------------------- same-shape fusion
@pytest.fixture(scope="module")
def fusion_tree():
    L, d = 3, 256
    def model_layout(seed, din, dout):
        return jnp.swapaxes(_mk_stack(seed, L, dout, din), -1, -2)
    params = {"layers": {
        "wq": model_layout(0, d, d),
        "wk": model_layout(100, d, d),
        "wo": model_layout(200, d, d),
        "w_up": model_layout(300, d, 2 * d),
    }}
    x_qk = jax.random.normal(jax.random.PRNGKey(3), (32, d))
    x_o = jax.random.normal(jax.random.PRNGKey(7), (32, d)) * 1.3
    calib = {
        "['layers']['wq']": x_qk,        # wq/wk share one batch (same input)
        "['layers']['wk']": x_qk,
        "['layers']['wo']": x_o,         # wo sees different activations →
        "['layers']['w_up']": x_qk,      #   forces the per-lane calib path
    }
    return params, calib


def test_fusion_bitwise_parity(fusion_tree):
    """Fused (wq+wk+wo in one (3L, m, n) launch, per-lane calibration) ==
    unfused, bit for bit — including the PRNG key chain."""
    params, calib = fusion_tree
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    qf, sf = quantize_model_stacked(params, calib, cfg, fuse_stacks=True)
    qu, su = quantize_model_stacked(params, calib, cfg, fuse_stacks=False)
    assert (jax.tree_util.tree_structure(qf)
            == jax.tree_util.tree_structure(qu))
    for (pa, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(qf)[0],
                               jax.tree_util.tree_flatten_with_path(qu)[0]):
        assert a.shape == b.shape, jax.tree_util.keystr(pa)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pa))
    for k in su:
        for st_f, st_u in zip(sf[k], su[k]):
            assert st_f.rank == st_u.rank
            assert st_f.name == st_u.name


def test_fusion_groups_same_shape_only(fusion_tree):
    """w_up (different quantizer shape) must not fuse with the d×d group —
    its per-tensor rank padding stays its own."""
    params, calib = fusion_tree
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    qf, sf = quantize_model_stacked(params, calib, cfg, fuse_stacks=True)
    up = qf["layers"]["w_up"]
    assert (up.m, up.n) == (512, 256)
    rmax_up = max(max(s.rank for s in sf["['layers']['w_up']"]), 1)
    assert up.u.shape[-1] == rmax_up


def test_fusion_no_calib(fusion_tree):
    params, _ = fusion_tree
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=8)
    qf, _ = quantize_model_stacked(params, None, cfg, fuse_stacks=True)
    qu, _ = quantize_model_stacked(params, None, cfg, fuse_stacks=False)
    for (pa, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(qf)[0],
                               jax.tree_util.tree_flatten_with_path(qu)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pa))


# ------------------------------------------- multi-device bitwise parity
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core.flrq import FLRQConfig, quantize_stack
from repro.quant.stacked import quantize_model_stacked

QT_FIELDS = ("packed", "scale", "zp", "u", "v", "act_scale_inv")

def mk_stack(seed, L, m, n):
    base = jax.random.normal(jax.random.PRNGKey(seed), (L, m, n)) * 0.02
    layers = []
    for i in range(L):
        r = 4 + 2 * i
        sv = 2.0 ** -jnp.arange(r)
        u = jax.random.normal(jax.random.PRNGKey(seed + 10 + i), (m, r))
        v = jax.random.normal(jax.random.PRNGKey(seed + 40 + i), (r, n))
        layers.append(base[i] + (u * sv) @ v * 0.5)
    return jnp.stack(layers)

def qt_equal(qa, qb):
    return all(np.array_equal(np.asarray(getattr(qa, f)),
                              np.asarray(getattr(qb, f))) for f in QT_FIELDS)

out = {}
assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("stack",))
cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
x = jax.random.normal(jax.random.PRNGKey(3), (32, 256))

# (1) L divisible by shard count
w8 = mk_stack(0, 8, 128, 256)
qt_ref, st_ref = quantize_stack(w8, x, cfg, jax.random.PRNGKey(0))
qt_sh, st_sh = quantize_stack(w8, x, cfg, jax.random.PRNGKey(0), mesh=mesh)
out["divisible_bitwise"] = qt_equal(qt_ref, qt_sh)
out["divisible_ranks"] = [a.rank for a in st_ref] == [b.rank for b in st_sh]

# (2) L NOT divisible: 6 lanes over 8 shards -> 2 masked padding lanes
w6 = mk_stack(50, 6, 128, 256)
qt_ref6, _ = quantize_stack(w6, x, cfg, jax.random.PRNGKey(1))
qt_sh6, _ = quantize_stack(w6, x, cfg, jax.random.PRNGKey(1), mesh=mesh)
out["padded_bitwise"] = qt_equal(qt_ref6, qt_sh6)

# (3) driver level: fusion + mesh together == plain single-device driver
def model_layout(seed, L, din, dout):
    return jnp.swapaxes(mk_stack(seed, L, dout, din), -1, -2)
params = {"layers": {"wq": model_layout(0, 3, 256, 256),
                     "wk": model_layout(100, 3, 256, 256)}}
calib = {"['layers']['wq']": x, "['layers']['wk']": x}
q_ref, _ = quantize_model_stacked(params, calib, cfg)
q_sh, _ = quantize_model_stacked(params, calib, cfg, mesh=mesh,
                                 fuse_stacks=True)
leaves_ref = jax.tree_util.tree_leaves(q_ref)
leaves_sh = jax.tree_util.tree_leaves(q_sh)
out["driver_bitwise"] = all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(leaves_ref, leaves_sh))

print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_engine_bitwise_parity_8dev():
    """Acceptance: the sharded engine produces bit-identical QTensors to
    the single-device batched engine on a forced 8-device CPU host —
    divisible and padded lane counts, and through the fused driver."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    res = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out == {k: True for k in out}, out
    assert set(out) == {"divisible_bitwise", "divisible_ranks",
                        "padded_bitwise", "driver_bitwise"}
