"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 real CPU
device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def llm_like_matrix():
    """Weight with decaying spectrum + outlier rows (LLM-like structure —
    what FLRQ's rank selection exploits)."""
    k = jax.random.PRNGKey(7)
    m, n = 256, 512
    base = jax.random.normal(k, (m, n)) * 0.02
    sv = 2.0 ** -jnp.arange(12)
    u = jax.random.normal(jax.random.PRNGKey(1), (m, 12))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, n))
    return base + (u * sv) @ v * 0.5


@pytest.fixture(scope="session")
def calib_acts():
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (64, 512))
    outlier = 1 + 5.0 * (jax.random.uniform(jax.random.PRNGKey(4), (512,)) < 0.02)
    return x * outlier
