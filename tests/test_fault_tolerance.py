"""Fault-tolerant serving: deterministic chaos suite.

The oracle for every scenario is the no-fault greedy run: fault injection
plus supervised restart must change WHEN tokens are computed, never WHAT
they are. Each chaos test asserts (a) zero drops — every submitted
request ends in exactly one terminal status from ``ok | timeout |
rejected | failed`` — and (b) every surviving (ok) request's tokens are
bitwise-identical to the fault-free oracle, with no token duplicated on
the resume/replay path. Clocks are virtual, so deadline and straggler
coordinates are exact, not sleep-and-hope; CI re-runs the seeded-random
chaos test under several CHAOS_SEED values.
"""
import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointCorruptionError,
                                           Checkpointer)
from repro.configs import PAPER_PROXIES
from repro.distributed.fault import (HealthMonitor, backoff_delay,
                                     run_with_retries)
from repro.models import LM
from repro.serve import (ContinuousScheduler, Engine, FaultPlan, FaultSpec,
                         Request, ServeConfig, Supervisor, SupervisorConfig,
                         VirtualClock)
from repro.serve.faults import InjectedFault, corrupt_slot_cache

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                head_dim=32, d_ff=128, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


@pytest.fixture(scope="module")
def tiny(key):
    model = LM(_tiny_cfg())
    return model, model.init(key)


def _requests(lens=(3, 9, 5, 14, 7), new=None, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, 128, l).astype(np.int32),
                    max_new_tokens=(new or 4 + i), id=i, **kw)
            for i, l in enumerate(lens)]


@pytest.fixture(scope="module")
def oracle(tiny):
    """Fault-free greedy ground truth (chunked engine, one slot)."""
    model, params = tiny
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
    return {r.id: eng.generate([r])[0].tokens for r in _requests()}


def _supervise(tiny, plan=None, reqs=None, cfg=None, clock=None, **kw):
    model, params = tiny
    sup = Supervisor(
        lambda: Engine(model, params, ServeConfig(max_slots=2, max_seq=32)),
        cfg or SupervisorConfig(replicas=2, step_cost_s=0.01,
                                prefill_chunk=4),
        fault_plan=plan, clock=clock or VirtualClock(), **kw)
    report = sup.serve(reqs if reqs is not None else _requests())
    return sup, report


def _assert_chaos_oracle(report, oracle, expect_status=("ok",)):
    """The acceptance invariant of the whole PR."""
    assert report.zero_drops, (len(report.outcomes), report.submitted)
    counts = report.status_counts()
    assert set(counts) <= {"ok", "timeout", "rejected", "failed"}, counts
    assert set(counts) <= set(expect_status), counts
    for o in report.outcomes:
        if o.status == "ok":
            assert o.tokens == oracle[o.id], \
                f"request {o.id} diverged from fault-free oracle"


# --------------------------------------------------------- chaos scenarios
def test_no_fault_fleet_matches_oracle(tiny, oracle):
    """2 replicas, no faults: the supervisor itself must be invisible."""
    _, report = _supervise(tiny)
    _assert_chaos_oracle(report, oracle)
    assert report.restarts == {0: 0, 1: 0}
    assert report.wasted_tokens == 0


def test_kill_mid_decode_recovers_bitwise(tiny, oracle):
    plan = FaultPlan.parse("exception@4:decode:0")
    sup, report = _supervise(tiny, plan)
    _assert_chaos_oracle(report, oracle)
    assert report.restarts[0] == 1 and report.failures
    # in-flight work was lost and re-prefilled: wasted tokens recorded
    assert report.wasted_tokens > 0
    assert 0 < report.wasted_token_fraction < 1
    assert any(o.replays > 0 for o in report.outcomes)


def test_kill_mid_prefill_recovers_bitwise(tiny, oracle):
    """The 14-token prompt is mid-prefill (chunked) when replica 0 dies
    inside the engine's prefill hook point."""
    plan = FaultPlan.parse("exception@1:prefill:0")
    _, report = _supervise(tiny, plan)
    _assert_chaos_oracle(report, oracle)
    assert report.restarts[0] == 1


def test_kill_at_retirement_boundary_keeps_retired_result(tiny):
    """A retires DURING the step that kills the replica (prefill phase
    finishes A; the decode-site fault fires later in the same step, while
    B decodes). A's already-retired result must survive the salvage —
    the classic lost-on-restart drop."""
    model, params = tiny
    reqs = [Request(np.arange(2, 7, dtype=np.int32), max_new_tokens=1, id=0),
            Request(np.arange(3, 6, dtype=np.int32), max_new_tokens=6, id=1)]
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
    orc = {r.id: eng.generate([r])[0].tokens for r in reqs}
    plan = FaultPlan.parse("exception@1:decode:0")
    _, report = _supervise(
        tiny, plan, reqs=reqs,
        cfg=SupervisorConfig(replicas=1, step_cost_s=0.01,
                             prefill_chunk=4))
    _assert_chaos_oracle(report, orc)
    a = next(o for o in report.outcomes if o.id == 0)
    assert a.status == "ok" and a.replays == 0  # retired, never replayed


def test_cache_corruption_detected_before_sampling(tiny, oracle):
    """NaN-poisoned slot cache must surface as CacheCorruptionError (a
    replica failure) — never as garbage tokens in the stream."""
    plan = FaultPlan.parse("corrupt_cache@2:step:0:0")
    sup, report = _supervise(tiny, plan)
    _assert_chaos_oracle(report, oracle)
    assert any("CacheCorruptionError" in exc for _, exc in report.failures)


def test_straggler_detected_and_restarted(tiny, oracle):
    """An injected 5s stall (virtual clock) on replica 0 trips the
    HealthMonitor's quantile detector; restart_stragglers routes it
    through the same salvage path as a crash — parity must survive."""
    plan = FaultPlan.parse("straggler@3:step:0:5.0")
    cfg = SupervisorConfig(replicas=2, step_cost_s=0.01, prefill_chunk=4,
                           straggler_factor=4.0, restart_stragglers=True)
    sup, report = _supervise(tiny, plan, cfg=cfg)
    _assert_chaos_oracle(report, oracle)
    assert report.straggler_events >= 1
    assert report.restarts[0] >= 1


def test_exhausted_restarts_fail_terminally(tiny, oracle):
    """max_restarts=0: the first kill retires the only replica; every
    unfinished request must end with a terminal ``failed`` status —
    visibly, not as a hang or a silent drop."""
    plan = FaultPlan.parse("exception@2:decode:0")
    cfg = SupervisorConfig(replicas=1, step_cost_s=0.01, prefill_chunk=4,
                           max_restarts=0)
    _, report = _supervise(tiny, plan, cfg=cfg)
    _assert_chaos_oracle(report, oracle, expect_status=("ok", "failed"))
    assert report.status_counts()["failed"] >= 1


def test_poison_pill_request_replay_cap(tiny, oracle):
    """Repeated kills push some requests past max_request_replays=1:
    those end ``failed`` (with their replay count recorded); the fleet
    keeps serving the rest."""
    plan = FaultPlan.parse("exception@2:decode:0,exception@6:decode:0")
    cfg = SupervisorConfig(replicas=1, step_cost_s=0.01, prefill_chunk=4,
                           max_request_replays=1, backoff_base_s=0.01)
    _, report = _supervise(tiny, plan, cfg=cfg)
    _assert_chaos_oracle(report, oracle, expect_status=("ok", "failed"))
    for o in report.outcomes:
        if o.status == "failed":
            assert o.replays > 1


def test_exactly_once_streaming_across_kill(tiny, oracle):
    """Replayed tokens ride in the resume prompt, so the user-visible
    stream must contain each token exactly once even though the request
    ran twice."""
    streams = {}
    plan = FaultPlan.parse("exception@4:decode:0")
    _, report = _supervise(
        tiny, plan,
        on_token=lambda rid, tok, done: streams.setdefault(rid, []).append(tok))
    _assert_chaos_oracle(report, oracle)
    for o in report.outcomes:
        assert streams[o.id] == o.tokens == oracle[o.id]


def test_seeded_random_chaos_reconciles(tiny, oracle):
    """Seeded random fault mode (CI varies CHAOS_SEED): whatever fires,
    zero drops, glossary statuses only, survivors bitwise — and the whole
    run replays identically under the same seed."""
    def run():
        plan = FaultPlan([], seed=CHAOS_SEED, rate=0.05, n_random=2)
        return _supervise(tiny, plan)[1]
    a, b = run(), run()
    _assert_chaos_oracle(a, oracle, expect_status=("ok", "failed"))
    assert [(o.id, o.status, o.tokens) for o in a.outcomes] == \
        [(o.id, o.status, o.tokens) for o in b.outcomes]


def test_kill_during_checkpoint_write(tiny, oracle, tmp_path):
    """A checkpoint-site fault fires between shard write and COMMIT in
    the background writer: the failure is counted (never swallowed), the
    partial checkpoint stays invisible, the prior complete one survives,
    and serving is unaffected."""
    ck = Checkpointer(tmp_path, keep=2)
    plan = FaultPlan([FaultSpec("exception", step=1, site="checkpoint",
                                replica=-1)])
    cfg = SupervisorConfig(replicas=2, step_cost_s=0.01, prefill_chunk=4, ckpt_every=3)
    sup, report = _supervise(tiny, plan, cfg=cfg, checkpointer=ck)
    _assert_chaos_oracle(report, oracle)
    assert sup.ckpt_failures >= 1
    # the killed save (tick 3, the plan's 2nd write) never committed;
    # the latest surviving checkpoint restores, checksum-verified
    model, params = tiny
    restored, step = ck.restore(params)
    assert step != 3
    np.testing.assert_array_equal(
        np.asarray(restored["embed"]), np.asarray(params["embed"]))


def test_restart_reloads_params_from_checkpoint(tiny, oracle, tmp_path):
    """With a checkpointer wired, a rebuilt replica reloads its weights
    through the checksum-verified restore path — and still matches the
    oracle bitwise (same params in, same tokens out)."""
    ck = Checkpointer(tmp_path, keep=2)
    plan = FaultPlan.parse("exception@4:decode:0")
    sup, report = _supervise(tiny, plan, checkpointer=ck)
    _assert_chaos_oracle(report, oracle)
    assert report.restarts[0] == 1


# ------------------------------------------- deadlines and backpressure
def _sched(tiny, clock, slots=1, chunk=4, **kw):
    model, params = tiny
    eng = Engine(model, params, ServeConfig(max_slots=slots, max_seq=32))
    return ContinuousScheduler(eng, prefill_chunk=chunk, clock=clock, **kw)


def test_deadline_exact_chunk_boundary(tiny):
    """now == arrival + deadline is NOT expired (strict >): a request
    whose deadline lands exactly on a chunk boundary still runs that
    chunk; one tick later it times out, mid-prefill, with no tokens."""
    clock = VirtualClock()
    sched = _sched(tiny, clock)
    sched.start([Request(np.arange(2, 10, dtype=np.int32),  # 2 chunks
                         max_new_tokens=4, id=0, deadline_s=0.5)])
    assert sched.step()              # chunk 1 prefilled
    clock.advance(0.5)               # exactly at the deadline
    assert sched.step()              # boundary: still alive, chunk 2 runs
    clock.advance(1e-3)
    sched.step()                     # now past: deadline sweep fires
    [res] = [r for r in sched.results if r.status == "timeout"] or \
        sched.results
    assert res.status == "timeout" or res.status == "ok"
    # with the tiny prompt the 2nd chunk finished prefill and emitted a
    # token before expiry — both ends are legal; what is NOT legal is a
    # request still in flight after its deadline:
    for s in sched.inflight():
        assert False, f"request past deadline still in flight: {s}"


def test_deadline_mid_decode_keeps_partial_tokens(tiny, oracle):
    clock = VirtualClock()
    sched = _sched(tiny, clock)
    reqs = _requests()
    sched.start([dataclasses.replace(reqs[1], deadline_s=1.0)])  # 8 tokens
    assert sched.step()              # prefill chunk 1
    assert sched.step()              # prefill chunk 2 + first token
    assert sched.step()              # decode token 2
    clock.advance(2.0)
    sched.step()                     # expired mid-decode
    [res] = sched.results
    assert res.status == "timeout"
    assert 0 < len(res.tokens) < 8
    assert res.tokens == oracle[1][:len(res.tokens)]  # partials are real


def test_deadline_expires_while_queued(tiny):
    """A queued request whose deadline passes before a slot frees times
    out AT admission — it never occupies a slot."""
    clock = VirtualClock()
    sched = _sched(tiny, clock)
    a = Request(np.arange(2, 5, dtype=np.int32), max_new_tokens=8, id=0)
    b = Request(np.arange(2, 5, dtype=np.int32), max_new_tokens=2, id=1,
                deadline_s=0.5)
    sched.start([a, b])
    sched.step()                     # a admitted (1 slot), b queued
    clock.advance(1.0)
    sched.step()
    res = {r.id: r for r in sched.results}
    assert res[1].status == "timeout" and res[1].tokens == []
    assert 1 not in sched.admission_order


def test_queue_cap_sheds_with_rejected_status(tiny):
    clock = VirtualClock()
    sched = _sched(tiny, clock, queue_cap=1)
    sched.start()
    reqs = _requests(lens=(3, 3, 3, 3), new=2)
    assert sched.submit(reqs[0])     # -> slot at next step
    sched.step()
    assert sched.submit(reqs[1])     # queued (cap 1)
    assert not sched.submit(reqs[2])  # shed
    assert not sched.submit(reqs[3])  # shed
    while not sched.done:
        sched.step()
    counts = sched.status_counts()
    assert counts == {"ok": 2, "rejected": 2}


def test_stop_drain_finishes_inflight(tiny, oracle):
    clock = VirtualClock()
    sched = _sched(tiny, clock, slots=2)
    reqs = _requests()
    sched.start(reqs)
    sched.step()
    sched.stop(drain=True)           # queued -> rejected; in-flight finish
    while not sched.done:
        sched.step()
    counts = sched.status_counts()
    assert counts["rejected"] == 3 and counts["ok"] == 2
    for r in sched.results:
        if r.status == "ok":
            assert r.tokens == oracle[r.id]


def test_stop_kill_abandons_inflight_visibly(tiny):
    clock = VirtualClock()
    sched = _sched(tiny, clock, slots=2)
    sched.start(_requests())
    sched.step()
    sched.stop(drain=False)
    sched.step()
    assert sched.done
    counts = sched.status_counts()
    assert counts["failed"] == 2 and counts["rejected"] == 3


def test_supervisor_deadline_and_queue_cap(tiny, oracle):
    """Fleet-level admission control: per-request deadlines time out
    mid-decode with real partial tokens; the bounded shared queue sheds
    the overflow with rejected outcomes."""
    reqs = _requests()
    # req 1 is dispatched immediately (2 slots) and expires mid-decode;
    # the cap-3 shared queue sheds the later arrivals
    reqs[1] = dataclasses.replace(reqs[1], deadline_s=0.07)
    cfg = SupervisorConfig(replicas=1, step_cost_s=0.02, prefill_chunk=4,
                           queue_cap=3)
    _, report = _supervise(tiny, reqs=reqs, cfg=cfg)
    counts = report.status_counts()
    assert report.zero_drops
    assert counts["rejected"] >= 1           # shed by the bounded queue
    timed = [o for o in report.outcomes if o.status == "timeout"]
    assert timed                             # the tight deadline fired
    for o in timed:
        assert o.tokens == oracle[o.id][:len(o.tokens)]
    for o in report.outcomes:
        if o.status == "ok":
            assert o.tokens == oracle[o.id]


# ------------------------------------------------------- fault primitives
def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse(
        "exception@3:decode:1,straggler@5:step:0:2.5,"
        "corrupt_cache@7:step:0:3,random@42:0.1:4")
    assert plan.faults[0] == FaultSpec("exception", 3, "decode", 1)
    assert plan.faults[1].delay_s == 2.5
    assert plan.faults[2].slot == 3
    assert (plan.seed, plan.rate, plan.n_random) == (42, 0.1, 4)
    with pytest.raises(ValueError):
        FaultPlan.parse("meteor@3")
    with pytest.raises(ValueError):
        FaultPlan.parse("exception@3:nowhere")
    with pytest.raises(ValueError):
        FaultPlan.parse("exception")


def test_injector_one_shot_and_monotonic_steps():
    """A spec fires exactly once, and the step counter is replica-lifetime
    monotonic — a restarted replica cannot re-trip the same coordinate."""
    plan = FaultPlan([FaultSpec("exception", step=2, site="step")])
    inj = plan.injector(0, VirtualClock())
    for _ in range(2):
        inj.begin_step()
        inj.check("step")
    inj.begin_step()
    with pytest.raises(InjectedFault):
        inj.check("step")
    inj.begin_step()                 # "restart": counter keeps counting
    assert inj.check("step") is None
    assert len(inj.fired) == 1


def test_corrupt_slot_cache_targets_slot_axis():
    cache = {"k": jnp.ones((2, 3, 4, 2, 8)), "codes": jnp.ones(
        (2, 3, 4), jnp.int8)}
    out = corrupt_slot_cache(cache, 1)
    k = np.asarray(out["k"])
    assert np.isnan(k[:, 1]).all()
    assert np.isfinite(k[:, 0]).all() and np.isfinite(k[:, 2]).all()
    assert np.asarray(out["codes"]).sum() == 2 * 3 * 4  # ints untouched


def test_virtual_clock_only_advances_when_told():
    clock = VirtualClock()
    t = clock.now()
    clock.sleep(0.5)
    clock.advance(0.25)
    assert clock.now() == t + 0.75


# ------------------------------------------------- satellites: fault.py
def test_run_with_retries_custom_retryable_and_backoff():
    sleeps = []
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise ValueError("transient")
        return "done"

    attempts, out = run_with_retries(
        flaky, max_restarts=3, retryable=(ValueError,),
        backoff_base_s=0.1, backoff_factor=2.0, backoff_jitter=0.0,
        sleep=sleeps.append)
    assert (attempts, out) == (2, "done")
    assert sleeps == [0.1, 0.2]      # exponential, deterministic

    with pytest.raises(KeyError):    # not retryable -> propagates raw
        run_with_retries(lambda a: (_ for _ in ()).throw(KeyError("x")),
                         retryable=(ValueError,))


def test_backoff_delay_jitter_is_seeded():
    a = [backoff_delay(i, 0.1, 2.0, 0.25,
                       np.random.default_rng(7)) for i in range(4)]
    b = [backoff_delay(i, 0.1, 2.0, 0.25,
                       np.random.default_rng(7)) for i in range(4)]
    assert a == b                    # same seed -> same jitter
    for i, d in enumerate(a):
        base = 0.1 * 2.0 ** i
        assert base * 0.75 <= d <= base * 1.25
    assert backoff_delay(3, 0.1) == pytest.approx(0.8)  # no rng: no jitter


def test_survivor_mesh_model_axis_parameterized():
    mon = HealthMonitor(n_hosts=32, model_axis=8)
    for h in range(32):
        mon.heartbeat(h, now=0.0)
    assert mon.survivor_mesh([]) == (64, 8)
    assert mon.survivor_mesh(list(range(16))) == (32, 8)
    assert HealthMonitor(n_hosts=32).survivor_mesh([]) == (32, 16)


# --------------------------------------------- satellites: checkpointer
def _ckpt_tree():
    return {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones((8,), np.float32)}


def test_checkpointer_rejects_corrupt_shard(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(0, _ckpt_tree(), blocking=True)
    shard = tmp_path / "step_000000000" / "shard_00000.npz"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF       # flip one byte mid-file
    shard.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptionError, match="sha256"):
        ck.restore(_ckpt_tree())


def test_checkpointer_rejects_truncated_shard(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(0, _ckpt_tree(), blocking=True)
    shard = tmp_path / "step_000000000" / "shard_00000.npz"
    shard.write_bytes(shard.read_bytes()[:-16])
    with pytest.raises(CheckpointCorruptionError, match="truncated"):
        ck.restore(_ckpt_tree())
    shard.unlink()
    with pytest.raises(CheckpointCorruptionError, match="missing"):
        ck.restore(_ckpt_tree())


def test_checkpointer_background_error_reraised(tmp_path):
    """A failed background write is captured and re-raised at the next
    wait()/save() — never swallowed — and leaves no COMMIT behind."""
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(0, _ckpt_tree(), blocking=True)

    def die(site):
        raise OSError("disk full")

    ck.fault_hook = die
    ck.save(1, _ckpt_tree(), blocking=False)
    with pytest.raises(OSError, match="disk full"):
        ck.wait()
    ck.fault_hook = None
    assert ck.latest_step() == 0     # partial save invisible (no COMMIT)
    ck.save(2, _ckpt_tree(), blocking=True)   # error was cleared: works
    restored, step = ck.restore(_ckpt_tree())
    assert step == 2
    np.testing.assert_array_equal(restored["w"], _ckpt_tree()["w"])


def test_checkpointer_blocking_save_raises_inline(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)

    def die(site):
        raise OSError("disk full")

    ck.fault_hook = die
    with pytest.raises(OSError, match="disk full"):
        ck.save(0, _ckpt_tree(), blocking=True)
    assert ck.latest_step() is None
