"""Continuous-batching scheduler: parity vs the chunked oracle (mixed
lengths, quantized + fp, scan + no-scan), chunked-prefill boundary cases,
slot retirement/admission ordering, length-bucketed compile counts,
streaming callbacks, metrics, and cache-donation discipline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig
from repro.serve.scheduler import ContinuousScheduler, bucket_sizes


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                head_dim=32, d_ff=128, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


def _mixed_requests(lens=(3, 9, 5, 14, 7), vocab=128, new=None, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, vocab, l).astype(np.int32),
                    max_new_tokens=(new or 4 + i), id=i)
            for i, l in enumerate(lens)]


def _oracle(model, params, reqs, max_seq=32):
    """Per-request ground truth: the chunked engine with one request per
    chunk (max_slots=1 — no left-padding, exact lengths)."""
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=max_seq))
    return {r.id: eng.generate([r])[0].tokens for r in reqs}


def _sched_tokens(model, params, reqs, max_seq=32, slots=3, chunk=4,
                  arrivals=None, **scfg):
    eng = Engine(model, params, ServeConfig(max_slots=slots,
                                            max_seq=max_seq, **scfg))
    sched = ContinuousScheduler(eng, prefill_chunk=chunk)
    res = sched.run(reqs, arrivals)
    return {r.id: r.tokens for r in res}, sched, eng


@pytest.fixture(scope="module")
def tiny_fp(key):
    model = LM(_tiny_cfg())
    return model, model.init(key)


@pytest.fixture(scope="module")
def tiny_quant(tiny_fp):
    model, params = tiny_fp
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=4))
    return model, qparams


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "no-scan"])
@pytest.mark.parametrize("quantized", [False, True], ids=["fp", "quant"])
def test_scheduler_matches_chunked_oracle(tiny_fp, tiny_quant, scan,
                                          quantized):
    """Acceptance: on a mixed-length workload the scheduler produces
    bitwise-identical per-request tokens vs the chunked oracle under
    greedy sampling — scheduling changes WHEN tokens are computed, never
    WHAT they are."""
    model, params = tiny_quant if quantized else tiny_fp
    if not scan:
        model = model.with_scan(False)
    reqs = _mixed_requests()
    oracle = _oracle(model, params, reqs)
    got, _, _ = _sched_tokens(model, params, reqs)
    assert got == oracle


def test_scheduler_matches_batched_chunk_on_equal_lengths(tiny_quant):
    """With equal prompt lengths the slot-chunked engine has no padding —
    the scheduler must match it at full batch too."""
    model, qparams = tiny_quant
    reqs = _mixed_requests(lens=(7, 7, 7, 7), new=6)
    eng = Engine(model, qparams, ServeConfig(max_slots=2, max_seq=32))
    oracle = {r.id: r.tokens for r in eng.generate(reqs)}
    got, _, _ = _sched_tokens(model, qparams, reqs, slots=2)
    assert got == oracle


def test_scheduler_parity_kv8_cache(key):
    """int8 KV cache: chunked prefill quantizes per (token, head) exactly
    like the decode step (and the chunked engine's kv8 path — previously a
    tree_map crash — now quantizes its prefill cache the same way)."""
    model = LM(_tiny_cfg(kv_cache_bits=8))
    params = model.init(key)
    reqs = _mixed_requests(lens=(3, 9, 6))
    oracle = _oracle(model, params, reqs)
    got, _, _ = _sched_tokens(model, params, reqs)
    assert got == oracle


def test_scheduler_parity_under_arrivals(tiny_fp):
    """Arrival timing (and therefore admission interleaving) must not
    change any request's tokens."""
    model, params = tiny_fp
    reqs = _mixed_requests()
    oracle = _oracle(model, params, reqs)
    got, _, _ = _sched_tokens(model, params, reqs,
                              arrivals=[0.0, 0.02, 0.02, 0.0, 0.05])
    assert got == oracle


def test_vector_lengths_match_scalar_decode(tiny_fp):
    """Model-level invariant under the scheduler's (B,) lengths vector:
    equal per-slot lengths must reproduce the scalar-length decode
    bitwise, and each slot's output must depend only on ITS OWN length."""
    model, params = tiny_fp
    b, plen = 2, 8
    prompts = jnp.asarray(
        np.arange(b * plen, dtype=np.int32).reshape(b, plen) % 100 + 2)
    logits, cache = model.prefill(params, prompts)
    full = model.init_cache(b, 16)
    cache = jax.tree.map(
        lambda d, s: jnp.pad(s.astype(d.dtype),
                             [(0, x - y) for x, y in zip(d.shape, s.shape)]),
        full, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    l_scalar, c_scalar = model.decode_step(params, tok, cache, jnp.int32(plen))
    l_vec, c_vec = model.decode_step(
        params, tok, cache, jnp.full((b,), plen, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_scalar), np.asarray(l_vec))
    for a, c in zip(jax.tree.leaves(c_scalar), jax.tree.leaves(c_vec)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))
    # slot isolation: perturbing slot 1's length must not move slot 0
    l_mixed, _ = model.decode_step(
        params, tok, cache, jnp.asarray([plen, plen - 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_mixed[0]),
                                  np.asarray(l_vec[0]))


# ------------------------------------------------- prefill chunk boundaries
@pytest.mark.parametrize("plen", [1, 3, 4, 5, 8, 11])
def test_prefill_chunk_boundary_lengths(tiny_fp, plen):
    """Prompt length below / at / above the chunk and off the chunk grid:
    same tokens as the unchunked oracle (chunk=4 -> lengths 1..11 cover
    partial-final, exact-multiple and multi-chunk cases)."""
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(plen,), new=5)
    oracle = _oracle(model, params, reqs)
    got, _, _ = _sched_tokens(model, params, reqs, chunk=4)
    assert got == oracle


def test_prefill_final_chunk_overlap_near_max_seq(tiny_fp):
    """The padded final chunk would write past max_seq — the scheduler
    left-overlaps the last bucket of REAL prompt tokens instead
    (recomputing position-local K/V bitwise) and still matches."""
    model, params = tiny_fp
    rng = np.random.default_rng(3)
    reqs = [Request(rng.integers(2, 128, 19).astype(np.int32),
                    max_new_tokens=1, id=0)]
    oracle = _oracle(model, params, reqs, max_seq=20)
    # chunk=8: final chunk c=3 buckets to 8; start 16+8 > max_seq=20
    got, _, _ = _sched_tokens(model, params, reqs, max_seq=20, chunk=8)
    assert got == oracle


def test_prefill_smaller_bucket_when_overlap_impossible(tiny_fp):
    """Prompt shorter than its covering bucket on a cache too small for
    the pad: the scheduler advances by the largest smaller bucket
    UNPADDED (tail next step, overlap then reachable) — still bucketed,
    still matching the oracle."""
    model, params = tiny_fp
    rng = np.random.default_rng(5)
    # buckets (8, 16): plen=11 -> bucket(11)=16 > max_seq=12 and 0+11 < 16
    # -> first chunk is an unpadded 8, then overlap start=3 for the tail
    reqs = [Request(rng.integers(2, 128, 11).astype(np.int32),
                    max_new_tokens=1, id=0)]
    oracle = _oracle(model, params, reqs, max_seq=12)
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=12))
    sched = ContinuousScheduler(eng, prefill_chunk=16)
    got = {r.id: r.tokens for r in sched.run(reqs)}
    assert got == oracle
    assert eng.prefill_slot_traces <= len(sched.buckets)


def test_prompt_too_long_rejected_cleanly(tiny_fp):
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=16))
    sched = ContinuousScheduler(eng, prefill_chunk=4)
    bad = Request(np.arange(14, dtype=np.int32) + 2, max_new_tokens=8, id=9)
    with pytest.raises(ValueError, match="exceeds max_seq=16"):
        sched.run([bad])
    # rejection happens before ANY slot state exists — no partial serve
    assert sched.trace == [] and sched.admission_order == []
    with pytest.raises(ValueError, match="max_new_tokens=0"):
        sched.run([Request(np.arange(4, dtype=np.int32) + 2,
                           max_new_tokens=0, id=1)])


# ------------------------------------------------ admission and retirement
def test_admission_fifo_and_slot_reuse(tiny_fp):
    """More requests than slots: admission follows arrival order FIFO, a
    retired slot is re-admitted while other slots are still serving, and
    concurrency never exceeds max_slots."""
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(3, 12, 4, 5, 6, 3), new=None)
    oracle = _oracle(model, params, reqs)
    got, sched, _ = _sched_tokens(model, params, reqs, slots=2, chunk=4)
    assert got == oracle
    assert sched.admission_order == [r.id for r in reqs]
    for t in sched.trace:
        assert t.prefilling + t.decoding <= 2
    # with 6 requests on 2 slots, some step must have run with a non-empty
    # queue while both slots were busy (continuous refill, not chunk drain)
    assert any(t.queued > 0 and t.prefilling + t.decoding == 2
               for t in sched.trace)


def test_retirement_frees_slot_immediately(tiny_fp):
    """A request hitting max_new_tokens=1 retires at its prefill step; the
    queued request must be admitted at the very next step."""
    model, params = tiny_fp
    rng = np.random.default_rng(1)
    reqs = [Request(rng.integers(2, 128, 4).astype(np.int32),
                    max_new_tokens=1, id=0),
            Request(rng.integers(2, 128, 4).astype(np.int32),
                    max_new_tokens=3, id=1)]
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
    sched = ContinuousScheduler(eng, prefill_chunk=4)
    res = sched.run(reqs)
    assert [r.id for r in res] == [0, 1]
    assert len(res[0].tokens) == 1 and len(res[1].tokens) == 3
    assert res[0].tok_s == 0.0  # no decode interval — not inf


# ------------------------------------------------------ compile bounding
def test_length_bucketing_bounds_compiles(tiny_fp):
    """Many distinct prompt lengths, bounded executables: prefill traces
    <= |bucket set|, decode traces == 1 (the (B,) lengths vector keeps one
    decode executable for the serve's whole lifetime)."""
    model, params = tiny_fp
    lens = (1, 2, 3, 5, 7, 9, 11, 13, 17, 19, 21, 23)
    reqs = _mixed_requests(lens=lens, new=2)
    eng = Engine(model, params, ServeConfig(max_slots=3, max_seq=40))
    sched = ContinuousScheduler(eng, prefill_chunk=16)
    assert sched.buckets == (8, 16)
    sched.run(reqs)
    assert eng.prefill_slot_traces <= len(sched.buckets)
    assert eng.decode_traces == 1


def test_bucket_sizes():
    assert bucket_sizes(32) == (8, 16, 32)
    assert bucket_sizes(16) == (8, 16)
    assert bucket_sizes(8) == (8,)
    assert bucket_sizes(4) == (4,)
    assert bucket_sizes(12) == (8, 12)
    with pytest.raises(ValueError):
        bucket_sizes(0)


# ----------------------------------------------------------- cache donation
def test_donate_cache_resolution():
    cfg = ServeConfig()
    assert cfg.resolve_donate() == (jax.default_backend() != "cpu")
    assert ServeConfig(donate_cache=True).resolve_donate() is True
    assert ServeConfig(donate_cache=False).resolve_donate() is False


def test_donated_cache_never_reused(tiny_fp):
    """Donation discipline: with donate_cache=True every cache-threading
    call consumes its cache input exactly once — the scheduler must never
    hand a consumed cache back (e.g. a stale reference kept across a
    mid-step slot retirement). JAX invalidates donated buffers even on
    CPU, so both the id-tracking assertion and the run itself (a stale
    reuse raises 'deleted buffer') are exercised here."""
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(3, 9, 4, 5), new=None)
    oracle = _oracle(model, params, reqs)
    eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32,
                                            donate_cache=True))
    assert eng._donate
    consumed = []
    orig_prefill, orig_decode = eng._prefill_slot_impl, eng._decode_slots_impl

    def track(cache):
        leaf = jax.tree.leaves(cache)[0]
        assert not any(leaf is c for c in consumed), \
            "scheduler passed an already-donated cache"
        consumed.append(leaf)

    def prefill(cache, slot, toks, start, last):
        track(cache)
        return orig_prefill(cache, slot, toks, start, last)

    def decode(cache, toks, lens):
        track(cache)
        return orig_decode(cache, toks, lens)

    # instance-level overrides under the historical names — the dense
    # backend's _legacy() lookup routes through these when present
    eng.prefill_slot_chunk, eng.decode_slots = prefill, decode
    sched = ContinuousScheduler(eng, prefill_chunk=4)
    res = sched.run(reqs)  # any stale reuse would also raise RuntimeError
    assert {r.id: r.tokens for r in res} == oracle
    assert len(consumed) > 4  # the cache really threaded through many calls


# ------------------------------------------------- streaming and metrics
def test_streaming_callbacks_and_metrics(tiny_fp):
    model, params = tiny_fp
    reqs = _mixed_requests(lens=(3, 9, 5), new=None)
    streamed = {}
    done_flags = {}

    def on_token(rid, tok, done):
        streamed.setdefault(rid, []).append(tok)
        done_flags[rid] = done

    drains = []
    eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32))
    sched = ContinuousScheduler(eng, prefill_chunk=4, on_token=on_token,
                                on_drain=lambda: drains.append(1))
    res = sched.run(reqs)
    for r in res:
        assert streamed[r.id] == r.tokens  # streamed == returned, in order
        assert done_flags[r.id] is True
        assert len(r.token_times) == len(r.tokens)
        assert 0.0 <= r.queue_s <= r.ttft_s <= r.finish_s + 1e-9
        assert all(b >= a for a, b in
                   zip(r.token_times, r.token_times[1:]))
        if len(r.tokens) > 1:
            assert r.decode_s >= 0 and r.tok_s > 0
            assert len(r.itl_s) == len(r.tokens) - 1
    assert drains == [1]  # one drain event for one contiguous burst
    assert 0.0 < sched.utilization() <= 1.0


def test_chunked_engine_per_request_queue_and_ttft(tiny_fp):
    """Satellite regression: the chunked engine reports true per-request
    queue/prefill/TTFT — the second chunk's requests carry the first
    chunk's full drain in queue_s, and an early-EOS/max_new request's
    decode_s stops at ITS last token instead of the chunk drain."""
    model, params = tiny_fp
    rng = np.random.default_rng(2)
    reqs = [Request(rng.integers(2, 128, 5).astype(np.int32),
                    max_new_tokens=n, id=i)
            for i, n in enumerate((8, 2))]
    eng = Engine(model, params, ServeConfig(max_slots=1, max_seq=32))
    r0, r1 = eng.generate(reqs)
    assert r0.queue_s < r1.queue_s  # chunk 2 waited for chunk 1's drain
    assert r1.queue_s >= r0.prefill_s + r0.decode_s
    for r in (r0, r1):
        assert abs(r.ttft_s - (r.queue_s + r.prefill_s)) < 1e-9
    # r1 generated 2 tokens in an 8-step-capable chunk of its own: its
    # decode_s covers exactly its own steps (strictly less than r0's)
    assert r1.decode_s <= r0.decode_s
    # same-chunk requests share one batched prefill wall time
    eng2 = Engine(model, params, ServeConfig(max_slots=2, max_seq=32))
    b0, b1 = eng2.generate([reqs[0], dataclasses.replace(reqs[1], id=9)])
    assert b0.prefill_s == b1.prefill_s and b0.queue_s == b1.queue_s
    assert b1.decode_s <= b0.decode_s  # early stop at its own last token
