"""Per-arch smoke tests (reduced same-family configs, one fwd/train step on
CPU, output shapes + no NaNs) and prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, PAPER_PROXIES, get_smoke_config
from repro.models import LM
from repro.models.layers import rms_norm, softcap

B, S = 2, 32


def _batch(cfg, key):
    if cfg.family == "encoder":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "mask": jnp.ones((B, S), bool),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch, key):
    """One forward + one gradient step per assigned architecture."""
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0, arch
    # sgd step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2 = model.loss(params2, batch)
    assert jnp.isfinite(loss2)
    assert float(loss2) != float(loss)


def _full_logits(model, params, tokens):
    cfg = model.cfg
    x = model._embed_tokens(params, tokens)
    h = model.stack.apply_train(params["layers"], x,
                                model._positions(*tokens.shape))
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        model._unembed(params).astype(jnp.float32))
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if ARCHS[a].family != "encoder"])
def test_prefill_decode_matches_forward(arch, key):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_impl="ragged")  # exact dispatch
    model = LM(cfg)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ref = _full_logits(model, params, tokens)
    pre, cache = model.prefill(params, tokens[:, :S - 1])
    np.testing.assert_allclose(np.asarray(pre[:, 0]), np.asarray(ref[:, S - 2]),
                               rtol=1e-3, atol=2e-2)
    full = model.init_cache(B, S)
    cache = jax.tree.map(
        lambda f, g: jax.lax.dynamic_update_slice(
            f, g.astype(f.dtype), (0,) * f.ndim) if f.shape != g.shape else g,
        full, cache)
    dec, _ = model.decode_step(params, tokens[:, S - 1], cache,
                               jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(ref[:, S - 1]),
                               rtol=1e-3, atol=2e-2)


def test_moe_capacity_approximates_ragged(key):
    """With generous capacity, GShard dispatch ≈ exact dropless dispatch."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    cfg_r = dataclasses.replace(cfg, moe_impl="ragged")
    cfg_c = dataclasses.replace(cfg, moe_impl="capacity", capacity_factor=4.0)
    m_r, m_c = LM(cfg_r), LM(cfg_c)
    params = m_r.init(key)
    batch = _batch(cfg, key)
    l_r = float(m_r.loss(params, batch))
    l_c = float(m_c.loss(params, batch))
    assert abs(l_r - l_c) / l_r < 0.05


def test_gemma2_softcap_and_local_window(key):
    cfg = get_smoke_config("gemma2-9b")
    assert cfg.attn_softcap and cfg.local_window
    model = LM(cfg)
    params = model.init(key)
    loss = model.loss(params, _batch(cfg, key))
    assert jnp.isfinite(loss)


def test_rwkv6_state_decode_is_o1(key):
    """rwkv6 cache size is independent of sequence length."""
    cfg = get_smoke_config("rwkv6-1.6b")
    model = LM(cfg)
    c1 = jax.eval_shape(lambda: model.init_cache(2, 128))
    c2 = jax.eval_shape(lambda: model.init_cache(2, 4096))
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
    assert s1 == s2


def test_vlm_mrope_positions(key):
    from repro.models.model import mrope_positions_for_image
    pos = mrope_positions_for_image(2, 1, 4, 6)
    assert pos.shape == (2, 3, 24)
    assert int(pos[0, 1].max()) == 3 and int(pos[0, 2].max()) == 5


def test_paper_proxy_losses(key):
    for name, cfg in PAPER_PROXIES.items():
        model = LM(cfg)
        params = model.init(key)
        loss = model.loss(params, _batch(cfg, key))
        assert jnp.isfinite(loss), name


# ------------------------------------------------------------- perf levers
def test_grouped_decode_attn_matches_baseline(key):
    """Beyond-paper grouped GQA decode is numerically identical."""
    cfg = get_smoke_config("qwen3-4b")
    m0, m1 = LM(cfg), LM(dataclasses.replace(cfg, grouped_decode_attn=True))
    params = m0.init(key)
    tokens = jax.random.randint(key, (B, 16), 0, cfg.vocab)
    _, cache = m0.prefill(params, tokens[:, :15])
    full = m0.init_cache(B, 16)
    cache = jax.tree.map(
        lambda f, g: jax.lax.dynamic_update_slice(
            f, g.astype(f.dtype), (0,) * f.ndim) if f.shape != g.shape else g,
        full, cache)
    l0, _ = m0.decode_step(params, tokens[:, 15], cache, jnp.int32(15))
    l1, _ = m1.decode_step(params, tokens[:, 15], cache, jnp.int32(15))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_decode_close(key):
    """int8 KV cache decode stays within ~2% of the bf16-cache logits."""
    cfg = get_smoke_config("qwen3-4b")
    m8 = LM(dataclasses.replace(cfg, kv_cache_bits=8,
                                grouped_decode_attn=True))
    m0 = LM(cfg)
    params = m0.init(key)
    tokens = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    c0 = m0.init_cache(B, 8)
    c8 = m8.init_cache(B, 8)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    l0, c0 = m0.decode_step(params, tokens[:, 0], c0, jnp.int32(0))
    l8, c8 = m8.decode_step(params, tokens[:, 0], c8, jnp.int32(0))
    for i in range(1, 5):
        l0, c0 = m0.decode_step(params, tokens[:, i], c0, jnp.int32(i))
        l8, c8 = m8.decode_step(params, tokens[:, i], c8, jnp.int32(i))
    rel = float(jnp.linalg.norm(l0 - l8) / jnp.linalg.norm(l0))
    assert rel < 0.05, rel


def test_moe_grouped_and_ep_match_ragged(key):
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    batch = _batch(cfg, key)
    m_r = LM(dataclasses.replace(cfg, moe_impl="ragged"))
    params = m_r.init(key)
    l_r = float(m_r.loss(params, batch))
    for ep in (False, True):
        m_g = LM(dataclasses.replace(cfg, moe_impl="grouped",
                                     capacity_factor=4.0, expert_parallel=ep))
        l_g = float(m_g.loss(params, batch))
        assert abs(l_g - l_r) / l_r < 0.02, (ep, l_g, l_r)


def test_remat_dots_same_loss(key):
    cfg = get_smoke_config("mistral-nemo-12b", remat=True)
    batch = _batch(cfg, key)
    m_full = LM(dataclasses.replace(cfg, remat_policy="full"))
    m_dots = LM(dataclasses.replace(cfg, remat_policy="dots"))
    params = m_full.init(key)
    l1 = float(m_full.loss(params, batch))
    l2 = float(m_dots.loss(params, batch))
    assert abs(l1 - l2) < 1e-5
