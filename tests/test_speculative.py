"""Self-speculative decoding from the FLRQ rank structure.

The contract under test is *bitwise* parity: with greedy sampling, the
speculative serve (draft k tokens with the rank-truncated model, verify
the window in one batched target pass, accept the longest agreeing
prefix + the target's correction token) must emit EXACTLY the tokens of
the plain sequential decode — across fp/quantized params, dense/paged
cache backends, scanned/unrolled stacks and every window size. Draft
quality only moves throughput, never tokens: even a terrible draft
(rank 0 on a 2-bit model) serves the same streams, just slower.

On top of the parity oracle: the quant-layer draft views (rank
truncation shares the packed int4 buffers), the dispatch-level
``draft_scope``, the one-pass ``verify_slots`` primitive, cache rollback
(paged tables/refcounts must be untouched by a window — reservation is
up-front), adaptive window sizing (deterministic), EOS inside a window,
the paged decode-kernel routing, and supervisor restart mid-window
(salvage at the last *accepted* token, bitwise continuation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.apply import active_draft_rank, dispatch, draft_scope
from repro.quant.qtensor import (QuantizedLinear, dequantize_stacked,
                                 is_stacked, lane, truncate_rank)
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig
from repro.serve.faults import FaultPlan
from repro.serve.kv_cache import CacheConfig
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.supervisor import Supervisor, SupervisorConfig


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    # d_model/d_ff multiples of 128 so should_quantize() actually fires —
    # smaller proxies silently serve full-precision weights
    base = dict(n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
                head_dim=64, d_ff=256, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


@pytest.fixture(scope="module")
def tiny_fp(key):
    model = LM(_tiny_cfg())
    return model, model.init(key)


@pytest.fixture(scope="module")
def tiny_quant(tiny_fp):
    model, params = tiny_fp
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=4, x=1.0))
    return model, qparams


@pytest.fixture(scope="module")
def tiny_quant_w2(tiny_fp):
    """2-bit quantization: coarse codes make the low-rank term carry real
    signal, so a rank-0 draft visibly disagrees with the full model —
    the regime where acceptance-vs-rank is non-trivial."""
    model, params = tiny_fp
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=2, blc_epochs=1, max_rank=4, x=1.0))
    return model, qparams


def _reqs(lens=(3, 9, 5, 14, 7), vocab=128, new=None, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, vocab, l).astype(np.int32),
                    max_new_tokens=(new or 6 + 2 * i), id=i)
            for i, l in enumerate(lens)]


def _serve(model, params, reqs, backend="dense", spec=False, k=4, rank=0,
           slots=3, chunk=8, max_seq=48, adaptive=True,
           decode_kernel="auto", **scfg):
    cfg = ServeConfig(
        cache=CacheConfig(backend=backend, max_slots=slots, max_seq=max_seq,
                          page_size=8, decode_kernel=decode_kernel),
        speculative=spec, draft_rank=rank, spec_k=k,
        spec_adaptive=adaptive, **scfg)
    eng = Engine(model, params, cfg)
    sched = ContinuousScheduler(eng, prefill_chunk=chunk)
    res = sched.run(reqs)
    return {r.id: r.tokens for r in res}, sched, eng


def _first_qt(params):
    qts = [x for x in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedLinear))
        if isinstance(x, QuantizedLinear)]
    assert qts, "no quantized tensors — proxy dims below should_quantize()"
    return max(qts, key=lambda q: q.rank)


# ------------------------------------------------------ quant-layer views
def test_truncate_rank_shares_buffers(tiny_quant):
    _, qparams = tiny_quant
    qt = _first_qt(qparams)
    assert qt.rank >= 1 and is_stacked(qt)
    if qt.rank < 4:
        # adaptive selection stops at rank 1 on unstructured tiny proxies;
        # widen the factors so truncation is non-trivial — still the SAME
        # packed/scale buffers, which is what this test is about
        qt = dataclasses.replace(
            qt,
            u=jnp.concatenate([qt.u] * 4, axis=-1)[..., :4],
            v=jnp.concatenate([qt.v] * 4, axis=-2)[..., :4, :])
    t = truncate_rank(qt, 2)
    # a view over the SAME packed codes/scales — no copies of the 4-bit
    # payload; only the low-rank factors narrow
    assert t.packed is qt.packed
    assert t.scale is qt.scale
    assert t.zp is qt.zp
    assert t.act_scale_inv is qt.act_scale_inv
    assert t.rank == 2
    assert t.u.shape[-1] == 2 and t.v.shape[-2] == 2
    # clamping: r past the stored rank and r=0 both behave
    assert truncate_rank(qt, 999).rank == qt.rank
    assert truncate_rank(qt, 0).rank == 0
    # full-rank truncation dequantizes identically
    np.testing.assert_array_equal(
        np.asarray(dequantize_stacked(truncate_rank(qt, qt.rank))),
        np.asarray(dequantize_stacked(qt)))


def test_draft_scope_dispatch(tiny_quant, key):
    _, qparams = tiny_quant
    qt = lane(_first_qt(qparams), 0)
    ku, kv, kx = jax.random.split(key, 3)
    # plant non-zero factors: on unstructured tiny proxies the adaptive
    # selection accepts no peels, and a zero low-rank term would make the
    # rank-0 draft trivially identical to the full model
    qt = dataclasses.replace(
        qt,
        u=0.05 * jax.random.normal(ku, qt.u.shape, qt.u.dtype),
        v=jax.random.normal(kv, qt.v.shape, qt.v.dtype))
    x = jax.random.normal(kx, (4, qt.v.shape[-1]), jnp.float32)
    assert active_draft_rank() is None
    with draft_scope(1):
        assert active_draft_rank() == 1
        with draft_scope(0):        # nests; innermost wins
            assert active_draft_rank() == 0
            y_drafted = dispatch(qt, x)
        assert active_draft_rank() == 1
    assert active_draft_rank() is None
    # dispatch under draft_scope(r) == dispatch of the truncated tensor
    np.testing.assert_array_equal(
        np.asarray(y_drafted), np.asarray(dispatch(truncate_rank(qt, 0), x)))
    assert not np.array_equal(np.asarray(y_drafted),
                              np.asarray(dispatch(qt, x)))
    with pytest.raises(ValueError):
        with draft_scope(-1):
            pass


# ------------------------------------------------ verify-in-one-pass oracle
@pytest.mark.parametrize("fixture", ["tiny_fp", "tiny_quant"])
def test_verify_slots_rows_match_sequential(fixture, request):
    """The core parity primitive: verify_slots' logits row j is the SAME
    mathematical function as the j-th sequential decode_step (same
    cache-insert op order, decode-formula attention, per-query horizon).
    Compiled reductions may reorder within ~1 ulp for the C-wide shapes,
    so logits compare at ulp tolerance — the serving contract (greedy
    ARGMAX per row) must be exact."""
    model, params = request.getfixturevalue(fixture)
    b, c = 2, 4
    rng = np.random.default_rng(5)
    cache = model.init_cache(b, 32)
    for j in range(5):   # populate 5 real positions per slot
        tok = rng.integers(2, 128, b).astype(np.int32)
        _, cache = model.decode_step(params, tok, cache,
                                     np.full((b,), j, np.int32))
    lens = np.full((b,), 5, np.int32)
    window = rng.integers(2, 128, (b, c)).astype(np.int32)
    seq_rows, seq_cache = [], cache
    for j in range(c):
        lg, seq_cache = model.decode_step(
            params, window[:, j], seq_cache, lens + j)
        seq_rows.append(np.asarray(lg)[:, 0])
    ver, _ = model.verify_slots(params, window, cache, lens)
    ver = np.asarray(ver)
    for j in range(c):
        np.testing.assert_allclose(ver[:, j], seq_rows[j],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(ver[:, j].argmax(-1),
                                      seq_rows[j].argmax(-1))


# --------------------------------------------- end-to-end bitwise oracle
@pytest.mark.parametrize("backend", ["dense", "paged"])
@pytest.mark.parametrize("fixture", ["tiny_fp", "tiny_quant"])
def test_spec_serve_bitwise_oracle(fixture, backend, request):
    """Speculative serve == plain greedy serve, token for token, for
    every window size — across cache backends and fp/quant params."""
    model, params = request.getfixturevalue(fixture)
    reqs = _reqs()
    base, _, _ = _serve(model, params, reqs, backend=backend)
    for k in (1, 2, 4, 8):
        spec, sched, _ = _serve(model, params, reqs, backend=backend,
                                spec=True, k=k, rank=0)
        assert spec == base, f"k={k} diverged"
        assert sched.spec_windows > 0


def test_spec_serve_bitwise_oracle_unrolled(tiny_quant):
    """Scan-over-layers off: the unrolled stack's spec serve matches the
    unrolled plain serve (same executables-per-layer structure)."""
    model, qparams = tiny_quant
    model = model.with_scan(False)
    reqs = _reqs(lens=(3, 9, 5))
    base, _, _ = _serve(model, qparams, reqs)
    spec, _, _ = _serve(model, qparams, reqs, spec=True, k=4, rank=2)
    assert spec == base


def test_spec_eos_mid_window(tiny_fp):
    """An EOS landing inside a draft window truncates that slot's
    emission mid-window (surplus verified tokens are discarded) and the
    slot retires — identically to the sequential serve hitting the same
    EOS one token at a time."""
    model, params = tiny_fp
    reqs = _reqs(lens=(4, 7, 5), new=10)
    base, _, _ = _serve(model, params, reqs)
    # pick a token the oracle emits mid-stream and promote it to EOS
    eos = next(t[2] for t in base.values() if len(t) >= 6)
    base_eos, _, _ = _serve(model, params, reqs, eos_token=int(eos))
    spec_eos, _, _ = _serve(model, params, reqs, spec=True, k=4,
                            eos_token=int(eos))
    assert spec_eos == base_eos
    stopped = [rid for rid, t in base_eos.items() if t[-1] == eos
               and len(t) < 10]
    assert stopped, "EOS promotion produced no early stop — vacuous test"


# ----------------------------------------------------------- cache rollback
def _prefill_direct(bk, prompts, max_new=16):
    for s, p in enumerate(prompts):
        p = np.asarray(p, np.int32)
        bk.alloc(s, p, max_new)
        bk.prefill_chunk(s, p, 0, len(p) - 1)
        bk.register_prompt(s, p)


def test_paged_rollback_leaves_tables_untouched(tiny_fp):
    """Up-front page reservation means a speculative window never
    allocates, frees, CoWs or remaps a page: tables, refcounts and
    per-slot page counts after spec_window+rollback are byte-identical
    to before the window — i.e. to a run that never drafted."""
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(
        cache=CacheConfig(backend="paged", max_slots=3, max_seq=48,
                          page_size=8),
        speculative=True, draft_rank=0, spec_k=4))
    bk = eng.cache_backend
    bk.start()
    rng = np.random.default_rng(2)
    _prefill_direct(bk, [rng.integers(2, 128, 5 + s) for s in range(3)])
    snap = (bk._table.copy(), bk._ref.copy(), bk._alloc_pages.copy(),
            sorted(bk._free))
    cur = np.array([3, 4, 5], np.int32)
    lens = np.array([int(x) for x in bk._lengths], np.int64)
    draft, logits = bk.spec_window(cur, lens, 4)
    # partial acceptance: every slot keeps only 1 emitted token
    bk.rollback(lens + 1)
    after = (bk._table.copy(), bk._ref.copy(), bk._alloc_pages.copy(),
             sorted(bk._free))
    for a, b in zip(snap, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(bk._lengths), lens + 1)
    # the rolled-back cache keeps serving: next decode matches a
    # never-drafted twin continuing from the same accepted state
    outs = np.asarray(eng._sample_window(logits))
    nxt = np.asarray(
        eng._sample(bk.decode(outs[:, 0], lens + 1))).reshape(-1)

    eng2 = Engine(model, params, ServeConfig(
        cache=CacheConfig(backend="paged", max_slots=3, max_seq=48,
                          page_size=8)))
    bk2 = eng2.cache_backend
    bk2.start()
    rng = np.random.default_rng(2)
    _prefill_direct(bk2, [rng.integers(2, 128, 5 + s) for s in range(3)])
    t1 = np.asarray(eng2._sample(bk2.decode(cur, lens))).reshape(-1)
    np.testing.assert_array_equal(t1, outs[:, 0])
    t2 = np.asarray(eng2._sample(bk2.decode(t1.astype(np.int32),
                                            lens + 1))).reshape(-1)
    np.testing.assert_array_equal(nxt, t2)


def test_dense_rollback_is_length_bookkeeping(tiny_fp):
    model, params = tiny_fp
    eng = Engine(model, params, ServeConfig(
        max_slots=2, max_seq=48, speculative=True, spec_k=3))
    bk = eng.cache_backend
    bk.start()
    rng = np.random.default_rng(4)
    _prefill_direct(bk, [rng.integers(2, 128, 6), rng.integers(2, 128, 4)])
    lens = np.array([6, 4], np.int64)
    bk.spec_window(np.array([7, 9], np.int32), lens, 3)
    assert list(bk._lengths) == [10, 8]     # provisional: lens + k + 1
    bk.rollback(lens + 2)
    assert list(bk._lengths) == [8, 6]


# ------------------------------------------------------------- adaptive k
def test_adaptive_k_deterministic(tiny_quant_w2):
    """Adaptive window sizing is pure arithmetic on acceptance counts:
    two identical serves take identical per-step window sizes and emit
    identical tokens. The 2-bit rank-0 draft disagrees often enough that
    the windows actually move."""
    model, qparams = tiny_quant_w2
    reqs = _reqs(new=12)
    runs = [_serve(model, qparams, reqs, spec=True, k=8, rank=0)
            for _ in range(2)]
    toks0, sched0, _ = runs[0]
    toks1, sched1, _ = runs[1]
    assert toks0 == toks1
    ks0 = [t.spec_k for t in sched0.trace]
    ks1 = [t.spec_k for t in sched1.trace]
    assert ks0 == ks1
    assert sched0.spec_stats() == sched1.spec_stats()


def test_acceptance_monotone_in_draft_rank(tiny_quant_w2):
    """More draft rank -> the draft agrees with the target at least as
    often (non-strict); and parity holds REGARDLESS of draft quality —
    a bad draft costs throughput, never correctness."""
    model, qparams = tiny_quant_w2
    reqs = _reqs(new=12)
    base, _, _ = _serve(model, qparams, reqs)
    acc = {}
    for rank in (0, 4):
        spec, sched, _ = _serve(model, qparams, reqs, spec=True, k=4,
                                rank=rank, adaptive=False)
        assert spec == base, f"rank={rank} broke parity"
        acc[rank] = sched.spec_stats()["acceptance_rate"]
    assert 0.0 <= acc[0] <= acc[4] <= 1.0


# ------------------------------------------------------------- validation
def test_spec_config_validation():
    with pytest.raises(ValueError, match="greedy"):
        ServeConfig(speculative=True, temperature=0.7)
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(speculative=True, spec_k=0)
    with pytest.raises(ValueError, match="draft_rank"):
        ServeConfig(speculative=True, draft_rank=-1)
    with pytest.raises(ValueError, match="decode_kernel"):
        CacheConfig(decode_kernel="vectorized")


# ------------------------------------------------------ decode-kernel route
def test_decode_kernel_routing_and_parity(tiny_fp):
    """Explicit "paged" routes plain decode through the
    flash_decode_gqa_paged kernel (interpret mode off-TPU) and serves
    the same greedy tokens as the gather route; "auto" on CPU resolves
    to gather, visibly."""
    model, params = tiny_fp
    reqs = _reqs(lens=(3, 9, 5))
    gather, _, eng_g = _serve(model, params, reqs, backend="paged")
    assert eng_g.cache_backend.stats()["decode_route"].startswith("gather")
    kern, _, eng_k = _serve(model, params, reqs, backend="paged",
                            decode_kernel="paged")
    assert eng_k.cache_backend.stats()["decode_route"] \
        == "paged (explicitly requested)"
    assert kern == gather


def test_decode_kernel_unsupported_model_falls_back(key):
    """A softcap model has no kernel path: even an explicit "paged"
    request resolves to gather, with the reason recorded."""
    model = LM(_tiny_cfg(attn_softcap=30.0))
    params = model.init(key)
    eng = Engine(model, params, ServeConfig(
        cache=CacheConfig(backend="paged", max_slots=2, max_seq=32,
                          page_size=8, decode_kernel="paged")))
    eng.cache_backend.start()
    route = eng.cache_backend.stats()["decode_route"]
    assert route.startswith("gather") and "softcap" in route


# --------------------------------------------------- supervisor mid-window
def test_supervisor_kill_at_verify_step_bitwise(tiny_fp):
    """A replica killed AT the verify step of a speculative window
    salvages at the last accepted token: draft tokens never entered the
    emitted stream, so the restarted replica's continuation is
    bitwise-identical to a never-faulted spec serve (which is itself
    bitwise the plain serve). Zero drops, all ok, exactly the planned
    restart."""
    model, params = tiny_fp
    reqs = _reqs(lens=(4, 8, 5, 6), new=12)
    base, _, _ = _serve(model, params, reqs)

    def run(plan):
        eng = Engine(model, params, ServeConfig(
            max_slots=2, max_seq=48, speculative=True, draft_rank=0,
            spec_k=4))
        sup = Supervisor(
            lambda: eng,
            SupervisorConfig(replicas=1, prefill_chunk=8,
                             backoff_base_s=0.0),
            fault_plan=plan)
        return sup.serve([dataclasses.replace(r) for r in reqs])

    rep = run(FaultPlan.parse("exception@5:verify:0"))
    assert rep.zero_drops
    counts = rep.status_counts()
    assert set(counts) == {"ok"}, dict(counts)
    assert sum(rep.restarts.values()) == 1
    assert {o.id: o.tokens for o in rep.outcomes} == base
