"""Observability suite: the metrics registry, span tracer, flight
recorder and their integration with the serving stack.

The load-bearing assertions:

  * **One storage location** — every number a report prints (scheduler
    token/status counts, cache stats, journal counters, fleet report
    fields) equals the registry snapshot, because the report reads the
    SAME instruments the snapshot serializes.
  * **Determinism** — under a ``VirtualClock`` the exported Chrome trace
    is byte-identical across two replays of the same chaos run
    (including a kill + respawn): timestamps come from the injected
    clock, ids are never random, serialization sorts keys.
  * **Stitching** — worker-subprocess spans ride step replies and land
    in the supervisor timeline under the worker's logical pid with the
    supervisor's trace id.
  * **Free when off** — a disabled Obs hands out shared no-op
    instruments and a shared null span; serving results are unchanged.
"""
import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_PROXIES
from repro.models import LM
from repro.obs import (NULL_SPAN, FlightRecorder, Obs, Registry,
                       latency_summary, metric_key, nearest_percentile,
                       validate_chrome_trace)
from repro.obs.check import validate_metrics_snapshot
from repro.obs.metrics import (NOOP_COUNTER, NOOP_GAUGE, NOOP_HISTOGRAM,
                               Counter)
from repro.obs.trace import Tracer
from repro.serve import (Engine, FaultPlan, Journal, Request, ServeConfig,
                         Supervisor, SupervisorConfig, SupervisorCrash,
                         VirtualClock, WorkerSpec, model_config_to_dict)
from repro.serve.scheduler import ContinuousScheduler

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


# ---------------------------------------------------------------- fixtures
def _tiny_cfg(**over):
    base = dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                head_dim=32, d_ff=128, vocab=128, dtype=jnp.float32)
    base.update(over)
    return dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], **base)


def _requests(lens=(3, 9, 5, 14, 7), new=None, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(2, 128, l).astype(np.int32),
                    max_new_tokens=(new or 4 + i), id=i, **kw)
            for i, l in enumerate(lens)]


@pytest.fixture(scope="module")
def tiny(key):
    model = LM(_tiny_cfg())
    return model, model.init(key)


# ========================================================== registry (pure)
class TestRegistry:
    def test_handles_are_cached_storage(self):
        reg = Registry()
        c = reg.counter("serve.decode.tokens", replica=1)
        c.inc(5)
        assert reg.counter("serve.decode.tokens", replica=1) is c
        assert reg.snapshot()["counters"][
            "serve.decode.tokens{replica=1}"] == 5

    def test_metric_key_sorts_labels(self):
        assert metric_key("x", dict(b=2, a=1)) == "x{a=1,b=2}"
        assert metric_key("x", {}) == "x"

    def test_register_counter_adopts_not_copies(self):
        reg = Registry()
        c = Counter()
        c.inc(3)
        assert reg.register_counter("journal.records", c, replica=0) is c
        c.inc(4)  # the component keeps writing through its own handle
        assert reg.snapshot()["counters"][
            "journal.records{replica=0}"] == 7

    def test_histogram_buckets_and_snapshot(self):
        reg = Registry()
        h = reg.histogram("serve.ttft", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        d = reg.snapshot()["histograms"]["serve.ttft"]
        assert d["counts"] == [1, 2, 1] and d["count"] == 4
        assert validate_metrics_snapshot(reg.snapshot()) == []

    def test_disabled_registry_is_shared_noops(self):
        reg = Registry(enabled=False)
        assert reg.counter("a") is NOOP_COUNTER
        assert reg.gauge("b") is NOOP_GAUGE
        assert reg.histogram("c") is NOOP_HISTOGRAM
        reg.counter("a").inc(99)
        assert reg.counter("a").value == 0
        assert reg.snapshot() == {"enabled": False}
        assert validate_metrics_snapshot(reg.snapshot()) == []
        # adopting into a disabled registry is a no-op, not an error
        c = Counter()
        assert reg.register_counter("x", c) is c

    def test_snapshot_json_is_stable(self):
        reg = Registry(clock=VirtualClock())
        reg.counter("b").inc()
        reg.counter("a").inc()
        assert reg.snapshot_json() == reg.snapshot_json()
        assert list(reg.snapshot()["counters"]) == ["a", "b"]


# ======================================================= percentiles (pure)
class TestStats:
    def test_nearest_rank_semantics(self):
        vals = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert nearest_percentile(vals, 0.5) == 3.0   # unsorted input ok
        assert nearest_percentile(vals, 0.0) == 1.0
        assert nearest_percentile(vals, 0.99) == 5.0
        assert nearest_percentile([], 0.5) == 0.0
        assert nearest_percentile([7.0], 0.95) == 7.0

    def test_scheduler_reexport_is_the_same_function(self):
        # serve.scheduler re-exports obs.stats.nearest_percentile — the
        # CLI, scheduler and benchmark cannot silently diverge
        from repro.serve.scheduler import nearest_percentile as sched_pct
        assert sched_pct is nearest_percentile

    def test_latency_summary(self):
        s = latency_summary([0.2, 0.1, 0.3])
        assert s["n"] == 3 and s["min"] == 0.1 and s["max"] == 0.3
        assert s["p50"] == nearest_percentile([0.1, 0.2, 0.3], 0.5)
        assert latency_summary([]) == dict(n=0, mean=0.0, p50=0.0,
                                           p95=0.0, min=0.0, max=0.0)


# ============================================================ tracer (pure)
class TestTracer:
    def test_disabled_tracer_is_free(self):
        tr = Tracer(enabled=False)
        assert tr.span("x") is NULL_SPAN
        assert tr.span("y", request_id=1) is NULL_SPAN  # shared singleton
        with tr.span("x"):
            pass
        tr.instant("i")
        assert tr.events == []

    def test_spans_under_virtual_clock_are_deterministic(self):
        def run():
            clock = VirtualClock()
            tr = Tracer(clock=clock, enabled=True, trace_id="cafe0001")
            with tr.span("prefill", request_id=0):
                clock.sleep(0.010)
            tr.instant("admit", request_id=1)
            clock.sleep(0.005)
            with tr.span("decode", tid=2):
                clock.sleep(0.001)
            return tr.to_json()

        a, b = run(), run()
        assert a == b
        obj = json.loads(a)
        assert validate_chrome_trace(obj) == []
        ev = {e["name"]: e for e in obj["traceEvents"] if e["ph"] != "M"}
        assert ev["prefill"]["dur"] == 10000  # virtual µs, exact
        assert ev["decode"]["tid"] == 2
        assert all(e["args"]["trace"] == "cafe0001" for e in ev.values())

    def test_span_records_exception_and_reraises(self):
        tr = Tracer(clock=VirtualClock(), enabled=True)
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        assert tr.events[-1]["args"]["error"] == "ValueError"

    def test_adopt_rehomes_and_offsets(self):
        worker = Tracer(clock=VirtualClock(), enabled=True, pid=0)
        with worker.span("decode_step"):
            worker.clock.sleep(0.001)
        shipped = worker.drain()
        assert worker.events == []  # drain clears the buffer
        sup = Tracer(clock=VirtualClock(), enabled=True)
        sup.adopt(shipped, pid=3, offset_us=500)
        e = sup.events[-1]
        assert e["pid"] == 3 and e["ts"] == 500
        sup.adopt(None)  # tolerated: a step reply without events

    def test_validator_catches_malformed_events(self):
        assert validate_chrome_trace({"traceEvents": 3})
        bad = {"traceEvents": [{"name": "", "ph": "Z", "pid": "x",
                                "tid": 0, "ts": -1}]}
        errs = validate_chrome_trace(bad)
        assert len(errs) >= 3


# ==================================================== flight recorder (pure)
class TestFlightRecorder:
    def test_ring_is_bounded(self):
        fr = FlightRecorder(capacity=4, clock=VirtualClock())
        for i in range(10):
            fr.record("tick", i=i)
        assert len(fr.events) == 4
        assert [e["i"] for e in fr.events] == [6, 7, 8, 9]

    def test_dump_writes_ring_with_reason(self, tmp_path):
        fr = FlightRecorder(capacity=8, clock=VirtualClock(),
                            dir=str(tmp_path))
        fr.record("restart", replica=1)
        path = fr.dump("supervisor_crash")
        assert path and fr.dumps == [path]
        payload = json.loads(open(path).read())
        assert payload["reason"] == "supervisor_crash"
        assert payload["events"][0]["kind"] == "restart"
        assert payload["n_events"] == 1

    def test_no_dir_records_but_never_writes(self, tmp_path):
        fr = FlightRecorder(clock=VirtualClock())  # dir=None
        fr.record("x")
        assert fr.dump("crash") is None and fr.dumps == []
        # explicit dir at dump time overrides
        assert fr.dump("crash", dir=str(tmp_path)) is not None

    def test_disabled_recorder_is_inert(self, tmp_path):
        fr = FlightRecorder(clock=VirtualClock(), dir=str(tmp_path),
                            enabled=False)
        fr.record("x")
        assert len(fr.events) == 0 and fr.dump("crash") is None


# ========================================================= check CLI (pure)
class TestCheckCLI:
    def test_valid_artifacts_exit_zero(self, tmp_path):
        from repro.obs.check import main
        tr = Tracer(clock=VirtualClock(), enabled=True)
        with tr.span("x"):
            pass
        tp = tmp_path / "t.json"
        tr.export(tp)
        reg = Registry(clock=VirtualClock())
        reg.counter("a").inc()
        mp = tmp_path / "m.json"
        mp.write_text(reg.snapshot_json())
        assert main(["--trace", str(tp), "--metrics", str(mp)]) == 0

    def test_invalid_artifacts_exit_one(self, tmp_path):
        from repro.obs.check import main
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"traceEvents": [{"ph": "??"}]}))
        assert main(["--trace", str(bad)]) == 1
        badm = tmp_path / "badm.json"
        badm.write_text(json.dumps({"enabled": True, "counters": {"a": "x"},
                                    "gauges": {}, "histograms": {}}))
        assert main(["--metrics", str(badm)]) == 1

    def test_histogram_sum_mismatch_detected(self):
        snap = dict(enabled=True, counters={}, gauges={}, histograms={
            "h": dict(buckets=[1.0], counts=[2, 1], count=99, sum=0.0)})
        assert validate_metrics_snapshot(snap)


# =============================================== scheduler integration
class TestSchedulerObs:
    def test_report_numbers_equal_registry(self, tiny):
        model, params = tiny
        obs = Obs()
        eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32))
        sched = ContinuousScheduler(eng, prefill_chunk=4, obs=obs)
        res = sched.run(_requests())
        snap = obs.registry.snapshot()
        toks = sum(len(r.tokens) for r in res)
        assert snap["counters"]["serve.decode.tokens"] == toks
        assert snap["counters"]["serve.requests{status=ok}"] == len(res)
        # cache backend counters bound into the same registry
        assert snap["counters"]["cache.prefill_launches{backend=dense}"] \
            == eng.cache_backend.n_prefill_launches
        # TTFT histogram observed one sample per served request
        assert snap["histograms"]["serve.ttft_s"]["count"] == len(res)

    def test_disabled_obs_serves_identically(self, tiny):
        model, params = tiny
        eng = Engine(model, params, ServeConfig(max_slots=2, max_seq=32),
                     obs=Obs())
        baseline = {r.id: r.tokens
                    for r in ContinuousScheduler(eng, prefill_chunk=4)
                    .run(_requests())}
        eng2 = Engine(model, params, ServeConfig(max_slots=2, max_seq=32),
                      obs=Obs.disabled())
        sched = ContinuousScheduler(eng2, prefill_chunk=4,
                                    obs=Obs.disabled())
        got = {r.id: r.tokens for r in sched.run(_requests())}
        assert got == baseline
        assert sched.obs.registry.snapshot() == {"enabled": False}

    def test_journal_bind_registry_preserves_counts(self, tmp_path):
        j = Journal(tmp_path / "wal.journal")
        j.append({"t": "admit", "id": 0, "prompt": [3], "new": 1,
                  "dl": None, "arr": 0.0})
        j.flush()
        reg = Registry()
        j.bind_registry(reg)
        snap = reg.snapshot()["counters"]
        assert snap["journal.records"] == j.records == 1
        assert snap["journal.bytes"] == j.bytes > 0
        j.append({"t": "term", "id": 0, "st": "ok"})
        assert reg.snapshot()["counters"]["journal.records"] == 2
        j.close()


# ============================================ supervised fleet integration
class TestSupervisedObs:
    def _trace_run(self, tiny, plan="sigkill@3:step:0"):
        """One supervised inproc chaos serve under a VirtualClock with a
        fresh Obs; returns (report, obs). Fresh engines per call so no
        state leaks between replays."""
        model, params = tiny
        clock = VirtualClock()
        obs = Obs(trace=True, clock=clock)

        def factory():
            return Engine(model, params,
                          ServeConfig(max_slots=2, max_seq=32))
        sup = Supervisor(
            factory,
            SupervisorConfig(replicas=2, prefill_chunk=4,
                             backoff_base_s=0.01, backoff_jitter=0.0,
                             step_cost_s=0.01),
            fault_plan=FaultPlan.parse(plan), clock=clock, obs=obs)
        report = sup.serve(_requests())
        return report, obs

    def test_chaos_trace_is_byte_identical_across_replays(self, tiny):
        # the deterministic-trace contract: same seed, same virtual
        # clock, same kill coordinate -> byte-identical Perfetto export,
        # respawn included
        rep_a, obs_a = self._trace_run(tiny)
        rep_b, obs_b = self._trace_run(tiny)
        assert rep_a.zero_drops and rep_b.zero_drops
        a, b = obs_a.tracer.to_json(), obs_b.tracer.to_json()
        assert a == b
        obj = json.loads(a)
        assert validate_chrome_trace(obj) == []
        names = {e["name"] for e in obj["traceEvents"]}
        # the full lifecycle is on the timeline, replica lanes included
        assert {"dispatch", "replica_step", "admit", "prefill_chunks",
                "decode_step", "retire", "replica_failure",
                "worker_respawn"} <= names
        tids = {e["tid"] for e in obj["traceEvents"] if e["ph"] != "M"}
        assert {0, 1, 2} <= tids  # supervisor lane + one per replica

    def test_report_equals_registry_snapshot(self, tiny):
        report, obs = self._trace_run(tiny)
        snap = obs.registry.snapshot()
        g, c = snap["gauges"], snap["counters"]
        assert g["fleet.restarts"] == sum(report.restarts.values())
        assert g["fleet.wasted_compute_tokens"] == \
            report.wasted_compute_tokens
        assert g["fleet.useful_tokens"] == report.useful_tokens
        assert g["fleet.straggler_events"] == report.straggler_events
        counts = report.status_counts()
        for s, n in counts.items():
            assert g[f"fleet.requests{{status={s}}}"] == n
        # per-replica scheduler counters live under their fleet labels —
        # no collisions. (A respawned replica's counters reset with
        # scheduler.start(), per-serve accounting, so replica 0's count
        # covers post-restart work only; the killed replica's lost
        # progress is what fleet.wasted_compute_tokens measures.)
        assert "serve.decode.tokens{replica=0}" in c
        assert c["serve.decode.tokens{replica=1}"] > 0

    def test_supervisor_crash_dumps_flight_and_resume_traces(
            self, tiny, tmp_path):
        model, params = tiny
        clock = VirtualClock()
        obs = Obs(trace=True, clock=clock, flight_dir=str(tmp_path))

        def factory():
            return Engine(model, params,
                          ServeConfig(max_slots=2, max_seq=32))

        def sup_cfg():
            return SupervisorConfig(replicas=2, prefill_chunk=4,
                                    backoff_base_s=0.01,
                                    backoff_jitter=0.0, step_cost_s=0.01)
        jp = tmp_path / "wal.journal"
        sup = Supervisor(factory, sup_cfg(), journal=Journal(jp),
                         fault_plan=FaultPlan.parse("supervisor_crash@3"),
                         clock=clock, obs=obs)
        with pytest.raises(SupervisorCrash):
            sup.serve(_requests())
        dumps = [p for p in obs.recorder.dumps
                 if "supervisor_crash" in p]
        assert len(dumps) == 1
        payload = json.loads(open(dumps[0]).read())
        assert payload["reason"] == "supervisor_crash"
        assert any(e["kind"] == "supervisor_crash"
                   for e in payload["events"])
        # resume with the SAME obs: one timeline spans crash + recovery
        sup2 = Supervisor(factory, sup_cfg(), journal=Journal(jp),
                          clock=VirtualClock(), obs=obs)
        report = sup2.resume()
        assert report.zero_drops
        names = [e["name"] for e in obs.tracer.events]
        assert "supervisor_crash" in names and "resume" in names
        assert names.index("supervisor_crash") < names.index("resume")

    def test_journal_admits_stamped_with_trace_id(self, tiny, tmp_path):
        model, params = tiny
        clock = VirtualClock()
        obs = Obs(trace=True, clock=clock, trace_id="feed0042")

        def factory():
            return Engine(model, params,
                          ServeConfig(max_slots=2, max_seq=32))
        jp = tmp_path / "wal.journal"
        sup = Supervisor(
            factory,
            SupervisorConfig(replicas=2, prefill_chunk=4,
                             step_cost_s=0.01),
            journal=Journal(jp), clock=clock, obs=obs)
        sup.serve(_requests())
        j2 = Journal(jp)
        admits = [r for r in j2.recovered if r.get("t") == "admit"]
        assert admits and all(r.get("tr") == "feed0042" for r in admits)
        j2.close()


# ============================================== process fleet integration
class TestProcessFleetObs:
    @pytest.fixture(scope="class")
    def spec(self):
        return WorkerSpec(model=model_config_to_dict(_tiny_cfg()),
                          serve=ServeConfig(max_slots=2,
                                            max_seq=32).to_dict(),
                          seed=0, prefill_chunk=4)

    def test_worker_spec_trace_field_roundtrips(self, spec):
        on = dataclasses.replace(spec, trace=True)
        assert WorkerSpec.from_json(on.to_json()).trace is True
        # old specs without the field deserialize to the default
        legacy = json.loads(spec.to_json())
        legacy.pop("trace")
        assert WorkerSpec(**legacy).trace is False

    def test_worker_spans_stitch_into_supervisor_timeline(
            self, spec, tmp_path):
        obs = Obs(trace=True, flight_dir=str(tmp_path),
                  process_name="supervisor", trace_id="0ddba11c")
        step = 3 + (CHAOS_SEED % 5)
        sup = Supervisor(
            cfg=SupervisorConfig(replicas=2, prefill_chunk=4,
                                 backoff_base_s=0.01, backoff_jitter=0.0),
            fleet="procs", worker_spec=spec,
            fault_plan=FaultPlan.parse(f"sigkill@{step}:step:0"), obs=obs)
        with sup:
            report = sup.serve(_requests())
        assert report.zero_drops and report.restarts[0] >= 1
        obj = json.loads(obs.tracer.to_json())
        assert validate_chrome_trace(obj) == []
        ev = [e for e in obj["traceEvents"] if e["ph"] != "M"]
        # worker-side spans landed under the workers' logical pids with
        # the supervisor's trace id — the stitching contract
        worker_ev = [e for e in ev if e["pid"] >= 1]
        assert worker_ev
        assert {e["name"] for e in worker_ev} & {"decode_step",
                                                 "prefill_chunks"}
        assert all(e["args"]["trace"] == "0ddba11c" for e in ev)
        meta = {e["pid"]: e["args"]["name"]
                for e in obj["traceEvents"] if e["ph"] == "M"}
        assert meta[0] == "supervisor" and meta[1] == "worker-0"
        # the SIGKILL left a worker_eof flight dump
        assert any("worker_eof" in p for p in obs.recorder.dumps)
