"""Per-kernel shape/dtype sweeps against the ref.py oracles (interpret
mode on CPU, per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.group_quant import group_quant
from repro.kernels.quant_matmul import quant_matmul_fused
from repro.kernels.r1_sketch import power_iter, sketch_gemv, sketch_gemv_t


# ------------------------------------------------------------ quant_matmul
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(128, 512, 128), (256, 1024, 128),
                                   (128, 256, 384)])
@pytest.mark.parametrize("rank", [0, 16])
def test_quant_matmul_sweep(bits, shape, rank, key):
    m, n, t = shape
    rng = np.random.default_rng(bits + m + rank)
    packed = jnp.asarray(
        rng.integers(0, 256, (m, n // 128, 128 * bits // 8)), jnp.uint8)
    scale = jnp.asarray(rng.random((m, n // 128, 1)) * 0.02 + 1e-3, jnp.float32)
    zp = jnp.asarray(rng.integers(0, 1 << bits, (m, n // 128, 1)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((m, rank)) * 0.05, jnp.float32)
    v = jnp.asarray(rng.standard_normal((rank, n)) * 0.05, jnp.float32)
    asi = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    x = jnp.asarray(rng.standard_normal((t, n)), jnp.float32)
    y_k = quant_matmul_fused(x, packed, scale, zp, u, v, asi,
                             bits=bits, interpret=True)
    y_r = ref.quant_matmul_ref(x, packed, scale, zp, u, v, asi, bits=bits)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype, key):
    from repro.core.flrq import FLRQConfig, quantize_matrix
    m, n = 128, 512
    w = jax.random.normal(key, (m, n)) * 0.05
    qt, _ = quantize_matrix(w, None, FLRQConfig(bits=4, blc_epochs=1,
                                                max_rank=8), key)
    x = jax.random.normal(key, (64, n)).astype(dtype)
    y_k = ops.quant_matmul(qt, x, interpret=True)
    y_r = ref.quant_matmul_ref(x.astype(jnp.float32), qt.packed, qt.scale,
                               qt.zp, qt.u, qt.v, qt.act_scale_inv, bits=4)
    assert y_k.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_r),
                               rtol=2e-2, atol=2e-2)


def test_quant_matmul_3bit_falls_back(key):
    from repro.core.flrq import FLRQConfig, quantize_matrix
    w = jax.random.normal(key, (128, 256)) * 0.05
    qt, _ = quantize_matrix(w, None, FLRQConfig(bits=3, blc_epochs=1,
                                                max_rank=8), key)
    x = jax.random.normal(key, (8, 256))
    y = ops.quant_matmul(qt, x, interpret=True)  # routes to ref path
    y_r = ref.quant_matmul_ref(x, qt.packed, qt.scale, qt.zp, qt.u, qt.v,
                               qt.act_scale_inv, bits=3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), rtol=1e-5)


# ------------------------------------------------------------- group_quant
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
@pytest.mark.parametrize("mn", [(256, 1024), (128, 256)])
def test_group_quant_sweep(bits, symmetric, mn, key):
    m, n = mn
    w = jax.random.normal(key, (m, n), jnp.float32)
    pk, sc, zp = group_quant(w, bits=bits, symmetric=symmetric, interpret=True)
    pk2, sc2, zp2 = ref.group_quant_ref(w, bits=bits, symmetric=symmetric)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zp2), atol=1)
    # codes may differ by ±1 ulp at exact rounding boundaries; compare deq
    from repro.quant import packing
    offs = (1 << (bits - 1)) if symmetric else 0
    d1 = (packing.unpack(pk, bits, 128) - offs - zp) * sc
    d2 = (packing.unpack(pk2, bits, 128) - offs - zp2) * sc2
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=float(sc.max()) * 1.01)


# ---------------------------------------------------------------- r1 sketch
@pytest.mark.parametrize("mn", [(256, 512), (512, 1024), (256, 1536)])
@pytest.mark.parametrize("b", [1, 8])
def test_sketch_gemv_sweep(mn, b, key):
    m, n = mn
    a = jax.random.normal(key, (m, n), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, b), jnp.float32)
    y = sketch_gemv(a, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ x),
                               rtol=2e-4, atol=2e-3)
    yb = jax.random.normal(jax.random.PRNGKey(2), (m, b), jnp.float32)
    z = sketch_gemv_t(a, yb, interpret=True)
    np.testing.assert_allclose(np.asarray(z), np.asarray(a.T @ yb),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("it", [0, 1, 2])
def test_power_iter_matches_ref(it, key):
    a = jax.random.normal(key, (256, 512), jnp.float32)
    s = jax.random.normal(jax.random.PRNGKey(5), (512,), jnp.float32)
    p_k, k_k = power_iter(a, s, it=it, interpret=True)
    p_r, k_r = ref.power_iter_ref(a, s, it=it)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(k_k), np.asarray(k_r),
                               rtol=1e-4, atol=1e-3)


def test_kernel_sketch_plugs_into_rank1(key):
    """ops.sketch_power_iter yields the same rank-1 factors as core."""
    from repro.core.r1_sketch import rank1_sketch
    a = jax.random.normal(key, (300, 700), jnp.float32)  # padded path
    p, k = ops.sketch_power_iter(a, jax.random.normal(key, (700,)), it=2,
                                 interpret=True)
    kn = jnp.linalg.norm(k)
    u_kernel = p * kn
    v_kernel = k / kn
    a1 = jnp.outer(u_kernel, v_kernel)
    u, v = rank1_sketch(a, key, it=2)
    # same dominant subspace (sign may flip): compare projections
    e_kernel = float(jnp.linalg.norm(a - a1))
    e_core = float(jnp.linalg.norm(a - jnp.outer(u, v)))
    assert abs(e_kernel - e_core) / e_core < 0.05


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("shape,causal", [
    ((2, 512, 4, 64), True), ((1, 1024, 2, 128), True),
    ((2, 256, 4, 64), False)])
def test_flash_attention_kernel(shape, causal, key):
    from repro.kernels.flash_attention import flash_attention_tpu
    from repro.models.layers import flash_attention
    b, s, h, hd = shape
    q = jax.random.normal(key, shape, jnp.float32)
    k_ = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
    o_k = flash_attention_tpu(q, k_, v, causal=causal, interpret=True)
    o_r = flash_attention(q, k_, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-4, atol=1e-5)
