"""One-pass BLC clip-grid sweep: fused Pallas kernel (interpret mode) vs
the hoisted XLA path vs the seed ``lax.map`` oracle, the single-launch
contract, and the backend plumbing through BLC."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blc import _best_clip_quant, blc, resolve_clip_backend
from repro.core.quantize import (
    DEFAULT_CLIP_GRID,
    QuantSpec,
    _clip_errors,
    group_stats,
    pseudo_quantize,
    qparams_from_stats,
    search_clip_ratio,
)
from repro.kernels import ref
from repro.kernels.clip_sweep import clip_sweep_errors, kernel_shape_ok
from repro.kernels.group_quant import group_pseudo_quant


@pytest.fixture(scope="module")
def wmat():
    k = jax.random.PRNGKey(7)
    w = jax.random.normal(k, (256, 512)) * 0.05
    outlier = 1 + 6.0 * (jax.random.uniform(jax.random.PRNGKey(8),
                                            (512,)) < 0.01)
    return w * outlier


@pytest.fixture(scope="module")
def xcal():
    return jax.random.normal(jax.random.PRNGKey(3), (512, 48))


GRIDS = [DEFAULT_CLIP_GRID, (1.0, 0.8, 0.6)]


# ---------------------------------------------------- stats factoring
def test_qparams_from_stats_bitwise_matches_compute(wmat):
    """The group-stats factoring is a pure hoist: scale/zp from reused
    stats must equal the unfactored computation exactly, every clip."""
    from repro.core.quantize import compute_qparams
    for sym in (False, True):
        spec = QuantSpec(4, 128, sym)
        stats = group_stats(wmat, spec)
        for c in DEFAULT_CLIP_GRID:
            s1, z1 = compute_qparams(wmat, spec, c)
            s2, z2 = qparams_from_stats(stats, spec, c)
            np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
            np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


# ------------------------------------------- three-way sweep parity
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
@pytest.mark.parametrize("grid", GRIDS)
def test_sweep_kernel_matches_hoisted_and_seed(wmat, xcal, bits, symmetric,
                                               grid):
    """Kernel (interpret) / hoisted XLA / seed lax.map oracle must select
    the same clip ratio on calibrated AND Frobenius objectives, with the
    hoisted errors bitwise-equal to the seed's and the kernel's equal to
    tight fp tolerance (its n-blocked GEMM accumulates in a different
    order)."""
    spec = QuantSpec(bits, 128, symmetric)
    e_seed = ref.clip_errors_ref(wmat, xcal, clips=grid, bits=bits,
                                 symmetric=symmetric)
    e_xla = _clip_errors(wmat, xcal, spec, jnp.asarray(grid, jnp.float32))
    e_k = clip_sweep_errors(wmat, xcal, clips=grid, bits=bits,
                            symmetric=symmetric, interpret=True)
    np.testing.assert_array_equal(np.asarray(e_xla), np.asarray(e_seed))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_seed),
                               rtol=1e-4)
    assert (int(jnp.argmin(e_k)) == int(jnp.argmin(e_xla))
            == int(jnp.argmin(e_seed)))

    f_seed = ref.clip_errors_ref(wmat, None, clips=grid, bits=bits,
                                 symmetric=symmetric)
    f_k = clip_sweep_errors(wmat, None, clips=grid, bits=bits,
                            symmetric=symmetric, interpret=True)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_seed),
                               rtol=1e-4)
    assert int(jnp.argmin(f_k)) == int(jnp.argmin(f_seed))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_best_clip_quant_xla_matches_seed_formulation(wmat, xcal, bits):
    """The routed XLA path returns the seed's exact winner and, compiled in
    the same program, the exact round-trip at that winner (two separately
    compiled programs may differ by FMA-contraction ulps, so the bitwise
    comparison runs inside one jit)."""
    spec = QuantSpec(bits, 128, False)

    @jax.jit
    def both(w, x):
        wq, clip = _best_clip_quant(w, x, spec, DEFAULT_CLIP_GRID)
        return wq, clip, pseudo_quantize(w, spec, clip)

    wq, clip, wq_ref = both(wmat, xcal)
    e_seed = ref.clip_errors_ref(wmat, xcal, clips=DEFAULT_CLIP_GRID,
                                 bits=bits)
    c_seed = DEFAULT_CLIP_GRID[int(jnp.argmin(e_seed))]
    assert float(clip) == pytest.approx(c_seed)
    np.testing.assert_array_equal(np.asarray(wq), np.asarray(wq_ref))


def test_frobenius_search_matches_eye_objective(wmat):
    """search_clip_ratio(w, None) — now scored as Σd² — must pick the same
    clip the materialized eye(n) objective picked."""
    for bits in (2, 4):
        spec = QuantSpec(bits, 128, False)
        c_direct = search_clip_ratio(wmat, None, spec)
        e_eye = ref.clip_errors_ref(wmat, None, clips=DEFAULT_CLIP_GRID,
                                    bits=bits)
        assert float(c_direct) == pytest.approx(
            DEFAULT_CLIP_GRID[int(jnp.argmin(e_eye))])


# --------------------------------------------- single-launch contract
def _count_primitive(jaxpr, name: str) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_primitive(sub, name)
    return n


def test_sweep_is_one_pallas_launch(wmat, xcal):
    """The whole grid's errors come from ONE pallas_call (one HBM read of
    W) — not one launch per grid point."""
    fn = lambda w, x: clip_sweep_errors(w, x, clips=DEFAULT_CLIP_GRID,
                                        bits=4, interpret=True)
    jaxpr = jax.make_jaxpr(fn)(wmat, xcal).jaxpr
    assert _count_primitive(jaxpr, "pallas_call") == 1

    fn_f = lambda w: clip_sweep_errors(w, None, clips=DEFAULT_CLIP_GRID,
                                       bits=4, interpret=True)
    jaxpr_f = jax.make_jaxpr(fn_f)(wmat).jaxpr
    assert _count_primitive(jaxpr_f, "pallas_call") == 1


def test_kernel_best_clip_is_two_launches_total(wmat, xcal):
    """Kernel-path _best_clip_quant = one sweep launch + one re-quant
    launch at the argmin — grid size never multiplies launch count."""
    spec = QuantSpec(4, 128, False)
    fn = lambda w, x: _best_clip_quant(w, x, spec, DEFAULT_CLIP_GRID,
                                       mode="pallas_interpret")
    jaxpr = jax.make_jaxpr(fn)(wmat, xcal).jaxpr
    assert _count_primitive(jaxpr, "pallas_call") == 2


# ------------------------------------------------ re-quant at argmin
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
def test_group_pseudo_quant_traced_clip(wmat, bits, symmetric):
    """The dequantizing group-quant twin with a TRACED clip matches the
    XLA round-trip: identical codes up to exact rounding-boundary ties, so
    the dequantized values agree within one quantization step on those
    ties and to fp tolerance elsewhere."""
    spec = QuantSpec(bits, 128, symmetric)
    clip = jnp.float32(0.85)  # traced, not baked into the kernel
    wq_k = jax.jit(lambda w, c: group_pseudo_quant(
        w, c, bits=bits, symmetric=symmetric, interpret=True))(wmat, clip)
    wq_x = pseudo_quantize(wmat, spec, clip)
    from repro.core.quantize import compute_qparams
    scale, _ = compute_qparams(wmat, spec, clip)
    m, n = wmat.shape
    local = np.broadcast_to(
        np.asarray(scale), (m, n // spec.group_size,
                            spec.group_size)).reshape(m, n)
    d = np.abs(np.asarray(wq_k) - np.asarray(wq_x))
    assert (d <= local * 1.01).all()  # never more than one code step
    # code flips (exact .5 rounding ties pushed by an FMA ulp) must be
    # rare; every other element agrees to ulp-level fp noise
    flips = float((d > local * 0.5).mean())
    assert flips < 1e-3, flips
    noise = d[d <= local * 0.5]
    assert noise.max() <= 1e-6


# ------------------------------------------------- backend resolution
def test_resolve_clip_backend():
    assert resolve_clip_backend("xla", (256, 512), 4) == "xla"
    if jax.default_backend() != "tpu":
        assert resolve_clip_backend("auto", (256, 512), 4) == "xla"
        assert resolve_clip_backend("pallas", (256, 512), 4) == \
            "pallas_interpret"
    # 3-bit and untileable shapes fall back under auto, raise under pallas
    assert resolve_clip_backend("auto", (256, 512), 3) == "xla"
    assert resolve_clip_backend("auto", (250, 500), 4) == "xla"
    # a group size the 512-wide blocks cannot tile must also fall back
    assert resolve_clip_backend("auto", (256, 2048), 4, group=1024) == "xla"
    with pytest.raises(ValueError):
        resolve_clip_backend("pallas", (256, 512), 3)
    with pytest.raises(ValueError):
        resolve_clip_backend("nope", (256, 512), 4)
    assert kernel_shape_ok(256, 512) and not kernel_shape_ok(250, 512)
    assert not kernel_shape_ok(256, 2048, group=1024)


def test_pallas_mode_runs_on_gate_approved_shapes(wmat):
    """Every shape the gate approves must run BOTH kernel launches — the
    sweep and the argmin re-quantization share one tiling predicate
    (n=1536 tiles 512-wide sweep blocks but not a 1024-wide requant
    default; the routed path must agree with itself)."""
    w = jnp.pad(wmat, ((0, 0), (0, 1024)))  # (256, 1536)
    spec = QuantSpec(4, 128, False)
    mode = resolve_clip_backend("pallas", w.shape, 4)
    assert mode == ("pallas" if jax.default_backend() == "tpu"
                    else "pallas_interpret")
    wq, clip = jax.jit(lambda w: _best_clip_quant(
        w, None, spec, DEFAULT_CLIP_GRID, mode=mode))(w)
    assert wq.shape == w.shape and np.isfinite(np.asarray(wq)).all()


def test_blc_clip_backend_pallas_matches_xla(wmat, xcal):
    """End-to-end BLC with the kernel sweep (interpret) lands on the same
    clip trajectory and an equivalent error as the XLA sweep (their
    round-trips may differ on exact rounding ties, so errors are compared
    to tolerance, clip choices exactly)."""
    spec = QuantSpec(4, 128, False)
    key = jax.random.PRNGKey(0)
    res_x = blc(wmat, xcal, key, spec, rank=8, epochs=2, clip_backend="xla")
    res_p = blc(wmat, xcal, key, spec, rank=8, epochs=2,
                clip_backend="pallas")
    assert float(res_x.clip) == float(res_p.clip)
    assert float(res_p.err) == pytest.approx(float(res_x.err), rel=1e-3)
    np.testing.assert_allclose(np.asarray(res_p.w_q), np.asarray(res_x.w_q),
                               atol=1e-2)


def test_blc_frobenius_objective(wmat):
    """blc(x=None) — the no-calib path — runs the direct Σd² objective and
    still improves monotonically over epochs' best."""
    spec = QuantSpec(4, 128, False)
    res = blc(wmat, None, jax.random.PRNGKey(0), spec, rank=8, epochs=2)
    assert float(res.err) <= float(res.err_trace[0]) + 1e-9
    assert np.isfinite(np.asarray(res.err_trace)).all()
