"""Batched layer-parallel quantization engine: parity against the
sequential reference oracle, and Pallas-vs-XLA sketch backend equivalence
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLRConfig,
    QuantSpec,
    blc,
    blc_batched,
    flexible_rank_select,
    flexible_rank_select_batched,
    flexible_rank_select_py,
    rank1_sketch,
    sketch_lowrank_block,
    sketch_lowrank_block_masked,
)
from repro.core.flrq import FLRQConfig, quantize_matrix, quantize_stack
from repro.kernels.r1_sketch import power_iter


@pytest.fixture(scope="module")
def layer_stack():
    """(4, 256, 512) stack with per-layer different low-rank structure, so
    R1-FLR picks different ranks per layer."""
    L, m, n = 4, 256, 512
    base = jax.random.normal(jax.random.PRNGKey(7), (L, m, n)) * 0.02
    stack = []
    for i in range(L):
        r = 4 + 4 * i
        sv = 2.0 ** -jnp.arange(r)
        u = jax.random.normal(jax.random.PRNGKey(10 + i), (m, r))
        v = jax.random.normal(jax.random.PRNGKey(40 + i), (r, n))
        stack.append(base[i] + (u * sv) @ v * 0.5)
    return jnp.stack(stack)


@pytest.fixture(scope="module")
def stack_calib():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 512))
    outlier = 1 + 5.0 * (jax.random.uniform(jax.random.PRNGKey(4), (512,)) < 0.02)
    return x * outlier


# ------------------------------------------------------------- batched FLR
def test_batched_flr_matches_per_layer(layer_stack):
    """One vmapped launch == looping the jitted single-matrix FLR: the
    masked while_loop body must leave early-stopping layers frozen."""
    cfg = FLRConfig(bits=4, max_rank=32)
    keys = jax.random.split(jax.random.PRNGKey(0), layer_stack.shape[0])
    res_b = flexible_rank_select_batched(layer_stack, keys, cfg)
    for i in range(layer_stack.shape[0]):
        res_i = flexible_rank_select(layer_stack[i], keys[i], cfg)
        assert int(res_b.rank[i]) == int(res_i.rank)
        np.testing.assert_allclose(np.asarray(res_b.u[i]),
                                   np.asarray(res_i.u), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(res_b.v[i]),
                                   np.asarray(res_i.v), rtol=1e-4, atol=1e-4)
        # trace included: a finished lane must stay frozen, not keep
        # propagating its final amax into the padding entries
        np.testing.assert_allclose(np.asarray(res_b.amax_trace[i]),
                                   np.asarray(res_i.amax_trace),
                                   rtol=1e-5, atol=1e-6)


def test_batched_flr_matches_python_oracle(layer_stack):
    """All three FLR implementations share the sequential PRNG key chain, so
    the vmapped engine selects the exact ranks of paper Alg. 1."""
    cfg = FLRConfig(bits=4, max_rank=32)
    keys = jax.random.split(jax.random.PRNGKey(0), layer_stack.shape[0])
    res_b = flexible_rank_select_batched(layer_stack, keys, cfg)
    for i in range(layer_stack.shape[0]):
        _, _, r_py, _ = flexible_rank_select_py(layer_stack[i], keys[i], cfg)
        assert int(res_b.rank[i]) == r_py


def test_batched_flr_ranks_differ_across_layers(layer_stack):
    """The stack is built so rank selection actually varies per layer —
    otherwise the masking logic is untested."""
    cfg = FLRConfig(bits=4, max_rank=32, t=0.0)
    keys = jax.random.split(jax.random.PRNGKey(0), layer_stack.shape[0])
    ranks = np.asarray(flexible_rank_select_batched(layer_stack, keys, cfg).rank)
    assert len(set(ranks.tolist())) > 1


# ----------------------------------------------------------- masked sketch
def test_masked_block_sketch_zeroes_beyond_rank(layer_stack):
    a = layer_stack[2]
    u, v = sketch_lowrank_block_masked(
        a, jax.random.PRNGKey(1), jnp.int32(11), max_rank=24, block=8)
    assert u.shape == (256, 24) and v.shape == (24, 512)
    np.testing.assert_array_equal(np.asarray(u[:, 11:]), 0.0)
    np.testing.assert_array_equal(np.asarray(v[11:, :]), 0.0)
    # approximation quality ~= the unmasked blocked sketch at the same rank
    uu, vv = sketch_lowrank_block(a, jax.random.PRNGKey(1), 11, block=8)
    e_masked = float(jnp.linalg.norm(a - u @ v))
    e_plain = float(jnp.linalg.norm(a - uu @ vv))
    assert e_masked <= e_plain * 1.1 + 1e-6


def test_masked_block_sketch_rank_zero(layer_stack):
    u, v = sketch_lowrank_block_masked(
        layer_stack[0], jax.random.PRNGKey(1), jnp.int32(0), max_rank=16)
    np.testing.assert_array_equal(np.asarray(u), 0.0)
    np.testing.assert_array_equal(np.asarray(v), 0.0)


# ------------------------------------------------------------- batched BLC
def test_blc_batched_matches_sequential(layer_stack, stack_calib):
    """Per-layer err_after of the vmapped rank-masked BLC within 5% of the
    sequential BLC at the same rank (sketch directions differ by key usage;
    the alternating optimization must land in the same place)."""
    spec = QuantSpec(4, 128)
    x = stack_calib.T
    L = layer_stack.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(5), L)
    ranks = jnp.asarray([4, 8, 12, 0], jnp.int32)
    res_b = blc_batched(layer_stack, x, keys, spec, ranks, max_rank=16,
                        epochs=3)
    for i in range(L):
        res_i = blc(layer_stack[i], x, keys[i], spec, int(ranks[i]), epochs=3)
        e_b, e_s = float(res_b.err[i]), float(res_i.err)
        assert e_b <= e_s * 1.05 + 1e-9, (i, e_b, e_s)
    # padded factors stay zero beyond each layer's rank
    np.testing.assert_array_equal(np.asarray(res_b.u[0][:, 4:]), 0.0)
    np.testing.assert_array_equal(np.asarray(res_b.v[3]), 0.0)


# --------------------------------------------------- whole-stack quantizer
def test_quantize_stack_parity_with_sequential(layer_stack, stack_calib):
    """Acceptance: batched engine ranks match and per-layer err_after is
    within 5% relative of the sequential reference on a 4-layer stack."""
    cfg = FLRQConfig(bits=4, blc_epochs=2, max_rank=32)
    qt, stats = quantize_stack(layer_stack, stack_calib, cfg,
                               jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(0)
    for i, st_b in enumerate(stats):
        key, sub = jax.random.split(key)
        _, st_s = quantize_matrix(layer_stack[i], stack_calib, cfg, sub)
        assert st_b.rank == st_s.rank, (i, st_b.rank, st_s.rank)
        # sketch directions differ (key-split counts) — batched may land
        # slightly better; it must never be more than 5% worse.
        assert st_b.err_after <= st_s.err_after * 1.05 + 1e-9, (i, st_b, st_s)
        assert st_b.err_after <= st_b.err_before + 1e-6  # robustness gate
    # stacked layout: padded to the realized max rank
    rmax = max(max(s.rank for s in stats), 1)
    assert qt.u.shape == (4, 256, rmax)
    assert qt.v.shape == (4, rmax, 512)


def test_quantize_stack_no_calib(layer_stack):
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    qt, stats = quantize_stack(layer_stack, None, cfg, jax.random.PRNGKey(0))
    assert len(stats) == 4
    for st in stats:
        assert st.err_after <= st.err_before + 1e-6


def test_model_stacked_engines_same_tree(layer_stack, stack_calib):
    """Driver-level check: both engines produce identical pytree structure
    and close errors."""
    from repro.quant.stacked import quantize_model_stacked
    params = {"layers": {"wq": jnp.swapaxes(layer_stack, -1, -2)}}
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    calib = {"['layers']['wq']": stack_calib}
    qb, sb = quantize_model_stacked(params, calib, cfg, engine="batched")
    qs, ss = quantize_model_stacked(params, calib, cfg, engine="sequential")
    assert jax.tree_util.tree_structure(qb) == jax.tree_util.tree_structure(qs)
    for b, s in zip(jax.tree.leaves(qb), jax.tree.leaves(qs)):
        assert b.shape == s.shape, (b.shape, s.shape)
    key = "['layers']['wq']"
    for st_b, st_s in zip(sb[key], ss[key]):
        assert st_b.rank == st_s.rank
        assert st_b.err_after <= st_s.err_after * 1.05 + 1e-9


# ------------------------------------------------- Pallas backend parity
def test_power_iter_kernel_matches_xla(layer_stack):
    """kernels.r1_sketch.power_iter (interpret mode) == the XLA power
    iteration, vector and block variants."""
    a = layer_stack[0].astype(jnp.float32)
    for b in (None, 8):
        shape = (a.shape[1],) if b is None else (a.shape[1], b)
        s = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
        p_k, k_k = power_iter(a, s, it=2, interpret=True)
        sb = s[:, None] if b is None else s
        p = a @ sb
        p = p / jnp.maximum(jnp.linalg.norm(p, axis=0, keepdims=True), 1e-20)
        for _ in range(2):
            p = a @ (a.T @ p)
            p = p / jnp.maximum(jnp.linalg.norm(p, axis=0, keepdims=True),
                                1e-20)
        k = a.T @ p
        if b is None:
            p, k = p[:, 0], k[:, 0]
        np.testing.assert_allclose(np.asarray(p_k), np.asarray(p),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(k_k), np.asarray(k),
                                   rtol=1e-4, atol=1e-5)


def test_rank1_sketch_pallas_backend_matches_xla(layer_stack, key):
    """backend="pallas" off-TPU falls into interpret mode and must agree
    with the XLA contraction chain."""
    a = layer_stack[1]
    u_x, v_x = rank1_sketch(a, key, it=2, backend="xla")
    u_p, v_p = rank1_sketch(a, key, it=2, backend="pallas")
    np.testing.assert_allclose(np.asarray(u_p), np.asarray(u_x),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_x),
                               rtol=1e-4, atol=1e-4)


def test_backend_auto_fallback_off_grid():
    """auto backend on a shape the kernels cannot tile must fall back to
    XLA instead of failing; forced pallas raises."""
    from repro.core.r1_sketch import resolve_backend
    assert resolve_backend("auto", (384, 512)) in ("xla", "pallas")
    if jax.default_backend() != "tpu":
        assert resolve_backend("auto", (384, 512)) == "xla"
    with pytest.raises(ValueError):
        resolve_backend("pallas", (384, 512))
    a = jax.random.normal(jax.random.PRNGKey(0), (384, 512)) * 0.1
    u, v = rank1_sketch(a, jax.random.PRNGKey(1), backend="auto")
    assert u.shape == (384,) and v.shape == (512,)
