"""Paper Tables 3/19/20/21: extracted rank & extra average bit-width of
FLRQ at different memory budgets x, across bits — and the claim that rank
saturates (budget x stops binding) on larger matrices.

Plus the stack-engine donation audit (``run_donation``): the batched
quantizer's donating launch must actually consume the weight stack —
single-device via an input→output alias covering the full (L, m, n) f32
slab, multi-partition via the ``jax.buffer_donor`` annotation XLA recycles
for the clip-grid transients. Both are verified from the compiled/lowered
artifacts, not assumed.

And the ``layer_chunk`` audit (``run_layer_chunk``): the chunked stack
driver's per-launch temp allocation vs chunk size K — the compiled-
artifact evidence that chunking bounds the engine's transient f32
residuals at (K, m, n). Emitted into the BENCH_quant_time.json trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flrq import FLRQConfig, quantize_matrix

from .common import calib_activations, llm_weight, emit


def donation_audit(L=8, m=256, n=512, cfg=None):
    """Compiled-memory audit of the donating vs plain stack launch.
    Returns a dict: per-variant ``argument+output+temp-alias`` footprints,
    the alias size (must equal the full weight-stack slab when donation
    binds), and whether the donating sharded lowering carries
    ``jax.buffer_donor`` (only bindable under >1 partitions — reported
    as None on a single-device run)."""
    from repro.core.flrq import (_quantize_stack_jit,
                                 _quantize_stack_jit_donate,
                                 _quantize_stack_sharded_donate,
                                 layer_key_chain)

    cfg = cfg or FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, m, n)
    ws = jnp.broadcast_to(w, (L, m, n)) * 1.0
    keys, _ = layer_key_chain(key, L)
    lane_mask = jnp.ones((L,), bool)
    xt = jnp.zeros((0, n), jnp.float32)
    args = (ws, xt, keys, lane_mask)
    kw = dict(cfg=cfg, use_scaling=False, has_calib=False)

    def footprint(compiled):
        ma = compiled.memory_analysis()
        if ma is None:
            return None, None
        net = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        return net, ma.alias_size_in_bytes

    net_p, _ = footprint(_quantize_stack_jit.lower(*args, **kw).compile())
    net_d, alias = footprint(_quantize_stack_jit_donate.lower(
        *args, **kw, return_resid=True).compile())

    donor = None
    if jax.device_count() > 1:
        from repro.launch.mesh import make_quant_mesh
        mesh = make_quant_mesh(jax.device_count())
        txt = _quantize_stack_sharded_donate.lower(
            *args, **kw, mesh=mesh, axis="stack").as_text()
        donor = "jax.buffer_donor" in txt

    return dict(
        stack_bytes=ws.size * ws.dtype.itemsize,
        net_plain=net_p,
        net_donate=net_d,
        alias_bytes=alias,
        sharded_buffer_donor=donor,
    )


def run_donation():
    rep = donation_audit()
    sb = rep["stack_bytes"]
    emit("memory_sweep.donation.stack_bytes", sb, "")
    emit("memory_sweep.donation.alias_bytes", rep["alias_bytes"] or 0,
         "donation binds iff alias covers the stack")
    if rep["net_plain"] is not None:
        emit("memory_sweep.donation.net_plain", rep["net_plain"], "")
        emit("memory_sweep.donation.net_donate", rep["net_donate"],
             f"recycled {100.0 * (rep['alias_bytes'] or 0) / sb:.0f}% of "
             f"the stack slab")
    if rep["sharded_buffer_donor"] is not None:
        emit("memory_sweep.donation.sharded_buffer_donor",
             int(rep["sharded_buffer_donor"]),
             "stack shards are general XLA donors (clip-grid transients)")
    return rep


def layer_chunk_audit(L=8, m=512, n=1024, chunks=(1, 2, 4, 8), cfg=None):
    """Compiled-memory audit of the ``layer_chunk`` lever: per chunk size
    K, the temp-allocation footprint of the (K, m, n) engine launch — the
    BLC clip-grid residual transients the ROADMAP flagged at production
    shapes. The whole-stack launch pays temps ∝ L; a chunked driver pays
    ceil(L/K) launches each ∝ K. Measured from the compiled artifact, not
    assumed."""
    from repro.core.flrq import _quantize_stack_jit, layer_key_chain

    cfg = cfg or FLRQConfig(bits=4, blc_epochs=1, max_rank=16)
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, m, n)
    rep = {}
    for k_chunk in chunks:
        ws = jnp.broadcast_to(w, (k_chunk, m, n)) * 1.0
        keys, _ = layer_key_chain(key, k_chunk)
        lane_mask = jnp.ones((k_chunk,), bool)
        xt = jnp.zeros((0, n), jnp.float32)
        compiled = _quantize_stack_jit.lower(
            ws, xt, keys, lane_mask, None, cfg=cfg, use_scaling=False,
            has_calib=False).compile()
        ma = compiled.memory_analysis()
        rep[k_chunk] = None if ma is None else int(ma.temp_size_in_bytes)
    return rep


def run_layer_chunk():
    rep = layer_chunk_audit()
    import jax as _jax
    from .quant_time import host_family
    record = dict(
        proxy=dict(layer_chunk_audit=[8, 512, 1024]),
        backend=_jax.default_backend(),
        host=host_family(),
    )
    for k_chunk, temp in rep.items():
        emit(f"memory_sweep.layer_chunk.K{k_chunk}.temp_bytes",
             temp if temp is not None else -1,
             "engine-launch temp allocation at (K, m, n)")
        if temp is not None:
            record[f"chunk{k_chunk}_temp_bytes"] = temp
    vals = [v for v in rep.values() if v is not None]
    if len(vals) >= 2 and vals[0] < vals[-1]:
        emit("memory_sweep.layer_chunk.bounded", 1,
             f"K=1 temps {vals[0]/1e6:.1f}MB vs whole-stack "
             f"{vals[-1]/1e6:.1f}MB")
    from .common import emit_bench_json
    emit_bench_json("quant_time", record)
    return rep


def run():
    key = jax.random.PRNGKey(0)
    # "small model" vs "large model" matrices (paper: 125M vs 13B)
    for tag, (m, n) in {"small": (256, 512), "large": (1024, 4096)}.items():
        w = llm_weight(key, m, n)
        x = calib_activations(jax.random.PRNGKey(1), 64, n)
        for bits in (4, 3, 2):
            ranks = {}
            for xbudget in (0.1, 0.2, 0.4):
                cfg = FLRQConfig(bits=bits, x=xbudget, blc_epochs=1,
                                 max_rank=96)
                qt, st = quantize_matrix(w, x, cfg, key)
                ranks[xbudget] = st.rank
                emit(f"memory_sweep.{tag}.w{bits}.x{xbudget}",
                     st.rank, f"extra_bits={st.extra_bits:.2f} "
                              f"err={st.err_after:.4f}")
            mono = ranks[0.1] <= ranks[0.2] <= ranks[0.4]
            emit(f"memory_sweep.{tag}.w{bits}.monotone", int(mono),
                 "rank grows with x (paper Table 19)")
    run_donation()
    run_layer_chunk()


if __name__ == "__main__":
    run()
