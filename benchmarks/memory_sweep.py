"""Paper Tables 3/19/20/21: extracted rank & extra average bit-width of
FLRQ at different memory budgets x, across bits — and the claim that rank
saturates (budget x stops binding) on larger matrices.
"""
from __future__ import annotations

import jax

from repro.core.flrq import FLRQConfig, quantize_matrix

from .common import calib_activations, llm_weight, emit


def run():
    key = jax.random.PRNGKey(0)
    # "small model" vs "large model" matrices (paper: 125M vs 13B)
    for tag, (m, n) in {"small": (256, 512), "large": (1024, 4096)}.items():
        w = llm_weight(key, m, n)
        x = calib_activations(jax.random.PRNGKey(1), 64, n)
        for bits in (4, 3, 2):
            ranks = {}
            for xbudget in (0.1, 0.2, 0.4):
                cfg = FLRQConfig(bits=bits, x=xbudget, blc_epochs=1,
                                 max_rank=96)
                qt, st = quantize_matrix(w, x, cfg, key)
                ranks[xbudget] = st.rank
                emit(f"memory_sweep.{tag}.w{bits}.x{xbudget}",
                     st.rank, f"extra_bits={st.extra_bits:.2f} "
                              f"err={st.err_after:.4f}")
            mono = ranks[0.1] <= ranks[0.2] <= ranks[0.4]
            emit(f"memory_sweep.{tag}.w{bits}.monotone", int(mono),
                 "rank grows with x (paper Table 19)")


if __name__ == "__main__":
    run()
