"""Paper Table 2 (proxy): layer reconstruction error across methods × bits.

RTN / AWQ-like / GPTQ / LQER-like / FLRQ (ours) at W4/W3/W2, group 128 —
relative output error ||WX − ŴX||/||WX|| on calibration activations
(absolute PPLs need the real OPT/LLaMA checkpoints, unavailable offline;
the ORDERING of methods is the reproduced claim, esp. FLRQ's 2-bit edge).
"""
from __future__ import annotations

import jax

from repro.core import recon_error
from repro.core.baselines import awq_like, lqer_like, rtn
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.flrq_gptq import flrq_gptq_quantize
from repro.core.gptq import gptq_quantize
from repro.quant.qtensor import dequantize

from .common import calib_activations, llm_weight, emit


def flrq_method(w, x, bits, key):
    cfg = FLRQConfig(bits=bits, blc_epochs=4 if bits > 2 else 10, max_rank=48)
    qt, st = quantize_matrix(w, x, cfg, key)
    return dequantize(qt), dict(rank=st.rank, extra_bits=st.extra_bits)


def flrq_gptq_method(w, x, bits, key):
    """Beyond-paper composition: flexible low-rank + OBS quantization."""
    what, st = flrq_gptq_quantize(w, x, FLRQConfig(bits=bits, max_rank=48), key)
    return what, dict(rank=st.rank)


METHODS = [
    ("rtn", lambda w, x, b, k: rtn(w, x, b)),
    ("awq", lambda w, x, b, k: awq_like(w, x, b)),
    ("gptq", lambda w, x, b, k: gptq_quantize(w, x, b)),
    ("lqer_r32", lambda w, x, b, k: lqer_like(w, x, b, rank=32)),
    ("flrq", flrq_method),
    ("flrq_gptq", flrq_gptq_method),
]


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, 512, 1024)
    x = calib_activations(jax.random.PRNGKey(1), 128, 1024)
    results = {}
    for bits in (4, 3, 2):
        for name, fn in METHODS:
            what, info = fn(w, x, bits, key)
            e = float(recon_error(w, what, x.T))
            results[(bits, name)] = e
            emit(f"method_quality.w{bits}.{name}", e * 1e6,
                 f"rel err x1e-6; rank={info.get('rank', 0)}")
    # headline claims
    for bits in (4, 3, 2):
        best = min((results[(bits, n)], n) for n, _ in METHODS)
        emit(f"method_quality.w{bits}.winner", 0, best[1])
    return results


if __name__ == "__main__":
    run()
