"""Paper Tables 7/8/12 + Fig. 6: R1-Sketch vs (truncated) SVD vs RSVD —
low-rank approximation time and quality, and the `it` sweep.

The paper's headline: T-SVD is 2.5–4.4× slower than R1-Sketch at equal
accuracy; it=2 suffices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.r1_sketch import sketch_lowrank, sketch_lowrank_block
from repro.core.rsvd import lowrank_error, rsvd, truncated_svd

from .common import llm_weight, time_fn, emit

SHAPES = [(2048, 2048), (4096, 4096)]  # proj-sized layers (CPU-feasible)
RANK = 32


def run():
    key = jax.random.PRNGKey(0)
    for m, n in SHAPES:
        w = llm_weight(key, m, n)
        t_svd, (us, vs) = time_fn(lambda: truncated_svd(w, RANK), repeats=2)
        e_svd = float(lowrank_error(w, us, vs))
        t_sk, (uk, vk) = time_fn(lambda: sketch_lowrank(w, key, RANK, it=2),
                                 repeats=2)
        e_sk = float(lowrank_error(w, uk, vk))
        t_bk, (ub, vb) = time_fn(
            lambda: sketch_lowrank_block(w, key, RANK, block=8, it=2), repeats=2)
        e_bk = float(lowrank_error(w, ub, vb))
        t_rs, (ur, vr) = time_fn(lambda: rsvd(w, key, RANK, it=2), repeats=2)
        e_rs = float(lowrank_error(w, ur, vr))
        tag = f"{m}x{n}"
        emit(f"sketch_speed.{tag}.tsvd", t_svd * 1e6, f"err={e_svd:.4f}")
        emit(f"sketch_speed.{tag}.r1sketch", t_sk * 1e6,
             f"err={e_sk:.4f} speedup_vs_svd={t_svd/t_sk:.2f}x")
        emit(f"sketch_speed.{tag}.block8", t_bk * 1e6,
             f"err={e_bk:.4f} speedup_vs_svd={t_svd/t_bk:.2f}x (beyond-paper)")
        emit(f"sketch_speed.{tag}.rsvd", t_rs * 1e6, f"err={e_rs:.4f}")

    # it sweep (paper Table 7): error converges by it=2
    w = llm_weight(key, 2048, 2048)
    for it in (0, 1, 2, 4, 8):
        t, (u, v) = time_fn(lambda it=it: sketch_lowrank(w, key, RANK, it=it),
                            repeats=2)
        emit(f"sketch_speed.it{it}", t * 1e6,
             f"err={float(lowrank_error(w, u, v)):.4f}")


if __name__ == "__main__":
    run()
