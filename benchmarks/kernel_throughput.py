"""Paper Fig. 3: inference overhead of the low-rank path.

On CPU we can't measure TPU wall-clock; we report (a) interpret-mode
correctness-path timings as smoke numbers and (b) the structural claim
that matters for Fig. 3 — the low-rank correction adds only
2·r·(m+n)/(2·m·n) extra FLOPs (≈1.2% at rank 40 on a 4096² layer) and zero
extra weight-bytes passes in the fused kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.kernels import ops
from repro.quant.apply import apply, apply_lowrank_separate

from .common import llm_weight, time_fn, emit


def run():
    key = jax.random.PRNGKey(0)
    m, n, t = 1024, 2048, 128
    w = llm_weight(key, m, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, n))

    for rank_cap, tag in ((0, "no_lowrank"), (48, "rank48")):
        cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=rank_cap or 1,
                         x=0.2 if rank_cap else 1e-9)
        qt, st = quantize_matrix(w, x[:32], cfg, key)
        t_ref, _ = time_fn(lambda: apply_lowrank_separate(qt, x), repeats=3)
        emit(f"kernel_throughput.jnp.{tag}", t_ref * 1e6,
             f"rank={st.rank}")
        # structural low-rank overhead (the Fig. 3 claim)
        extra = 2 * st.rank * (m + n) / (2 * m * n)
        emit(f"kernel_throughput.flops_overhead.{tag}", extra * 1e6,
             f"fraction x1e-6 ({extra*100:.2f}% — paper reports 4-6% latency)")

    # fused kernel interpret-mode sanity timing (not a TPU number)
    cfg = FLRQConfig(bits=4, blc_epochs=1, max_rank=48)
    qt, _ = quantize_matrix(w, x[:32], cfg, key)
    t_k, _ = time_fn(lambda: ops.quant_matmul(qt, x, interpret=True),
                     repeats=1, warmup=1)
    emit("kernel_throughput.pallas_interpret", t_k * 1e6,
         "CPU interpret mode (correctness path)")


if __name__ == "__main__":
    run()
