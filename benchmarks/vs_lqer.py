"""Paper Table 4 + appendix Table 18: FLRQ vs LQER at iso-memory, and
R1-Sketch as a drop-in replacement for SVD inside LQER (L²QER-sketch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import recon_error
from repro.core.baselines import lqer_like
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import QuantSpec, pseudo_quantize
from repro.core.r1_sketch import sketch_lowrank
from repro.core.rsvd import truncated_svd
from repro.quant.qtensor import dequantize

from .common import calib_activations, llm_weight, time_fn, emit


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, 512, 1024)
    x = calib_activations(jax.random.PRNGKey(1), 64, 1024)

    # Table 4: 2-bit, LQER fixed-256-ish (scaled: 64) vs FLRQ flexible
    what_lqer, _ = lqer_like(w, x, 2, rank=64)
    e_lqer = float(recon_error(w, what_lqer, x.T))
    qt, st = quantize_matrix(w, x, FLRQConfig(bits=2, blc_epochs=10,
                                              max_rank=64), key)
    e_flrq = float(recon_error(w, dequantize(qt), x.T))
    emit("vs_lqer.w2.lqer_rank64", e_lqer * 1e6, "extra_bits=3.00")
    emit("vs_lqer.w2.flrq", e_flrq * 1e6,
         f"rank={st.rank} extra_bits={st.extra_bits:.2f} "
         f"(less memory, err ratio={e_lqer/max(e_flrq,1e-12):.2f})")

    # Table 18 / Fig. 6: swap SVD->R1-Sketch inside LQER — lossless + faster
    spec = QuantSpec(4, 128)
    wq = pseudo_quantize(w, spec)
    err_mat = w - wq

    t_svd, (us, vs) = time_fn(lambda: truncated_svd(err_mat, 32), repeats=2)
    t_sk, (uk, vk) = time_fn(lambda: sketch_lowrank(err_mat, key, 32, it=2),
                             repeats=2)
    e_svd = float(recon_error(w, wq + us @ vs, x.T))
    e_sk = float(recon_error(w, wq + uk @ vk, x.T))
    emit("vs_lqer.l2qer_svd", t_svd * 1e6, f"err={e_svd:.5f}")
    emit("vs_lqer.l2qer_sketch", t_sk * 1e6,
         f"err={e_sk:.5f} speedup={t_svd/t_sk:.2f}x lossless="
         f"{int(abs(e_sk-e_svd) < 5e-3)}")


if __name__ == "__main__":
    run()
