"""Benchmark-regression gate (CI): re-run a gated benchmark and fail if
wall time regresses beyond a tolerance band against the recorded
reference — ReFrame-style performance references, with the p95 of the
last k matching BENCH_quant_time.json entries as the reference value.

    PYTHONPATH=src python -m benchmarks.gate [--tol 0.25] [--metric batched_s]
    PYTHONPATH=src python -m benchmarks.gate --bench serve

``--bench`` selects the gated workload: ``quant`` (stacked-engine
quantization wall time, metric ``batched_min_s``) or ``serve`` (serving
runtime: the scanned-ref decode wall time ``decode_scan_ref_min_s``, the
continuous scheduler's mixed-length Poisson workload wall time
``mixed_sched_wall_min_s``, the supervised chaos workload's
``chaos_recovery_wall_min_s`` + ``chaos_wasted_token_fraction``, the
paged prefix-reuse workload's ``paged_wall_min_s``, the self-speculative
workload's ``spec_wall_min_s`` (the spec run also hard-fails inside the
benchmark if its tokens diverge from the non-spec greedy oracle — token
parity is a correctness contract, not a gated statistic), and the
multi-tenant paged trace's ``multitenant_wall_min_s``, and the
observability workload's ``obs_overhead_x`` (instrumented / bare wall,
gated against an ABSOLUTE 1.05x limit rather than the trajectory — see
``_ABSOLUTE_LIMITS``) — the interpret-mode kernel variant is excluded
from gating by construction).
``--metric`` takes a comma-separated list;
each metric gates against its own reference from ONE benchmark run.

Reference matching: an entry is comparable only if its proxy workload
descriptor, backend AND host family (``quant_time.host_family``: "ci" /
"local" / $BENCH_HOST) match the current run — a benchmark whose workload
changed this PR gets a fresh baseline instead of a bogus comparison, a GPU
trajectory never gates a CPU run, and CI-runner wall times never gate
against developer-machine baselines (CI persists its own trajectory via
actions/cache; see .github/workflows/ci.yml). When no comparable reference
exists, the gate records the new baseline and passes with a notice.

Exit codes: 0 pass, 1 regression, 2 harness error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_reference(bench: str, proxy: dict, backend: str, host: str,
                   metric: str, window: int = 10):
    """Performance reference: the p95-of-last-``window`` trajectory entries
    matching the workload descriptor, backend and host family — or None.

    p95-of-window (nearest-rank over the last k measurements, capped below
    the window maximum once two entries exist) is the pre-planned
    escalation from best-of-last-5: the min statistic made the reference
    the *fastest* recent run, so one lucky quiet window on a shared runner
    ratcheted the bar down and flaked every normal run after it. The p95
    tracks the distribution's upper envelope instead — a real regression
    still clears it by the tolerance band, while ordinary scheduler noise
    does not. Capping below the max matters at small k (nearest-rank p95
    of ≤10 samples IS the max): without it, every tolerance-accepted slow
    run would immediately become the next reference and slowdowns could
    compound at +tol per run; excluding the slowest entry means a lone
    accepted outlier never moves the bar, and sustained slowdowns still
    creep only as fast as the min statistic allowed (they must recur
    before they count). The bounded window still lets genuine
    machine-generation drift age out. Host matching keeps CI-runner wall
    times from being gated against developer-machine baselines (entries
    predating the host tag count as "local")."""
    path = os.path.join(_REPO_ROOT, f"BENCH_{bench}.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            history = json.load(f)
    except json.JSONDecodeError:
        return None
    if not isinstance(history, list):
        history = [history]
    matches = [e for e in history
               if e.get("proxy") == proxy and e.get("backend") == backend
               and e.get("host", "local") == host and metric in e]
    if not matches:
        return None
    recent = sorted(matches[-window:], key=lambda e: float(e[metric]))
    rank = max(0, -(-95 * len(recent) // 100) - 1)  # nearest-rank p95
    if len(recent) >= 2:
        rank = min(rank, len(recent) - 2)  # never the window maximum
    return recent[rank]


_BENCH_DEFAULT_METRIC = {
    "quant": "batched_min_s",
    "serve": ("decode_scan_ref_min_s,mixed_sched_wall_min_s,"
              "chaos_recovery_wall_min_s,chaos_wasted_token_fraction,"
              "paged_wall_min_s,spec_wall_min_s,multitenant_wall_min_s,"
              "proc_chaos_recovery_wall_min_s,proc_chaos_replayed_fraction,"
              "obs_overhead_x"),
}

# Metrics gated against a FIXED limit instead of the p95-of-history
# reference: ratios with a meaningful absolute contract. obs_overhead_x
# is instrumented-wall / bare-wall on the same warm engine — full span
# tracing + registry counters must cost the serve loop nothing
# measurable, so the limit is a constant, not a trajectory statistic
# (a creeping reference would let instrumentation tax compound).
_ABSOLUTE_LIMITS = {
    "obs_overhead_x": 1.05,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="quant",
                    choices=sorted(_BENCH_DEFAULT_METRIC),
                    help="which gated workload to run")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional slowdown vs reference "
                         "(0.25 = fail beyond +25%%)")
    ap.add_argument("--metric", default=None,
                    help="comma-separated wall-time metric(s) to gate on "
                         "(default: the bench's min-of-repeats statistics)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    if args.metric is None:
        args.metric = _BENCH_DEFAULT_METRIC[args.bench]
    metrics = [m for m in args.metric.split(",") if m]
    if not metrics:
        print(f"[gate] FAIL: no metrics to gate (--metric {args.metric!r})")
        return 2

    from . import quant_time

    # Resolve the references BEFORE running — the run appends new entries
    # to the trajectory, which must not gate themselves. Each metric keys
    # its OWN workload descriptor (the serve bench emits a stable decode
    # record plus a separate mixed-workload record, so adding a workload
    # never orphans another metric's baselines).
    if args.bench == "serve":
        from . import serve_throughput
        def serve_proxy(m):
            if m.startswith("mixed_"):
                return serve_throughput.mixed_workload_descriptor()
            if m.startswith(("proc_chaos_", "journal_")):
                return serve_throughput.proc_chaos_workload_descriptor()
            if m.startswith("chaos_"):
                return serve_throughput.chaos_workload_descriptor()
            if m.startswith("spec_"):
                return serve_throughput.spec_workload_descriptor()
            if m.startswith("multitenant_"):
                return serve_throughput.multitenant_workload_descriptor()
            if m.startswith(("paged_", "prefix_", "page_")):
                return serve_throughput.prefix_workload_descriptor()
            if m.startswith("obs_"):
                return serve_throughput.obs_workload_descriptor()
            return serve_throughput.workload_descriptor()

        proxies = {m: serve_proxy(m) for m in metrics}

        def run_bench():
            # interpret-mode kernel timing is validation-only noise on a
            # shared runner; the gate re-measures just the gated variants
            return serve_throughput.run_bench(repeats=args.repeats,
                                              include_fused=False)
    else:
        quant_proxy = dict(layers=quant_time.STACK_L,
                           tensors={k: list(v) for k, v in
                                    quant_time.STACK_TENSORS.items()})
        proxies = {m: quant_proxy for m in metrics}

        def run_bench():
            return quant_time.run_stacked(repeats=args.repeats,
                                          include_sequential=False)

    import jax
    backend = jax.default_backend()
    host = quant_time.host_family()
    refs = {m: None if m in _ABSOLUTE_LIMITS else
            load_reference("quant_time", proxies[m], backend, host, m)
            for m in metrics}

    record = run_bench()
    missing = [m for m in metrics if m not in record]
    if missing:
        print(f"[gate] FAIL: metric(s) {missing} not in record {record}")
        return 2
    got = {m: float(record[m]) for m in metrics}

    def over(m):
        if m in _ABSOLUTE_LIMITS:
            return got[m] > _ABSOLUTE_LIMITS[m]
        return refs[m] is not None and \
            got[m] > float(refs[m][m]) * (1.0 + args.tol)

    if any(over(m) for m in metrics):
        # One re-measure before failing: a single noisy window on a shared
        # runner must not fail the build — a real regression reproduces.
        print(f"[gate] over limit on {[m for m in metrics if over(m)]} — "
              f"re-measuring once to rule out interference")
        record = run_bench()
        got = {m: min(got[m], float(record[m])) for m in metrics}

    failed = False
    for m in metrics:
        if m in _ABSOLUTE_LIMITS:
            limit = _ABSOLUTE_LIMITS[m]
            verdict = "PASS" if got[m] <= limit else "FAIL"
            failed |= got[m] > limit
            print(f"[gate] {verdict}: {m}={got[m]:.3f} vs absolute limit "
                  f"{limit:.3f} (no trajectory reference by design)")
            continue
        if refs[m] is None:
            print(f"[gate] no comparable reference for backend={backend} "
                  f"host={host} workload={proxies[m]} — recorded new "
                  f"baseline {m}={got[m]:.4f}s, passing")
            continue
        ref_val = float(refs[m][m])
        limit = ref_val * (1.0 + args.tol)
        verdict = "PASS" if got[m] <= limit else "FAIL"
        failed |= got[m] > limit
        print(f"[gate] {verdict}: {m}={got[m]:.4f}s vs reference "
              f"{ref_val:.4f}s (ts={refs[m].get('ts', '?')}, tolerance "
              f"+{args.tol:.0%} -> limit {limit:.4f}s)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
