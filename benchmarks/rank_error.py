"""Paper Fig. 2 / Fig. 4: quantization error E and residual amax vs rank.

Reproduces the claim that (a) E and amax both fall as rank grows, (b) the
amax curve tracks the E curve well enough for rank selection, (c) the
R1-FLR stopping point sits near the E-curve knee.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flr import FLRConfig, flexible_rank_select_py
from repro.core.quantize import QuantSpec, pseudo_quantize, recon_error
from repro.core.r1_sketch import rank1_sketch

from .common import calib_activations, llm_weight, emit


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, 512, 1024)
    x = calib_activations(jax.random.PRNGKey(1), 64, 1024).T
    spec = QuantSpec(3, 128)
    resid = w
    amax0 = float(jnp.max(jnp.abs(w)))
    rows = []
    k = key
    for r in range(0, 33):
        if r > 0:
            k, sub = jax.random.split(k)
            u, v = rank1_sketch(resid, sub, it=2)
            resid = resid - jnp.outer(u, v)
        wq = pseudo_quantize(resid, spec)
        e = float(recon_error(w, wq + (w - resid), x))
        amax = float(jnp.max(jnp.abs(resid)))
        rows.append((r, e, amax))
    # R1-FLR chosen rank for reference
    _, _, rank, _ = flexible_rank_select_py(w, key, FLRConfig(bits=3, max_rank=64))
    e0, e_sel = rows[0][1], rows[min(rank, 32)][1]
    emit("rank_error.E_rank0", rows[0][1] * 1e6, f"E at rank 0")
    emit("rank_error.E_rank8", rows[8][1] * 1e6, "E at rank 8")
    emit("rank_error.E_rank32", rows[32][1] * 1e6, "E at rank 32")
    emit("rank_error.amax_ratio_r32", rows[32][2] / amax0 * 1e6,
         "amax_32/amax_0 (x1e-6)")
    emit("rank_error.flr_rank", rank, f"R1-FLR pick; E {e0:.4f}->{e_sel:.4f}")
    # decreasing up to sketch noise at the flat tail (compare vs running min)
    def decreasing(vals, tol=0.05):
        run_min, ok = vals[0], True
        for v in vals[1:]:
            ok &= v <= run_min * (1 + tol) + 1e-4
            run_min = min(run_min, v)
        return ok

    mono_e = decreasing([r[1] for r in rows])
    mono_a = decreasing([r[2] for r in rows])
    emit("rank_error.monotone", int(mono_e and mono_a),
         "both curves decrease (paper Fig.2)")
    return rows


if __name__ == "__main__":
    run()
