"""Paper Table 8: end-to-end quantization wall-time per method (scaled to
CPU-feasible layer sizes; the paper's claim is the ORDERING — FLRQ ≈ AWQ
speed at 3/4-bit, ≥30% faster than SVD-based LQER, and much faster than
iterative-optimization methods at 2-bit).

Plus the batched-engine benchmark: quantizing a stacked multi-layer proxy
tensor with the layer-parallel engine (one jitted program per stack:
vmapped R1-FLR, rank-masked batched BLC, batched packing) vs. the
sequential per-layer reference (one python loop, one host sync per R1-FLR
peel). The speedup lands in the BENCH_quant_time.json trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.baselines import awq_like, lqer_like, rtn
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.gptq import gptq_quantize

from .common import (calib_activations, emit, emit_bench_json, llm_weight,
                     time_fn, time_fn_min)

def host_family() -> str:
    """Performance-reference grouping key: wall times are only comparable
    within the same class of machine, so trajectory entries are tagged and
    the regression gate never compares a CI runner against a developer
    laptop. Override with BENCH_HOST for named fleets."""
    import os
    return os.environ.get("BENCH_HOST") or (
        "ci" if os.environ.get("CI") else "local")


M, N = 1024, 2048

# stacked proxy model: L transformer-ish layers, five stacked weight
# families at CPU-feasible sizes (model layout: (L, in, out)). wq/wk/wv
# share the quantizer shape (256, 256) — the same-shape fusion group.
STACK_L = 8
STACK_TENSORS = {"wq": (256, 256), "wk": (256, 256), "wv": (256, 256),
                 "w_up": (256, 512), "w_down": (512, 256)}


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, M, N)
    x = calib_activations(jax.random.PRNGKey(1), 64, N)

    for bits in (4, 2):
        t_rtn, _ = time_fn(lambda: rtn(w, x, bits)[0], repeats=2)
        t_awq, _ = time_fn(lambda: awq_like(w, x, bits)[0], repeats=1)
        t_lqer, _ = time_fn(lambda: lqer_like(w, x, bits, rank=32)[0],
                            repeats=1)
        t_gptq, _ = time_fn(lambda: gptq_quantize(w, x, bits)[0], repeats=1)

        def flrq():
            qt, _ = quantize_matrix(
                w, x, FLRQConfig(bits=bits, max_rank=48,
                                 blc_epochs=1 if bits > 2 else 8), key)
            return qt.packed

        t_flrq, _ = time_fn(flrq, repeats=1)
        tag = f"w{bits}"
        emit(f"quant_time.{tag}.rtn", t_rtn * 1e6, "")
        emit(f"quant_time.{tag}.awq", t_awq * 1e6, "")
        emit(f"quant_time.{tag}.lqer_svd", t_lqer * 1e6, "")
        emit(f"quant_time.{tag}.gptq", t_gptq * 1e6, "")
        emit(f"quant_time.{tag}.flrq", t_flrq * 1e6,
             f"vs lqer {t_lqer/t_flrq:.2f}x")

    run_stacked()


def run_stacked(repeats: int = 3, include_sequential: bool = True):
    """Whole-model stacked quantization: batched layer-parallel engine
    (fused and unfused) vs the sequential per-layer reference, through the
    real driver (``quantize_model_stacked``) on a proxy params tree of five
    stacked weight families × STACK_L layers. Returns the record appended
    to the BENCH_quant_time.json trajectory (the CI regression gate's
    performance reference)."""
    from repro.quant.stacked import quantize_model_stacked

    params = {"layers": {}}
    calib = {}
    # One calibration batch per input width — mirrors
    # data.pipeline.collect_layer_activations, which hands every matrix fed
    # by the same stream the same activation array (so the wq/wk/wv fusion
    # group shares its batch, like a real transformer block).
    calib_by_width = {}
    for t_i, (name, (d_in, d_out)) in enumerate(STACK_TENSORS.items()):
        w = jnp.stack([
            llm_weight(jax.random.PRNGKey(100 * t_i + i), d_out, d_in)
            for i in range(STACK_L)])
        params["layers"][name] = jnp.swapaxes(w, -1, -2)  # model (L, in, out)
        if d_in not in calib_by_width:
            calib_by_width[d_in] = calib_activations(
                jax.random.PRNGKey(1000 + d_in), 64, d_in)
        calib[f"['layers']['{name}']"] = calib_by_width[d_in]
    cfg = FLRQConfig(bits=4, max_rank=48, blc_epochs=1)

    def run_engine(engine, fuse=True):
        def fn():
            q, _ = quantize_model_stacked(params, calib, cfg, engine=engine,
                                          fuse_stacks=fuse)
            return jax.tree.leaves(q)
        return fn

    (t_b_min, t_b), _ = time_fn_min(run_engine("batched", fuse=True),
                                    repeats=repeats)
    (t_u_min, t_u), _ = time_fn_min(run_engine("batched", fuse=False),
                                    repeats=repeats)
    shape_tag = f"{len(STACK_TENSORS)}tensors_L{STACK_L}"
    record = dict(
        proxy=dict(layers=STACK_L,
                   tensors={k: list(v) for k, v in STACK_TENSORS.items()}),
        batched_s=round(t_b, 4),
        batched_min_s=round(t_b_min, 4),
        batched_unfused_s=round(t_u, 4),
        batched_unfused_min_s=round(t_u_min, 4),
        backend=jax.default_backend(),
        host=host_family(),
    )
    if include_sequential:
        t_s, _ = time_fn(run_engine("sequential"), repeats=repeats)
        record.update(sequential_s=round(t_s, 4),
                      speedup=round(t_s / t_b, 2))
        emit("quant_time.stack.batched", t_b * 1e6,
             f"{shape_tag} {t_s / t_b:.2f}x vs sequential")
        emit("quant_time.stack.sequential", t_s * 1e6, shape_tag)
    else:
        emit("quant_time.stack.batched", t_b * 1e6, shape_tag)
    emit("quant_time.stack.batched_unfused", t_u * 1e6,
         f"{shape_tag} fusion {t_u / t_b:.2f}x")
    emit_bench_json("quant_time", record)
    return record


if __name__ == "__main__":
    run()
