"""Paper Table 8: end-to-end quantization wall-time per method (scaled to
CPU-feasible layer sizes; the paper's claim is the ORDERING — FLRQ ≈ AWQ
speed at 3/4-bit, ≥30% faster than SVD-based LQER, and much faster than
iterative-optimization methods at 2-bit).
"""
from __future__ import annotations

import jax

from repro.core.baselines import awq_like, lqer_like, rtn
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.gptq import gptq_quantize

from .common import calib_activations, llm_weight, time_fn, emit

M, N = 1024, 2048


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, M, N)
    x = calib_activations(jax.random.PRNGKey(1), 64, N)

    for bits in (4, 2):
        t_rtn, _ = time_fn(lambda: rtn(w, x, bits)[0], repeats=2)
        t_awq, _ = time_fn(lambda: awq_like(w, x, bits)[0], repeats=1)
        t_lqer, _ = time_fn(lambda: lqer_like(w, x, bits, rank=32)[0],
                            repeats=1)
        t_gptq, _ = time_fn(lambda: gptq_quantize(w, x, bits)[0], repeats=1)

        def flrq():
            qt, _ = quantize_matrix(
                w, x, FLRQConfig(bits=bits, max_rank=48,
                                 blc_epochs=1 if bits > 2 else 8), key)
            return qt.packed

        t_flrq, _ = time_fn(flrq, repeats=1)
        tag = f"w{bits}"
        emit(f"quant_time.{tag}.rtn", t_rtn * 1e6, "")
        emit(f"quant_time.{tag}.awq", t_awq * 1e6, "")
        emit(f"quant_time.{tag}.lqer_svd", t_lqer * 1e6, "")
        emit(f"quant_time.{tag}.gptq", t_gptq * 1e6, "")
        emit(f"quant_time.{tag}.flrq", t_flrq * 1e6,
             f"vs lqer {t_lqer/t_flrq:.2f}x")


if __name__ == "__main__":
    run()
