"""Serving-throughput benchmark: tokens/s through ``serve.Engine`` on an
FLRQ-W4 proxy model, across the quantized runtime's execution variants:

  * ``unroll_ref`` — scan_layers=False, backend="ref": L per-layer pytree
    dispatches per step (the pre-runtime reference execution).
  * ``scan_ref``   — scan_layers=True, backend="ref": ONE compiled layer
    body scanned over the stacked QuantizedLinear weights (the default
    serving path).
  * ``fused_interpret`` — scanned + backend="fused" in Pallas interpret
    mode: exercises the fused-kernel serving path end-to-end off-TPU.
    Interpret mode is a *validation* execution, not a performance number —
    it is recorded for trajectory shape/coverage, never gated on.

Plus the **mixed-length continuous-batching workload**: prompt lengths
spanning a 4x range with Poisson arrivals and mixed generation budgets,
served by (a) the chunked engine — whole slot-chunks prefill together and
decode until the LAST member drains — and (b) the continuous scheduler —
per-slot admission, chunked prefill, immediate retirement. The scheduler's
end-to-end wall time (``mixed_sched_wall_min_s``), tok/s
(``mixed_decode_toks_per_s``) and TTFT p50/p95 land in the same record;
the chunked numbers sit beside them as the A/B.

Plus the **chaos workload**: the same request mix served through the
fault-tolerant supervisor (2 replicas, shared queue) with a deterministic
replica kill mid-decode — measuring what fault tolerance *costs*:
``chaos_recovery_wall_min_s`` (end-to-end wall including salvage, backoff,
rebuild and re-prefill), ``chaos_recovery_overhead_x`` (vs the same
supervised fleet with no fault), and ``chaos_wasted_token_fraction``
(positions recomputed / total computed). The run hard-fails if any request
is dropped or ends non-ok — a chaos benchmark that quietly sheds work
would report a flattering wall time.

Plus the **process-chaos workload**: the same supervised trace served by
REAL worker subprocesses (``--fleet procs``: ``serve.worker`` over the
framed RPC transport) with a durable journal, a worker SIGKILL mid-serve
and an injected supervisor crash — recovery here pays actual process
spawn, deterministic re-quantization and journal replay, not an
in-process ``scheduler.start()``. Records
``proc_chaos_recovery_wall_min_s`` (wall including the crash, the fresh
supervisor and the resume), ``proc_chaos_replayed_fraction``
(journal/emitted tokens that rode resume prompts / all kept positions)
and the journal's measured fsync overhead
(``journal_fsync_us_per_record``). Hard-fails on any drop, duplicate
streamed token, or non-ok status — exactly-once is asserted, not
assumed.

Plus the **prefix-reuse workload**: 16 requests sharing one system
prompt, served dense vs ``--cache-backend paged`` (block-table cache +
radix prefix trie, ``serve.kv_cache``). The paged run must match the
dense tokens bitwise AND demonstrably reuse the shared prefix (non-zero
``prefix_hit_rate``, fewer prefill tokens) or it hard-fails; it records
``paged_wall_min_s``, ``paged_decode_toks_per_s``, ``prefix_hit_rate``
and the steady-state ``page_utilization``.

Plus the **self-speculative workload**: a decode-heavy trace (short
prompts, long budgets) served plain vs ``speculative=True`` (draft k
tokens with the rank-truncated FLRQ model, verify in one batched pass).
The speculative run must emit bitwise-identical tokens to the non-spec
greedy oracle or the benchmark hard-fails; it records
``spec_wall_min_s`` (gated), tok/s, the speedup over the non-spec
baseline, acceptance rate, accepted tokens per slot-step and the
wasted-draft fraction.

Plus the **multi-tenant prefix trace**: several distinct system prompts
interleaved in one request stream — the radix trie holds multiple live
subtrees and each admission must match its own tenant's prefix. Bitwise
parity with dense plus demonstrable reuse, recording
``multitenant_wall_min_s`` (gated) and the hit rate.

Plus the **observability-overhead workload**: the same trace served with
a disabled ``repro.obs`` bundle vs metrics + span tracing fully on. The
traced export must validate as Chrome trace-event JSON and
``obs_overhead_x`` (instrumented wall / bare wall) is gated at an
absolute 1.05x — instrumentation is free or it is a regression.

Each variant reports prefill and decode tokens/s; the record lands in the
BENCH_quant_time.json trajectory and ``benchmarks.gate --bench serve``
gates the scanned-ref decode wall time AND the mixed scheduler wall time
AND the chaos recovery wall + wasted-token fraction AND the paged
prefix-reuse wall time AND the speculative + multi-tenant wall times
(min-of-repeats, p95-of-last-10 reference).

    PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig
from repro.serve.scheduler import ContinuousScheduler

from .common import emit, emit_bench_json
from .quant_time import host_family

# CPU-feasible serving proxy (kept small enough that the interpret-mode
# kernel variant stays in CI budget).
SERVE_L = 4
SERVE_D = 256
SERVE_FF = 512
SERVE_VOCAB = 1024
SLOTS = 4
PROMPT = 16
NEW_TOKENS = 24
BITS = 4

VARIANTS = (
    ("unroll_ref", False, "ref", None),
    ("scan_ref", True, "ref", None),
    ("fused_interpret", True, "fused", True),
)

# Mixed-length continuous-batching workload: prompt lengths span 4x
# (8..32), generation budgets span 12x (4..48 — output-length variance is
# what dominates real traffic), Poisson arrivals fast enough that the
# queue never starves — the regime where chunked serving idles retired
# slots until the chunk's longest member drains. Workload size matters
# honestly in BOTH directions on this CPU proxy: a narrow budget spread
# (6..24) measures ~0.9x (the scheduler's extra per-step dispatches eat
# the small drain waste), 16 requests measure ~1.0-1.1x (tail-drain and
# prefill interleaving offset the win), while 32 requests give the
# chunk-drain waste enough chunks to compound (nearly every chunk
# inherits one long-budget member) — the steady-state regime a serving
# scheduler exists for.
MIX_REQUESTS = 32
MIX_PROMPT_MIN, MIX_PROMPT_MAX = 8, 32
MIX_NEW_MIN, MIX_NEW_MAX = 4, 48
MIX_RATE = 200.0            # requests/s
# prefill tokens per scheduler step: on this proxy every compiled call has
# a ~30ms fixed cost (CPU dispatch + whole-stack dequant), so chunk=8
# spends 45 prefill dispatches where chunk=32 spends 17 — measured 0.87x
# vs 1.13x end-to-end. Real hardware shrinks the fixed cost and with it
# the chunk-size sensitivity; the chunking machinery (bucketing, resume
# offsets) is identical either way.
MIX_CHUNK = 32
MIX_MAX_SEQ = MIX_PROMPT_MAX + MIX_NEW_MAX + 8

# Chaos workload: smaller than the mixed trace (two replicas double the
# compile bill) but long enough that the step-8 kill always lands
# mid-serve with work in flight on replica 0.
CHAOS_REQUESTS = 12
CHAOS_REPLICAS = 2
CHAOS_PLAN = "exception@8:decode:0"

# Process-chaos workload: smaller still (every worker spawn pays real
# model build + deterministic re-quantization + compile), but the kill
# and the supervisor crash both land mid-serve with work in flight.
PROC_CHAOS_REQUESTS = 8
PROC_CHAOS_REPLICAS = 2
PROC_CHAOS_PLAN = "sigkill@5:step:0,supervisor_crash@10"
JOURNAL_RECORDS = 256       # fsync micro-measurement batch

# Prefix-reuse workload: every request opens with the same system prompt
# (3 full pages at PREFIX_PAGE) and diverges into a short user tail — the
# regime the paged backend's radix trie exists for. Dense serves it by
# re-prefilling the prefix 16 times; paged prefills it once and maps the
# shared pages read-only into each slot.
PREFIX_REQUESTS = 16
PREFIX_LEN = 24
PREFIX_TAILS = (2, 5, 3, 7, 4, 6, 2, 8)
PREFIX_NEW = 8
PREFIX_PAGE = 8

# Self-speculative workload: decode-dominated (short prompts, long
# generation budgets — the regime speculation exists for; prefill is
# identical between the spec and non-spec runs). The draft keeps 4 of
# the proxy's 16 low-rank terms: on CPU the draft runs hoisted
# (dequantized-dense) weights, so extra draft rank costs nothing per
# step while lifting greedy agreement from ~62% (rank 0) to ~99% —
# measured 1.5x+ end-to-end vs ~1.1x at rank 0.
SPEC_REQUESTS = SLOTS
SPEC_NEW = 48
SPEC_K = 4
SPEC_DRAFT_RANK = 4

# Observability-overhead workload: the same short trace served twice
# through the continuous scheduler — once with a disabled Obs bundle,
# once with metrics AND span tracing fully on. The instrumented wall
# must stay within noise of the bare wall (obs_overhead_x, gated at an
# absolute 1.05x — instrumentation that taxes the serve loop is a bug,
# not a trade-off), and the traced run must export a schema-valid
# Chrome trace or the benchmark hard-fails.
OBS_REQUESTS = 8
OBS_NEW = 16

# Multi-tenant prefix-reuse trace: TENANTS distinct system prompts, the
# request stream round-robins across them — the trie must keep several
# live prefix subtrees at once and every tenant's requests must hit THEIR
# prefix (a single-prefix trie would score the same hit rate serving one
# tenant; interleaving is what exercises eviction pressure and per-tenant
# sharing together).
TENANTS = 4
TENANT_REQUESTS = 16


def workload_descriptor() -> dict:
    """The gate's comparability key: a changed serving workload re-baselines
    instead of comparing against a different experiment. Kept STABLE when a
    new workload is added elsewhere — widening this dict would orphan every
    existing decode baseline and silently disable the decode regression
    gate for one run (the mixed workload keys its own descriptor below)."""
    return dict(kind="serve", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS,
                prompt=PROMPT, new_tokens=NEW_TOKENS, bits=BITS)


def mixed_workload_descriptor() -> dict:
    """Comparability key for the continuous-batching mixed workload — its
    own trajectory entries, gated independently of the decode variants."""
    return dict(kind="serve_mixed", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                requests=MIX_REQUESTS,
                prompt=[MIX_PROMPT_MIN, MIX_PROMPT_MAX],
                new_tokens=[MIX_NEW_MIN, MIX_NEW_MAX],
                rate=MIX_RATE, chunk=MIX_CHUNK)


def chaos_workload_descriptor() -> dict:
    """Comparability key for the supervised chaos workload — the fault
    plan is part of the workload: changing the kill coordinate re-baselines
    instead of comparing different recoveries."""
    return dict(kind="serve_chaos", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                replicas=CHAOS_REPLICAS, requests=CHAOS_REQUESTS,
                prompt=[MIX_PROMPT_MIN, MIX_PROMPT_MAX],
                new_tokens=[MIX_NEW_MIN, MIX_NEW_MAX],
                plan=CHAOS_PLAN, chunk=MIX_CHUNK)


def proc_chaos_workload_descriptor() -> dict:
    """Comparability key for the cross-process chaos workload — the
    fault plan (kill + supervisor crash coordinates) is part of the
    workload identity, like the in-process chaos descriptor."""
    return dict(kind="serve_proc_chaos", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                replicas=PROC_CHAOS_REPLICAS, requests=PROC_CHAOS_REQUESTS,
                prompt=[MIX_PROMPT_MIN, MIX_PROMPT_MAX],
                new_tokens=[MIX_NEW_MIN, MIX_NEW_MAX],
                plan=PROC_CHAOS_PLAN, chunk=MIX_CHUNK,
                journal_records=JOURNAL_RECORDS)


def prefix_workload_descriptor() -> dict:
    """Comparability key for the same-system-prompt paged workload — its
    own trajectory entries, gated independently of decode/mixed/chaos."""
    return dict(kind="serve_prefix", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                requests=PREFIX_REQUESTS, prefix=PREFIX_LEN,
                tails=list(PREFIX_TAILS), new_tokens=PREFIX_NEW,
                page=PREFIX_PAGE)


def spec_workload_descriptor() -> dict:
    """Comparability key for the self-speculative workload — its own
    trajectory entries; changing the window size or draft rank
    re-baselines instead of comparing different speculation regimes."""
    return dict(kind="serve_spec", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                requests=SPEC_REQUESTS, prompt=PROMPT, new_tokens=SPEC_NEW,
                spec_k=SPEC_K, draft_rank=SPEC_DRAFT_RANK)


def obs_workload_descriptor() -> dict:
    """Comparability key for the observability-overhead workload — its
    own trajectory entries; the gate reads ``obs_overhead_x`` against an
    absolute limit rather than the p95-of-history reference."""
    return dict(kind="serve_obs", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                requests=OBS_REQUESTS, prompt=PROMPT, new_tokens=OBS_NEW)


def multitenant_workload_descriptor() -> dict:
    """Comparability key for the multi-tenant paged trace — its own
    trajectory entries, gated independently of the single-prefix
    workload."""
    return dict(kind="serve_multitenant", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS, bits=BITS,
                tenants=TENANTS, requests=TENANT_REQUESTS,
                prefix=PREFIX_LEN, new_tokens=PREFIX_NEW, page=PREFIX_PAGE)


def mixed_workload():
    """Deterministic mixed-length request trace + Poisson arrival offsets
    (same trace for the chunked baseline and the scheduler). Arrival
    semantics shared with the serve CLI (``launch.serve.poisson_arrivals``)
    so the benchmark and the launcher cannot silently diverge."""
    from repro.launch.serve import poisson_arrivals

    rng = np.random.default_rng(7)
    reqs = []
    for i in range(MIX_REQUESTS):
        plen = int(rng.integers(MIX_PROMPT_MIN, MIX_PROMPT_MAX + 1))
        new = int(rng.integers(MIX_NEW_MIN, MIX_NEW_MAX + 1))
        reqs.append(Request(rng.integers(2, SERVE_VOCAB, plen)
                            .astype(np.int32), max_new_tokens=new, id=i))
    return reqs, poisson_arrivals(rng, MIX_REQUESTS, MIX_RATE)


def run_mixed(model, qparams, repeats: int = 3) -> dict:
    """Chunked engine vs continuous scheduler on the mixed workload.
    The chunked baseline gets every request up-front (its strongest case —
    no arrival waits); the scheduler replays the Poisson arrivals AND
    still has to win on end-to-end wall time.

    Two honesty notes on the comparison. (1) The chunked engine left-pads
    batched prompts without a padding mask, so short prompts' tokens are
    pad-contaminated and can EOS at different steps than the scheduler's
    — each side's tok/s therefore uses its OWN token count (both counts
    land in the record); token-level correctness is established
    separately against the max_slots=1 chunked oracle, where padding
    vanishes (tests/test_scheduler.py). (2) ``mixed_decode_toks_per_s``
    (the metric name the tracking issue specifies) is END-TO-END
    throughput — generated tokens over full wall time including chunked
    prefill and arrival waits — not a decode-interval rate like
    ``decode_scan_ref_tok_s``."""
    reqs, arrivals = mixed_workload()
    scfg = dict(max_slots=SLOTS, max_seq=MIX_MAX_SEQ, backend="ref")

    eng_c = Engine(model, qparams, ServeConfig(**scfg))
    eng_c.generate(reqs)  # warm: compile per-plen prefills + decode
    chunked_walls, chunked_toks = [], 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = eng_c.generate(reqs)
        chunked_walls.append(time.perf_counter() - t0)
        chunked_toks = sum(len(r.tokens) for r in res)

    eng_s = Engine(model, qparams, ServeConfig(**scfg))
    ContinuousScheduler(eng_s, prefill_chunk=MIX_CHUNK).run(reqs, arrivals)
    sched_walls, sched_toks, ttfts = [], 0, []
    for _ in range(repeats):
        sched = ContinuousScheduler(eng_s, prefill_chunk=MIX_CHUNK)
        t0 = time.perf_counter()
        sres = sched.run(reqs, arrivals)
        sched_walls.append(time.perf_counter() - t0)
        sched_toks = sum(len(r.tokens) for r in sres)
        # percentiles pool EVERY repeat's TTFTs — a single-repeat snapshot
        # would sit beside min-of-repeats wall times yet reflect one
        # arbitrary (possibly the noisiest) run
        ttfts.extend(r.ttft_s for r in sres)

    from repro.obs.stats import nearest_percentile

    c_min, s_min = float(np.min(chunked_walls)), float(np.min(sched_walls))
    p = lambda q: nearest_percentile(ttfts, q)
    out = {
        "mixed_chunked_wall_min_s": round(c_min, 4),
        "mixed_chunked_toks_per_s": round(chunked_toks / c_min, 1),
        "mixed_chunked_tokens": chunked_toks,
        "mixed_sched_wall_min_s": round(s_min, 4),
        "mixed_decode_toks_per_s": round(sched_toks / s_min, 1),
        "mixed_sched_tokens": sched_toks,
        "mixed_ttft_p50_s": round(p(0.50), 4),
        "mixed_ttft_p95_s": round(p(0.95), 4),
        "mixed_sched_vs_chunked_x": round(
            (sched_toks / s_min) / max(chunked_toks / c_min, 1e-9), 3),
    }
    emit("serve_throughput.mixed.chunked", c_min * 1e6,
         f"{chunked_toks / c_min:.0f} tok/s")
    emit("serve_throughput.mixed.continuous", s_min * 1e6,
         f"{sched_toks / s_min:.0f} tok/s, TTFT p50 {p(0.5)*1e3:.0f}ms "
         f"p95 {p(0.95)*1e3:.0f}ms, "
         f"sched/chunked tok/s {out['mixed_sched_vs_chunked_x']:.2f}x")
    return out


def run_prefix(model, qparams, repeats: int = 3) -> dict:
    """Dense vs paged on the same-system-prompt workload. The paged run
    must (a) emit bitwise-identical tokens to the dense oracle and
    (b) actually reuse the shared prefix — fewer prefill launches AND
    fewer prefill tokens, with a non-zero trie hit rate — or the
    benchmark hard-fails: a paged number without reuse is just a slower
    gather/scatter dense run."""
    from repro.serve.kv_cache import CacheConfig

    rng = np.random.default_rng(23)
    prefix = rng.integers(2, SERVE_VOCAB, PREFIX_LEN).astype(np.int32)
    reqs = []
    for i in range(PREFIX_REQUESTS):
        tail = rng.integers(2, SERVE_VOCAB,
                            PREFIX_TAILS[i % len(PREFIX_TAILS)])
        reqs.append(Request(np.concatenate([prefix, tail.astype(np.int32)]),
                            max_new_tokens=PREFIX_NEW, id=i))
    max_seq = PREFIX_LEN + max(PREFIX_TAILS) + PREFIX_NEW + 8

    def serve(backend):
        cache = CacheConfig(backend=backend, max_slots=SLOTS,
                            max_seq=max_seq, page_size=PREFIX_PAGE)
        eng = Engine(model, qparams, ServeConfig(cache=cache,
                                                 backend="ref"))
        sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK)
        sched.run(reqs)  # warm: compile prefill/decode (+gather/scatter)
        walls, toks = [], None
        for _ in range(repeats):
            sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK)
            t0 = time.perf_counter()
            res = sched.run(reqs)
            walls.append(time.perf_counter() - t0)
            toks = {r.id: r.tokens for r in res}
        return float(np.min(walls)), toks, eng.cache_backend.stats()

    d_min, d_toks, d_stats = serve("dense")
    p_min, p_toks, p_stats = serve("paged")
    if p_toks != d_toks:
        raise RuntimeError("paged tokens diverged from the dense oracle")
    if not (p_stats["prefix_hit_rate"] > 0.0
            and p_stats["prefill_tokens"] < d_stats["prefill_tokens"]
            and p_stats["prefill_launches"] <= d_stats["prefill_launches"]):
        raise RuntimeError(
            f"paged run shows no prefix reuse: paged={p_stats} "
            f"dense={d_stats}")
    n_toks = sum(len(t) for t in p_toks.values())
    out = {
        "prefix_dense_wall_min_s": round(d_min, 4),
        "paged_wall_min_s": round(p_min, 4),
        "paged_decode_toks_per_s": round(n_toks / p_min, 1),
        "prefix_hit_rate": round(p_stats["prefix_hit_rate"], 4),
        "page_utilization": round(p_stats["page_utilization"], 4),
        "prefix_prefill_tokens_dense": d_stats["prefill_tokens"],
        "prefix_prefill_tokens_paged": p_stats["prefill_tokens"],
        "prefix_cow_copies": p_stats["cow_copies"],
    }
    emit("serve_throughput.prefix.paged", p_min * 1e6,
         f"{n_toks / p_min:.0f} tok/s, hit rate "
         f"{p_stats['prefix_hit_rate']:.0%}, prefill tokens "
         f"{p_stats['prefill_tokens']} vs dense "
         f"{d_stats['prefill_tokens']}, steady-state page util "
         f"{p_stats['page_utilization']:.0%}")
    return out


def run_spec(model, qparams, repeats: int = 3) -> dict:
    """Self-speculative decode vs the plain continuous scheduler on a
    decode-heavy trace. The speculative run must emit bitwise-identical
    tokens to the non-spec oracle — greedy verification guarantees it by
    construction, and this benchmark hard-fails (not just regresses) the
    moment that guarantee breaks: a speculation speedup with different
    tokens is not serving the same model. Records end-to-end wall
    (``spec_wall_min_s``, gated), tok/s, the speedup over the non-spec
    baseline, and the effectiveness metrics the adaptive window is tuned
    by (acceptance rate, accepted tokens per slot-step, wasted-draft
    fraction)."""
    from repro.serve.kv_cache import CacheConfig

    rng = np.random.default_rng(17)
    reqs = [Request(rng.integers(2, SERVE_VOCAB, PROMPT).astype(np.int32),
                    max_new_tokens=SPEC_NEW, id=i)
            for i in range(SPEC_REQUESTS)]
    max_seq = PROMPT + SPEC_NEW + 8

    def serve(speculative):
        eng = Engine(model, qparams, ServeConfig(
            cache=CacheConfig(max_slots=SLOTS, max_seq=max_seq),
            backend="ref", speculative=speculative,
            draft_rank=SPEC_DRAFT_RANK, spec_k=SPEC_K))
        ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK).run(reqs)  # warm
        walls, toks, sched = [], None, None
        for _ in range(repeats):
            sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK)
            t0 = time.perf_counter()
            res = sched.run(reqs)
            walls.append(time.perf_counter() - t0)
            toks = {r.id: r.tokens for r in res}
        return float(np.min(walls)), toks, sched

    b_min, b_toks, _ = serve(False)
    s_min, s_toks, sched = serve(True)
    if s_toks != b_toks:
        raise RuntimeError(
            "speculative tokens diverged from the non-spec greedy oracle "
            "— the bitwise-parity contract is broken")
    st = sched.spec_stats()
    n_toks = sum(len(t) for t in s_toks.values())
    out = {
        "spec_base_wall_min_s": round(b_min, 4),
        "spec_wall_min_s": round(s_min, 4),
        "spec_decode_toks_per_s": round(n_toks / s_min, 1),
        "spec_vs_base_x": round(b_min / max(s_min, 1e-9), 3),
        "spec_acceptance_rate": round(st["acceptance_rate"], 4),
        "spec_accepted_per_step": round(st["accepted_per_step"], 3),
        "spec_wasted_draft_fraction": round(st["wasted_draft_fraction"], 4),
    }
    emit("serve_throughput.spec.decode", s_min * 1e6,
         f"{n_toks / s_min:.0f} tok/s, {out['spec_vs_base_x']:.2f}x vs "
         f"non-spec, acceptance {st['acceptance_rate']:.0%}, "
         f"{st['accepted_per_step']:.2f} tok/slot-step, wasted draft "
         f"{st['wasted_draft_fraction']:.0%}")
    return out


def run_multitenant(model, qparams, repeats: int = 3) -> dict:
    """Multi-tenant paged trace: TENANTS distinct system prompts with the
    request stream interleaved across them. Same hard-fail contract as
    the single-prefix workload — bitwise token parity with the dense
    oracle plus demonstrable reuse (non-zero hit rate, fewer prefill
    tokens) — but the trie now holds several live subtrees and every
    slot admission must match against the right tenant's prefix."""
    from repro.serve.kv_cache import CacheConfig

    rng = np.random.default_rng(29)
    prefixes = [rng.integers(2, SERVE_VOCAB, PREFIX_LEN).astype(np.int32)
                for _ in range(TENANTS)]
    reqs = []
    for i in range(TENANT_REQUESTS):
        tail = rng.integers(2, SERVE_VOCAB,
                            PREFIX_TAILS[i % len(PREFIX_TAILS)])
        reqs.append(Request(
            np.concatenate([prefixes[i % TENANTS], tail.astype(np.int32)]),
            max_new_tokens=PREFIX_NEW, id=i))
    max_seq = PREFIX_LEN + max(PREFIX_TAILS) + PREFIX_NEW + 8

    def serve(backend):
        cache = CacheConfig(backend=backend, max_slots=SLOTS,
                            max_seq=max_seq, page_size=PREFIX_PAGE)
        eng = Engine(model, qparams, ServeConfig(cache=cache,
                                                 backend="ref"))
        sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK)
        sched.run(reqs)  # warm
        walls, toks = [], None
        for _ in range(repeats):
            sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK)
            t0 = time.perf_counter()
            res = sched.run(reqs)
            walls.append(time.perf_counter() - t0)
            toks = {r.id: r.tokens for r in res}
        return float(np.min(walls)), toks, eng.cache_backend.stats()

    d_min, d_toks, d_stats = serve("dense")
    p_min, p_toks, p_stats = serve("paged")
    if p_toks != d_toks:
        raise RuntimeError(
            "multi-tenant paged tokens diverged from the dense oracle")
    if not (p_stats["prefix_hit_rate"] > 0.0
            and p_stats["prefill_tokens"] < d_stats["prefill_tokens"]):
        raise RuntimeError(
            f"multi-tenant paged run shows no prefix reuse: "
            f"paged={p_stats} dense={d_stats}")
    n_toks = sum(len(t) for t in p_toks.values())
    out = {
        "multitenant_dense_wall_min_s": round(d_min, 4),
        "multitenant_wall_min_s": round(p_min, 4),
        "multitenant_decode_toks_per_s": round(n_toks / p_min, 1),
        "multitenant_prefix_hit_rate": round(p_stats["prefix_hit_rate"], 4),
        "multitenant_prefill_tokens_dense": d_stats["prefill_tokens"],
        "multitenant_prefill_tokens_paged": p_stats["prefill_tokens"],
    }
    emit("serve_throughput.multitenant.paged", p_min * 1e6,
         f"{n_toks / p_min:.0f} tok/s, {TENANTS} tenants, hit rate "
         f"{p_stats['prefix_hit_rate']:.0%}, prefill tokens "
         f"{p_stats['prefill_tokens']} vs dense "
         f"{d_stats['prefill_tokens']}")
    return out


def run_obs_overhead(model, qparams, repeats: int = 3) -> dict:
    """Fully-instrumented vs obs-disabled serve on the same trace and the
    same warm engine: what span tracing (every prefill chunk, decode
    step, admit and retire) plus registry counters cost the serve loop.
    The traced export must validate as Chrome trace-event JSON and the
    registry must actually have recorded counters — an overhead number
    for instrumentation that silently no-opped would gate nothing."""
    import json

    from repro.obs import Obs
    from repro.obs.trace import validate_chrome_trace

    rng = np.random.default_rng(31)
    reqs = [Request(rng.integers(2, SERVE_VOCAB, PROMPT).astype(np.int32),
                    max_new_tokens=OBS_NEW, id=i)
            for i in range(OBS_REQUESTS)]
    eng = Engine(model, qparams, ServeConfig(
        max_slots=SLOTS, max_seq=PROMPT + OBS_NEW + 8, backend="ref"))
    ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK).run(reqs)  # warm

    def serve(obs_factory):
        walls, obs = [], None
        for _ in range(repeats):
            obs = obs_factory()
            sched = ContinuousScheduler(eng, prefill_chunk=MIX_CHUNK,
                                        obs=obs)
            t0 = time.perf_counter()
            sched.run(reqs)
            walls.append(time.perf_counter() - t0)
        return float(np.min(walls)), obs

    off_min, _ = serve(Obs.disabled)
    on_min, obs = serve(lambda: Obs(trace=True))
    errs = validate_chrome_trace(json.loads(obs.tracer.to_json()))
    if errs:
        raise RuntimeError(
            f"instrumented serve exported an invalid Chrome trace: "
            f"{errs[:3]}")
    if not obs.registry.snapshot()["counters"]:
        raise RuntimeError("instrumented serve recorded no counters")
    out = {
        "obs_off_wall_min_s": round(off_min, 4),
        "obs_on_wall_min_s": round(on_min, 4),
        "obs_overhead_x": round(on_min / max(off_min, 1e-9), 3),
        "obs_trace_events": len(obs.tracer.events),
    }
    emit("serve_throughput.obs.overhead", on_min * 1e6,
         f"instrumented/bare {out['obs_overhead_x']:.3f}x, "
         f"{out['obs_trace_events']} trace events")
    return out


def run_chaos(model, qparams, repeats: int = 3) -> dict:
    """Recovery-overhead measurement: the supervised fleet serves the
    chaos trace twice — fault-free, then with replica 0 killed mid-decode
    at a fixed step — on the SAME engine pool (the factory cycles through
    pre-built engines so repeats and the A/B share compiled executables).
    Every faulted run must reconcile to zero drops with all-ok statuses
    and at least one restart, or the benchmark hard-fails: a chaos number
    that quietly shed work would be flattering fiction."""
    import itertools

    from repro.obs.stats import nearest_percentile
    from repro.serve.faults import FaultPlan
    from repro.serve.supervisor import Supervisor, SupervisorConfig

    repeats = min(repeats, 3)  # two supervised fleets per repeat: cap cost
    rng = np.random.default_rng(11)
    reqs = []
    for i in range(CHAOS_REQUESTS):
        plen = int(rng.integers(MIX_PROMPT_MIN, MIX_PROMPT_MAX + 1))
        new = int(rng.integers(MIX_NEW_MIN, MIX_NEW_MAX + 1))
        reqs.append(Request(rng.integers(2, SERVE_VOCAB, plen)
                            .astype(np.int32), max_new_tokens=new, id=i))
    pool = [Engine(model, qparams, ServeConfig(
        max_slots=SLOTS, max_seq=MIX_MAX_SEQ, backend="ref"))
        for _ in range(CHAOS_REPLICAS)]
    counter = itertools.count()

    def factory():
        return pool[next(counter) % CHAOS_REPLICAS]

    def sup_cfg():
        return SupervisorConfig(replicas=CHAOS_REPLICAS,
                                prefill_chunk=MIX_CHUNK,
                                backoff_base_s=0.01)

    Supervisor(factory, sup_cfg()).serve(reqs)  # warm: compile both pools
    nofault_walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        rep = Supervisor(factory, sup_cfg()).serve(reqs)
        nofault_walls.append(time.perf_counter() - t0)
        if not rep.zero_drops:
            raise RuntimeError(f"no-fault fleet dropped requests: "
                               f"{rep.status_counts()}")
    fault_walls, fracs, ttfts = [], [], []
    for _ in range(repeats):
        sup = Supervisor(factory, sup_cfg(),
                         fault_plan=FaultPlan.parse(CHAOS_PLAN))
        t0 = time.perf_counter()
        rep = sup.serve(reqs)
        fault_walls.append(time.perf_counter() - t0)
        counts = rep.status_counts()
        if not rep.zero_drops or set(counts) != {"ok"} or \
                sum(rep.restarts.values()) < 1:
            raise RuntimeError(
                f"chaos run invalid: statuses={dict(counts)} "
                f"restarts={rep.restarts} drops="
                f"{rep.submitted - len(rep.outcomes)}")
        fracs.append(rep.wasted_token_fraction)
        ttfts.extend(o.ttft_s for o in rep.outcomes)

    n_min, f_min = float(np.min(nofault_walls)), float(np.min(fault_walls))
    out = {
        "chaos_nofault_wall_min_s": round(n_min, 4),
        "chaos_recovery_wall_min_s": round(f_min, 4),
        "chaos_recovery_overhead_x": round(f_min / max(n_min, 1e-9), 3),
        "chaos_wasted_token_fraction": round(float(np.max(fracs)), 4),
        "chaos_ttft_p95_s": round(nearest_percentile(ttfts, 0.95), 4),
    }
    emit("serve_throughput.chaos.recovery", f_min * 1e6,
         f"kill+restart overhead {out['chaos_recovery_overhead_x']:.2f}x "
         f"vs no-fault fleet, wasted tokens "
         f"{out['chaos_wasted_token_fraction']:.1%}")
    return out


def run_proc_chaos(model, repeats: int = 1) -> dict:
    """Cross-process recovery measurement: worker subprocesses + durable
    journal, with a worker SIGKILL and a supervisor crash mid-serve.
    Recovery pays real spawn + deterministic re-quantization + journal
    replay. The no-fault process run doubles as the bitwise oracle; the
    faulted run must reconcile to zero drops, all-ok, and exactly-once
    streams or the benchmark hard-fails."""
    import pathlib
    import tempfile

    from repro.serve.faults import FaultPlan
    from repro.serve.journal import Journal
    from repro.serve.supervisor import (Supervisor, SupervisorConfig,
                                        SupervisorCrash)
    from repro.serve.worker import WorkerSpec, model_config_to_dict

    repeats = min(repeats, 1)   # every faulted run spawns ~5 worker
                                # processes, each paying real model build
                                # + re-quantization + compile (~2min on
                                # the CPU proxy): one honest measurement
    rng = np.random.default_rng(13)
    reqs = []
    for i in range(PROC_CHAOS_REQUESTS):
        plen = int(rng.integers(MIX_PROMPT_MIN, MIX_PROMPT_MAX + 1))
        new = int(rng.integers(MIX_NEW_MIN, MIX_NEW_MAX + 1))
        reqs.append(Request(rng.integers(2, SERVE_VOCAB, plen)
                            .astype(np.int32), max_new_tokens=new, id=i))
    spec = WorkerSpec(
        model=model_config_to_dict(model.cfg),
        serve=ServeConfig(max_slots=SLOTS, max_seq=MIX_MAX_SEQ,
                          backend="ref").to_dict(),
        seed=0, quantize_bits=BITS, blc_epochs=1, max_rank=16,
        prefill_chunk=MIX_CHUNK)

    def sup_cfg():
        return SupervisorConfig(replicas=PROC_CHAOS_REPLICAS,
                                prefill_chunk=MIX_CHUNK,
                                backoff_base_s=0.01, backoff_jitter=0.0)

    # no-fault process run: the bitwise oracle AND the overhead baseline
    t0 = time.perf_counter()
    with Supervisor(cfg=sup_cfg(), fleet="procs",
                    worker_spec=spec) as sup:
        base = sup.serve(reqs)
    nofault_wall = time.perf_counter() - t0
    if not base.zero_drops or set(base.status_counts()) != {"ok"}:
        raise RuntimeError(f"no-fault process fleet invalid: "
                           f"{dict(base.status_counts())}")
    oracle = {o.id: o.tokens for o in base.outcomes}

    fault_walls, replayed_fracs = [], []
    for _ in range(repeats):
        streams = {}
        resumed_tokens = [0]

        def on_token(rid, tok, done):
            streams.setdefault(rid, []).append(tok)

        def on_replay(rid, prefix):
            streams[rid] = list(prefix)
            resumed_tokens[0] += len(prefix)
        with tempfile.TemporaryDirectory() as td:
            jp = pathlib.Path(td) / "wal.journal"
            replayed = 0
            t0 = time.perf_counter()
            sup = Supervisor(cfg=sup_cfg(), fleet="procs", worker_spec=spec,
                             journal=Journal(jp), on_token=on_token,
                             fault_plan=FaultPlan.parse(PROC_CHAOS_PLAN))
            try:
                with sup:
                    rep = sup.serve(reqs)
                raise RuntimeError(
                    "supervisor_crash coordinate never fired — the "
                    "workload no longer covers supervisor recovery")
            except SupervisorCrash:
                replayed += sup.replayed_emitted_tokens
                sup2 = Supervisor(cfg=sup_cfg(), fleet="procs",
                                  worker_spec=spec, journal=Journal(jp),
                                  on_token=on_token, on_replay=on_replay)
                with sup2:
                    rep = sup2.resume()
                # tokens that rode a resume prompt: pre-crash worker-kill
                # salvage + journal re-admits + post-resume salvage
                replayed += resumed_tokens[0]
                replayed += sup2.replayed_emitted_tokens
            wall = time.perf_counter() - t0
        counts = rep.status_counts()
        if not rep.zero_drops or set(counts) != {"ok"}:
            raise RuntimeError(f"process-chaos run invalid: "
                               f"statuses={dict(counts)}")
        for o in rep.outcomes:
            if o.tokens != oracle[o.id] or streams[o.id] != oracle[o.id]:
                raise RuntimeError(
                    f"request {o.id}: tokens/stream diverged from the "
                    "no-fault oracle (duplicate or lost token)")
        fault_walls.append(wall)
        useful = rep.useful_tokens
        replayed_fracs.append(replayed / max(replayed + useful, 1))

    # journal fsync overhead: worst-case one fsync per record
    fsync_walls = []
    for _ in range(3):
        with tempfile.TemporaryDirectory() as td:
            j = Journal(pathlib.Path(td) / "wal.journal")
            t0 = time.perf_counter()
            for i in range(JOURNAL_RECORDS):
                j.append({"t": "emit", "id": i % 8, "i": i, "toks": [7] * 8})
                j.flush()
            fsync_walls.append(time.perf_counter() - t0)
            j.close()

    f_min = float(np.min(fault_walls))
    out = {
        "proc_chaos_nofault_wall_min_s": round(nofault_wall, 4),
        "proc_chaos_recovery_wall_min_s": round(f_min, 4),
        "proc_chaos_recovery_overhead_x":
            round(f_min / max(nofault_wall, 1e-9), 3),
        "proc_chaos_replayed_fraction":
            round(float(np.max(replayed_fracs)), 4),
        "journal_fsync_us_per_record":
            round(float(np.min(fsync_walls)) / JOURNAL_RECORDS * 1e6, 1),
    }
    emit("serve_throughput.proc_chaos.recovery", f_min * 1e6,
         f"sigkill+supervisor-crash overhead "
         f"{out['proc_chaos_recovery_overhead_x']:.2f}x vs no-fault "
         f"process fleet, replayed {out['proc_chaos_replayed_fraction']:.1%}, "
         f"fsync {out['journal_fsync_us_per_record']:.0f}us/record")
    return out


def _build():
    cfg = dataclasses.replace(
        PAPER_PROXIES["opt-proxy-25m"], n_layers=SERVE_L, d_model=SERVE_D,
        n_heads=4, n_kv_heads=4, head_dim=SERVE_D // 4, d_ff=SERVE_FF,
        vocab=SERVE_VOCAB)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=BITS, blc_epochs=1, max_rank=16))
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(2, SERVE_VOCAB, PROMPT).astype(np.int32),
                    max_new_tokens=NEW_TOKENS, id=i) for i in range(SLOTS)]
    return model, qparams, reqs


def run_bench(repeats: int = 3, include_fused: bool = True,
              include_mixed: bool = True,
              include_chaos: bool = True,
              include_prefix: bool = True,
              include_spec: bool = True,
              include_multitenant: bool = True,
              include_proc_chaos: bool = True,
              include_obs: bool = True) -> dict:
    """Measure every variant; returns the record appended to the
    BENCH_quant_time.json trajectory."""
    model, qparams, reqs = _build()
    record = dict(proxy=workload_descriptor(),
                  backend=jax.default_backend(), host=host_family())

    for name, scan, backend, interpret in VARIANTS:
        if name == "fused_interpret" and not include_fused:
            continue
        eng = Engine(model.with_scan(scan), qparams, ServeConfig(
            max_slots=SLOTS, max_seq=PROMPT + NEW_TOKENS + 8,
            backend=backend, interpret=interpret))
        t0 = time.perf_counter()
        eng.generate(reqs)  # warm: compile prefill + decode
        record[f"compile_{name}_s"] = round(time.perf_counter() - t0, 2)
        prefills, decodes = [], []
        for _ in range(repeats):
            res = eng.generate(reqs)
            prefills.append(res[0].prefill_s)
            # drain time (max over requests): Result.decode_s is now
            # per-request EOS-truncated — the gated metric must not
            # silently shrink if a future tweak makes request 0 EOS early
            decodes.append(max(r.decode_s for r in res))
        p_min, d_min = float(np.min(prefills)), float(np.min(decodes))
        prefill_toks = SLOTS * PROMPT
        decode_toks = SLOTS * (NEW_TOKENS - 1)  # first token is prefill's
        record[f"prefill_{name}_min_s"] = round(p_min, 4)
        record[f"decode_{name}_min_s"] = round(d_min, 4)
        record[f"decode_{name}_tok_s"] = round(decode_toks / d_min, 1)
        emit(f"serve_throughput.{name}.prefill", p_min * 1e6,
             f"{prefill_toks / p_min:.0f} tok/s")
        emit(f"serve_throughput.{name}.decode", d_min * 1e6,
             f"{decode_toks / d_min:.0f} tok/s")

    if "decode_unroll_ref_min_s" in record and \
            "decode_scan_ref_min_s" in record:
        emit("serve_throughput.scan_vs_unroll",
             record["decode_scan_ref_min_s"] * 1e6,
             f"decode scan/unroll "
             f"{record['decode_unroll_ref_min_s'] / record['decode_scan_ref_min_s']:.2f}x")
    emit_bench_json("quant_time", record)
    if include_mixed:
        mixed = dict(proxy=mixed_workload_descriptor(),
                     backend=jax.default_backend(), host=host_family())
        mixed.update(run_mixed(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", mixed)
        # merged view for callers (the gate reads per-metric records by
        # their own proxies; the merge keys do not collide)
        record.update(mixed)
        record["proxy"] = workload_descriptor()
    if include_chaos:
        chaos = dict(proxy=chaos_workload_descriptor(),
                     backend=jax.default_backend(), host=host_family())
        chaos.update(run_chaos(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", chaos)
        record.update(chaos)
        record["proxy"] = workload_descriptor()
    if include_prefix:
        pref = dict(proxy=prefix_workload_descriptor(),
                    backend=jax.default_backend(), host=host_family())
        pref.update(run_prefix(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", pref)
        record.update(pref)
        record["proxy"] = workload_descriptor()
    if include_spec:
        spec = dict(proxy=spec_workload_descriptor(),
                    backend=jax.default_backend(), host=host_family())
        spec.update(run_spec(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", spec)
        record.update(spec)
        record["proxy"] = workload_descriptor()
    if include_multitenant:
        mt = dict(proxy=multitenant_workload_descriptor(),
                  backend=jax.default_backend(), host=host_family())
        mt.update(run_multitenant(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", mt)
        record.update(mt)
        record["proxy"] = workload_descriptor()
    if include_proc_chaos:
        pc = dict(proxy=proc_chaos_workload_descriptor(),
                  backend=jax.default_backend(), host=host_family())
        pc.update(run_proc_chaos(model, repeats=repeats))
        emit_bench_json("quant_time", pc)
        record.update(pc)
        record["proxy"] = workload_descriptor()
    if include_obs:
        ob = dict(proxy=obs_workload_descriptor(),
                  backend=jax.default_backend(), host=host_family())
        ob.update(run_obs_overhead(model, qparams, repeats=repeats))
        emit_bench_json("quant_time", ob)
        record.update(ob)
        record["proxy"] = workload_descriptor()
    return record


def run():
    run_bench()


if __name__ == "__main__":
    run()
