"""Serving-throughput benchmark: tokens/s through ``serve.Engine`` on an
FLRQ-W4 proxy model, across the quantized runtime's execution variants:

  * ``unroll_ref`` — scan_layers=False, backend="ref": L per-layer pytree
    dispatches per step (the pre-runtime reference execution).
  * ``scan_ref``   — scan_layers=True, backend="ref": ONE compiled layer
    body scanned over the stacked QuantizedLinear weights (the default
    serving path).
  * ``fused_interpret`` — scanned + backend="fused" in Pallas interpret
    mode: exercises the fused-kernel serving path end-to-end off-TPU.
    Interpret mode is a *validation* execution, not a performance number —
    it is recorded for trajectory shape/coverage, never gated on.

Each variant reports prefill and decode tokens/s; the record lands in the
BENCH_quant_time.json trajectory and ``benchmarks.gate --bench serve``
gates the scanned-ref decode wall time (min-of-repeats).

    PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig

from .common import emit, emit_bench_json
from .quant_time import host_family

# CPU-feasible serving proxy (kept small enough that the interpret-mode
# kernel variant stays in CI budget).
SERVE_L = 4
SERVE_D = 256
SERVE_FF = 512
SERVE_VOCAB = 1024
SLOTS = 4
PROMPT = 16
NEW_TOKENS = 24
BITS = 4

VARIANTS = (
    ("unroll_ref", False, "ref", None),
    ("scan_ref", True, "ref", None),
    ("fused_interpret", True, "fused", True),
)


def workload_descriptor() -> dict:
    """The gate's comparability key: a changed serving workload re-baselines
    instead of comparing against a different experiment."""
    return dict(kind="serve", layers=SERVE_L, d_model=SERVE_D,
                d_ff=SERVE_FF, vocab=SERVE_VOCAB, slots=SLOTS,
                prompt=PROMPT, new_tokens=NEW_TOKENS, bits=BITS)


def _build():
    cfg = dataclasses.replace(
        PAPER_PROXIES["opt-proxy-25m"], n_layers=SERVE_L, d_model=SERVE_D,
        n_heads=4, n_kv_heads=4, head_dim=SERVE_D // 4, d_ff=SERVE_FF,
        vocab=SERVE_VOCAB)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams, _ = quantize_model_stacked(
        params, None, FLRQConfig(bits=BITS, blc_epochs=1, max_rank=16))
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(2, SERVE_VOCAB, PROMPT).astype(np.int32),
                    max_new_tokens=NEW_TOKENS, id=i) for i in range(SLOTS)]
    return model, qparams, reqs


def run_bench(repeats: int = 3, include_fused: bool = True) -> dict:
    """Measure every variant; returns the record appended to the
    BENCH_quant_time.json trajectory."""
    model, qparams, reqs = _build()
    record = dict(proxy=workload_descriptor(),
                  backend=jax.default_backend(), host=host_family())

    for name, scan, backend, interpret in VARIANTS:
        if name == "fused_interpret" and not include_fused:
            continue
        eng = Engine(model.with_scan(scan), qparams, ServeConfig(
            max_slots=SLOTS, max_seq=PROMPT + NEW_TOKENS + 8,
            backend=backend, interpret=interpret))
        t0 = time.perf_counter()
        eng.generate(reqs)  # warm: compile prefill + decode
        record[f"compile_{name}_s"] = round(time.perf_counter() - t0, 2)
        prefills, decodes = [], []
        for _ in range(repeats):
            res = eng.generate(reqs)
            prefills.append(res[0].prefill_s)
            decodes.append(res[0].decode_s)
        p_min, d_min = float(np.min(prefills)), float(np.min(decodes))
        prefill_toks = SLOTS * PROMPT
        decode_toks = SLOTS * (NEW_TOKENS - 1)  # first token is prefill's
        record[f"prefill_{name}_min_s"] = round(p_min, 4)
        record[f"decode_{name}_min_s"] = round(d_min, 4)
        record[f"decode_{name}_tok_s"] = round(decode_toks / d_min, 1)
        emit(f"serve_throughput.{name}.prefill", p_min * 1e6,
             f"{prefill_toks / p_min:.0f} tok/s")
        emit(f"serve_throughput.{name}.decode", d_min * 1e6,
             f"{decode_toks / d_min:.0f} tok/s")

    if "decode_unroll_ref_min_s" in record and \
            "decode_scan_ref_min_s" in record:
        emit("serve_throughput.scan_vs_unroll",
             record["decode_scan_ref_min_s"] * 1e6,
             f"decode scan/unroll "
             f"{record['decode_unroll_ref_min_s'] / record['decode_scan_ref_min_s']:.2f}x")
    emit_bench_json("quant_time", record)
    return record


def run():
    run_bench()


if __name__ == "__main__":
    run()
