"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSON.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_baseline.json
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_t(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s*1e3:.1f}ms"


def render(rows, multi_pod=False, quantized=None):
    sel = [r for r in rows
           if r["multi_pod"] == multi_pod
           and (quantized is None or r.get("quantized", False) == quantized)]
    sel.sort(key=lambda r: (r["arch"], r["shape"]))
    out = []
    out.append("| arch | shape | status | t_compute | t_memory | t_collective "
               "| bound | useful-FLOPs ratio | roofline frac | per-dev mem |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sel:
        if r["status"] != "OK":
            reason = r.get("reason") or r.get("error", "")
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} — "
                       f"{reason} | | | | | | | |")
            continue
        ro = r["roofline"]
        mem = r["memory"]
        # memory_analysis reports per-device sizes (verified: grok-1 train
        # args 12.37 GB = 3.14 TB state / 256 chips)
        per_dev = mem["argument"] + mem["temp"] + mem["output"]
        out.append(
            f"| {r['arch']} | {r['shape']} | OK "
            f"| {fmt_t(ro['t_compute'])} | {fmt_t(ro['t_memory'])} "
            f"| {fmt_t(ro['t_collective'])} | {ro['bottleneck']} "
            f"| {ro['useful_flops_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} | {per_dev/1e9:.2f}GB |")
    return "\n".join(out)


def summary(rows):
    ok = [r for r in rows if r["status"] == "OK"]
    by_bound = defaultdict(int)
    for r in ok:
        by_bound[r["roofline"]["bottleneck"]] += 1
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    lines = [f"cells OK: {len(ok)}; bound distribution: {dict(by_bound)}",
             "worst roofline fractions:"]
    for r in worst:
        lines.append(f"  {r['arch']} × {r['shape']} "
                     f"(mp={r['multi_pod']}, q={r.get('quantized', False)}): "
                     f"{r['roofline']['roofline_fraction']:.3f} "
                     f"[{r['roofline']['bottleneck']}]")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json"
    rows = json.load(open(path))
    print("## Single-pod (16×16 = 256 chips)\n")
    print(render(rows, multi_pod=False))
    print("\n## Multi-pod (2×16×16 = 512 chips)\n")
    print(render(rows, multi_pod=True))
    print("\n## Summary\n")
    print(summary(rows))


if __name__ == "__main__":
    main()
