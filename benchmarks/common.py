"""Shared benchmark helpers: synthetic LLM-like weights, timing, CSV, and
the BENCH_*.json metric trajectory."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def llm_weight(key, m, n, rank_structure=16, outlier_frac=0.003):
    """Weight with geometric spectrum + channel outliers (the structure
    FLRQ exploits; matches published LLM weight statistics qualitatively)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    base = jax.random.normal(k1, (m, n)) * 0.02
    sv = 2.0 ** -jnp.arange(rank_structure)
    u = jax.random.normal(k2, (m, rank_structure))
    v = jax.random.normal(k3, (rank_structure, n))
    w = base + (u * sv) @ v * 0.4
    # heavy channel outliers (the amax drivers)
    mask = jax.random.uniform(k4, (n,)) < outlier_frac
    return w * (1 + 7.0 * mask)


def calib_activations(key, tokens, n, outlier_frac=0.01):
    x = jax.random.normal(key, (tokens, n))
    mask = jax.random.uniform(jax.random.PRNGKey(17), (n,)) < outlier_frac
    return x * (1 + 5.0 * mask)


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds; blocks on jax outputs."""
    (_, med), out = time_fn_min(fn, *args, repeats=repeats, warmup=warmup,
                                **kw)
    return med, out


def time_fn_min(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw):
    """((min, median) wall time in seconds, out). The min is the
    noise-robust statistic — on shared machines the median of a few
    repeats can swing ±50% with interference, while the fastest repeat
    tracks the true cost; regression gates should compare mins."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return (float(np.min(ts)), float(np.median(ts))), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_bench_json(bench: str, record: dict):
    """Append ``record`` to BENCH_<bench>.json at the repo root — a JSON
    list forming the metric trajectory across PRs (each run appends one
    timestamped entry; regressions show up as a visible downward step)."""
    path = os.path.join(_REPO_ROOT, f"BENCH_{bench}.json")
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            history = loaded if isinstance(loaded, list) else [loaded]
        except json.JSONDecodeError:
            # Preserve the unreadable trajectory instead of clobbering it.
            os.replace(path, path + ".corrupt")
    entry = dict(record)
    entry["ts"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# wrote {os.path.basename(path)} ({len(history)} entries)")
