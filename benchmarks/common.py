"""Shared benchmark helpers: synthetic LLM-like weights, timing, CSV."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def llm_weight(key, m, n, rank_structure=16, outlier_frac=0.003):
    """Weight with geometric spectrum + channel outliers (the structure
    FLRQ exploits; matches published LLM weight statistics qualitatively)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    base = jax.random.normal(k1, (m, n)) * 0.02
    sv = 2.0 ** -jnp.arange(rank_structure)
    u = jax.random.normal(k2, (m, rank_structure))
    v = jax.random.normal(k3, (rank_structure, n))
    w = base + (u * sv) @ v * 0.4
    # heavy channel outliers (the amax drivers)
    mask = jax.random.uniform(k4, (n,)) < outlier_frac
    return w * (1 + 7.0 * mask)


def calib_activations(key, tokens, n, outlier_frac=0.01):
    x = jax.random.normal(key, (tokens, n))
    mask = jax.random.uniform(jax.random.PRNGKey(17), (n,)) < outlier_frac
    return x * (1 + 5.0 * mask)


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
