"""Run every benchmark (one per paper table/figure).

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Output: ``name,us_per_call,derived`` CSV lines per benchmark, with a
summary footer. Roofline terms for the 40 (arch × shape) dry-run cells are
produced by ``repro.launch.dryrun`` (they need 512 forced devices and are
kept out of this CPU-sized harness); see EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    blc_ablation,
    kernel_throughput,
    memory_sweep,
    method_quality,
    quant_time,
    rank_error,
    serve_throughput,
    sketch_speed,
    vs_lqer,
)

BENCHES = [
    ("rank_error (Fig.2/4)", rank_error.run),
    ("method_quality (Table 2)", method_quality.run),
    ("sketch_speed (Tables 7/12, Fig.6)", sketch_speed.run),
    ("memory_sweep (Tables 3/19/21)", memory_sweep.run),
    ("blc_ablation (Tables 10/22, Fig.13)", blc_ablation.run),
    ("vs_lqer (Tables 4/18)", vs_lqer.run),
    ("quant_time (Table 8)", quant_time.run),
    ("kernel_throughput (Fig.3)", kernel_throughput.run),
    ("serve_throughput (serving runtime)", serve_throughput.run),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===")
        try:
            fn()
            print(f"# {name}: done in {time.time()-t0:.1f}s")
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n# {traceback.format_exc()}")
    print(f"# summary: {len(BENCHES)-failures}/{len(BENCHES)} benchmarks ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
