"""Paper Tables 10/22 + Fig. 13: BLC ablation and epoch convergence.

Claims reproduced: (a) BLC improves error at every bit width, most at
2-bit; (b) the error trace converges within ~1 epoch at 3/4-bit and needs
~10–20 epochs at 2-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.blc import blc
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import QuantSpec

from .common import calib_activations, llm_weight, emit


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, 512, 1024)
    x = calib_activations(jax.random.PRNGKey(1), 64, 1024)

    for bits in (4, 3, 2):
        _, st_no = quantize_matrix(
            w, x, FLRQConfig(bits=bits, use_blc=False, max_rank=48), key)
        _, st_yes = quantize_matrix(
            w, x, FLRQConfig(bits=bits, use_blc=True,
                             blc_epochs=4 if bits > 2 else 12,
                             max_rank=48), key)
        gain = st_no.err_after / max(st_yes.err_after, 1e-12)
        emit(f"blc_ablation.w{bits}.no_blc", st_no.err_after * 1e6, "rel err x1e-6")
        emit(f"blc_ablation.w{bits}.blc", st_yes.err_after * 1e6,
             f"gain={gain:.2f}x")

    # epoch trace (paper Fig. 13)
    res = blc(w, x.T, key, QuantSpec(2, 128), rank=24, epochs=16)
    tr = [float(t) for t in res.err_trace]
    emit("blc_ablation.trace_epoch0", tr[0] * 1e6, "")
    emit("blc_ablation.trace_epoch4", tr[min(4, len(tr) - 1)] * 1e6, "")
    emit("blc_ablation.trace_final", tr[-1] * 1e6,
         f"reduction={tr[0]/max(tr[-1],1e-12):.2f}x over {len(tr)-1} epochs")
    res3 = blc(w, x.T, key, QuantSpec(4, 128), rank=24, epochs=8)
    tr3 = [float(t) for t in res3.err_trace]
    conv_by_1 = abs(tr3[1] - min(tr3)) / max(min(tr3), 1e-12) < 0.1
    emit("blc_ablation.w4_converged_by_epoch1", int(conv_by_1),
         "paper Table 22")


if __name__ == "__main__":
    run()
