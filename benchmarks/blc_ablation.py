"""Paper Tables 10/22 + Fig. 13: BLC ablation and epoch convergence.

Claims reproduced: (a) BLC improves error at every bit width, most at
2-bit; (b) the error trace converges within ~1 epoch at 3/4-bit and needs
~10–20 epochs at 2-bit.

Plus the clip-grid sweep benchmark (``run_clip_sweep``): the one-pass
hoisted sweep (group range stats computed once per epoch, Frobenius
objective scored as Σd² instead of through a materialized eye(n) GEMM)
vs the seed ``lax.map`` formulation that re-reduced and re-GEMMed the full
matrix once per grid point. Wall times land in the BENCH_quant_time.json
trajectory (the acceptance record for the ≥2× clip-search win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.blc import _best_clip_quant, blc
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import DEFAULT_CLIP_GRID, QuantSpec
from repro.kernels import ref as kernels_ref

from .common import (calib_activations, emit, emit_bench_json, llm_weight,
                     time_fn_min)

# CPU proxy for the clip sweep: one layer of an 8k-class model scaled to
# CI-feasible width, with the paper's 8-point grid.
CLIP_M, CLIP_N, CLIP_B = 1024, 2048, 64


def run_clip_sweep(repeats: int = 3):
    """Seed lax.map clip search vs the hoisted one-pass sweep, calibrated
    and Frobenius objectives. Returns the BENCH record."""
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, CLIP_M, CLIP_N)
    x = calib_activations(jax.random.PRNGKey(1), CLIP_B, CLIP_N).T
    spec = QuantSpec(4, 128, False)
    grid = DEFAULT_CLIP_GRID

    # jit both sides: the seed ran inside jitted BLC, so the honest
    # comparison compiles the seed formulation the same way; both sides do
    # the full job (score every clip, re-quantize once at the argmin)
    from repro.core.quantize import pseudo_quantize
    garr = jnp.asarray(grid, jnp.float32)

    def _seed_best(w_, x_):
        errs = kernels_ref.clip_errors_ref(w_, x_, clips=grid, bits=4)
        return pseudo_quantize(w_, spec, garr[jnp.argmin(errs)])

    seed_jit = jax.jit(lambda w_, x_: _seed_best(w_, x_))
    seed_frob_jit = jax.jit(lambda w_: _seed_best(w_, None))

    def seed_calib():
        return seed_jit(w, x)

    def seed_frob():  # the seed scored no-calib through eye(n)
        return seed_frob_jit(w)

    swept = jax.jit(lambda w, x: _best_clip_quant(w, x, spec, grid)[0])
    swept_frob = jax.jit(lambda w: _best_clip_quant(w, None, spec, grid)[0])

    (t_seed_c, _), _ = time_fn_min(seed_calib, repeats=repeats)
    (t_new_c, _), _ = time_fn_min(lambda: swept(w, x), repeats=repeats)
    (t_seed_f, _), _ = time_fn_min(seed_frob, repeats=max(2, repeats - 1))
    (t_new_f, _), _ = time_fn_min(lambda: swept_frob(w), repeats=repeats)

    emit("blc_ablation.clip_sweep.seed_calib", t_seed_c * 1e6,
         f"{CLIP_M}x{CLIP_N} b={CLIP_B} grid={len(grid)}")
    emit("blc_ablation.clip_sweep.hoisted_calib", t_new_c * 1e6,
         f"{t_seed_c / t_new_c:.2f}x vs seed")
    emit("blc_ablation.clip_sweep.seed_frob", t_seed_f * 1e6,
         "eye(n) objective GEMM")
    emit("blc_ablation.clip_sweep.hoisted_frob", t_new_f * 1e6,
         f"{t_seed_f / t_new_f:.2f}x vs seed")
    record = dict(
        proxy=dict(clip_sweep=[CLIP_M, CLIP_N, CLIP_B],
                   grid=len(grid)),
        clip_seed_calib_s=round(t_seed_c, 4),
        clip_hoisted_calib_s=round(t_new_c, 4),
        clip_calib_speedup=round(t_seed_c / t_new_c, 2),
        clip_seed_frob_s=round(t_seed_f, 4),
        clip_hoisted_frob_s=round(t_new_f, 4),
        clip_frob_speedup=round(t_seed_f / t_new_f, 2),
        backend=jax.default_backend(),
    )
    from .quant_time import host_family
    record["host"] = host_family()
    emit_bench_json("quant_time", record)
    return record


def run():
    key = jax.random.PRNGKey(0)
    w = llm_weight(key, 512, 1024)
    x = calib_activations(jax.random.PRNGKey(1), 64, 1024)

    for bits in (4, 3, 2):
        _, st_no = quantize_matrix(
            w, x, FLRQConfig(bits=bits, use_blc=False, max_rank=48), key)
        _, st_yes = quantize_matrix(
            w, x, FLRQConfig(bits=bits, use_blc=True,
                             blc_epochs=4 if bits > 2 else 12,
                             max_rank=48), key)
        gain = st_no.err_after / max(st_yes.err_after, 1e-12)
        emit(f"blc_ablation.w{bits}.no_blc", st_no.err_after * 1e6, "rel err x1e-6")
        emit(f"blc_ablation.w{bits}.blc", st_yes.err_after * 1e6,
             f"gain={gain:.2f}x")

    # epoch trace (paper Fig. 13)
    res = blc(w, x.T, key, QuantSpec(2, 128), rank=24, epochs=16)
    tr = [float(t) for t in res.err_trace]
    emit("blc_ablation.trace_epoch0", tr[0] * 1e6, "")
    emit("blc_ablation.trace_epoch4", tr[min(4, len(tr) - 1)] * 1e6, "")
    emit("blc_ablation.trace_final", tr[-1] * 1e6,
         f"reduction={tr[0]/max(tr[-1],1e-12):.2f}x over {len(tr)-1} epochs")
    res3 = blc(w, x.T, key, QuantSpec(4, 128), rank=24, epochs=8)
    tr3 = [float(t) for t in res3.err_trace]
    conv_by_1 = abs(tr3[1] - min(tr3)) / max(min(tr3), 1e-12) < 0.1
    emit("blc_ablation.w4_converged_by_epoch1", int(conv_by_1),
         "paper Table 22")

    run_clip_sweep()


if __name__ == "__main__":
    run()
