"""Serve a model with FLRQ-quantized weights through the batched engine
and compare tokens/s + greedy agreement vs the fp baseline.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    cfg = dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], n_layers=4)
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    qparams, stats = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=16))
    n_bytes = lambda t: sum(x.size * x.dtype.itemsize
                            for x in jax.tree.leaves(t))
    print(f"fp params: {n_bytes(params)/1e6:.1f}MB -> "
          f"quantized: {n_bytes(qparams)/1e6:.1f}MB")

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(2, cfg.vocab, size=12).astype(np.int32),
                    max_new_tokens=16, id=i) for i in range(8)]

    scfg = ServeConfig(max_slots=4, max_seq=64)
    for tag, p in (("fp", params), ("flrq-w4", qparams)):
        eng = Engine(model, p, scfg)
        t0 = time.time()
        res = eng.generate(reqs)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in res)
        print(f"{tag}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s incl. compile)")
        if tag == "fp":
            ref = {r.id: r.tokens for r in res}
        else:
            agree = np.mean([
                np.mean([a == b for a, b in zip(ref[r.id], r.tokens)])
                for r in res])
            print(f"greedy agreement with fp: {agree*100:.0f}%")


if __name__ == "__main__":
    main()
