"""Serve a model with FLRQ-quantized weights through the batched engine
and compare tokens/s + greedy agreement vs the fp baseline.

    PYTHONPATH=src python examples/serve_quantized.py

``--fleet procs`` instead serves the quantized model through the
cross-process replica fleet (worker subprocesses + framed RPC +
durable journal) and scripts a mid-serve worker SIGKILL plus a
supervisor crash — then auto-resumes from the journal and shows that
every request still finished exactly-once with the same tokens:

    PYTHONPATH=src python examples/serve_quantized.py --fleet procs
"""
import argparse
import dataclasses
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked
from repro.serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", default="inproc", choices=("inproc", "procs"),
                    help="procs: serve through worker subprocesses with a "
                         "scripted SIGKILL + supervisor crash + journal "
                         "resume")
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"], n_layers=4)
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    qparams, stats = quantize_model_stacked(
        params, None, FLRQConfig(bits=4, blc_epochs=1, max_rank=16))
    n_bytes = lambda t: sum(x.size * x.dtype.itemsize
                            for x in jax.tree.leaves(t))
    print(f"fp params: {n_bytes(params)/1e6:.1f}MB -> "
          f"quantized: {n_bytes(qparams)/1e6:.1f}MB")

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(2, cfg.vocab, size=12).astype(np.int32),
                    max_new_tokens=16, id=i) for i in range(8)]

    scfg = ServeConfig(max_slots=4, max_seq=64)
    eng = Engine(model, qparams, scfg)
    ref = {r.id: r.tokens for r in eng.generate(reqs)}

    if args.fleet == "procs":
        return serve_process_fleet(cfg, scfg, reqs, ref)

    for tag, p in (("fp", params), ("flrq-w4", qparams)):
        eng = Engine(model, p, scfg)
        t0 = time.time()
        res = eng.generate(reqs)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in res)
        print(f"{tag}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s incl. compile)")
        if tag == "fp":
            fp = {r.id: r.tokens for r in res}
        else:
            agree = np.mean([
                np.mean([a == b for a, b in zip(fp[r.id], r.tokens)])
                for r in res])
            print(f"greedy agreement with fp: {agree*100:.0f}%")
    return 0


def serve_process_fleet(cfg, scfg, reqs, ref):
    """Two quantized worker subprocesses, one scripted SIGKILL, one
    scripted supervisor crash — and a journal resume that finishes every
    request exactly-once. Untouched requests stay bitwise-identical to
    the no-fault engine; replayed ones are checked for exactly-once
    delivery (stream == terminal tokens, no gaps/duplicates) because a
    resumed continuation re-prefills ``prompt + emitted``, and on this
    *untrained* random-init proxy the chunked-prefill vs decode-step
    reduction order can flip a near-tied greedy argmax — the same flip
    reproduces with two plain ``Engine.generate`` calls and no fleet at
    all (the chaos suite proves bitwise resume parity on its shapes)."""
    from repro.serve.faults import FaultPlan
    from repro.serve.journal import Journal
    from repro.serve.supervisor import (Supervisor, SupervisorConfig,
                                        SupervisorCrash)
    from repro.serve.worker import WorkerSpec, model_config_to_dict

    spec = WorkerSpec(model=model_config_to_dict(cfg), serve=scfg.to_dict(),
                      seed=0, quantize_bits=4, blc_epochs=1, max_rank=16,
                      prefill_chunk=8)
    sup_cfg = SupervisorConfig(replicas=2, prefill_chunk=8,
                               backoff_base_s=0.01)
    streams, replayed = {}, set()

    def on_token(rid, tok, done):
        streams.setdefault(rid, []).append(tok)

    def on_replay(rid, prefix):
        streams[rid] = list(prefix)
        if prefix:          # an empty prefix restarts from scratch on an
            replayed.add(rid)  # undisturbed worker — no re-prefill drift

    with tempfile.TemporaryDirectory() as td:
        jp = pathlib.Path(td) / "requests.journal"
        print("\nprocess fleet: 2 quantized workers, plan = "
              "kill worker 0 at its step 5, crash the supervisor at "
              "tick 10, resume from the journal")
        t0 = time.time()
        sup = Supervisor(
            cfg=sup_cfg, fleet="procs", worker_spec=spec,
            journal=Journal(jp), on_token=on_token, on_replay=on_replay,
            fault_plan=FaultPlan.parse(
                "sigkill@5:step:0,supervisor_crash@10"))
        try:
            with sup:
                report = sup.serve(reqs)
        except SupervisorCrash as e:
            print(f"  supervisor died ({e}); a fresh supervisor replays "
                  f"the journal")
            sup2 = Supervisor(
                cfg=sup_cfg, fleet="procs", worker_spec=spec,
                journal=Journal(jp), on_token=on_token,
                on_replay=on_replay)
            with sup2:
                report = sup2.resume()
        dt = time.time() - t0
    counts = dict(report.status_counts())
    print(f"  {len(report.outcomes)}/{report.submitted} requests terminal "
          f"in {dt:.1f}s, statuses={counts}, "
          f"journal replayed {report.journal_replayed} records")
    once = sum(streams.get(o.id, []) == o.tokens for o in report.outcomes)
    clean = [o for o in report.outcomes if o.id not in replayed]
    exact = sum(streams.get(o.id, []) == ref[o.id] for o in clean)
    agree = np.mean([a == b for o in report.outcomes
                     for a, b in zip(streams.get(o.id, []), ref[o.id])])
    print(f"  exactly-once: {once}/{len(reqs)} streams == terminal "
          f"outcomes; {exact}/{len(clean)} untouched streams "
          f"bitwise-identical to the no-fault engine")
    print(f"  {len(replayed)} requests resumed mid-stream by "
          f"re-prefilling their emitted prefix; token agreement with "
          f"no-fault: {agree*100:.1f}% (near-tied argmax on the "
          f"untrained proxy — see docstring)")
    ok = (report.zero_drops and counts == {"ok": len(reqs)}
          and once == len(reqs) and exact == len(clean))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
