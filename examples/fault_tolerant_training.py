"""Fault-tolerance demo: train with async checkpoints, simulate a
preemption mid-run, then resume — including onto a different mesh layout
(elastic re-mesh), with bit-exact continuation.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import PAPER_PROXIES
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def main():
    cfg = dataclasses.replace(PAPER_PROXIES["opt-proxy-25m"],
                              n_layers=2, d_model=128, n_heads=4,
                              n_kv_heads=4, head_dim=32, d_ff=256, vocab=512)
    model = LM(cfg)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=4))
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3,
                                                      total_steps=40)))
    batch_at = lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        # phase 1: run until "preempted" at step 12
        calls = {"n": 0}
        res = train_loop(
            step, state, batch_at, ck,
            LoopConfig(total_steps=40, ckpt_every=10, log_every=10),
            preempt_flag=lambda: (calls.__setitem__("n", calls["n"] + 1)
                                  or calls["n"] >= 12))
        print(f"preempted at step {res.final_step} "
              f"(checkpoint committed: step {ck.latest_step()})")

        # phase 2: new process resumes from the checkpoint and finishes
        res2 = train_loop(
            step, state, batch_at, ck,
            LoopConfig(total_steps=40, ckpt_every=20, log_every=10),
            on_metrics=lambda s, m: print(f"  step {s}: loss={m['loss']:.3f}"))
        print(f"resumed from {res2.resumed_from}, finished at "
              f"{res2.final_step}")
        assert res2.resumed_from == res.final_step


if __name__ == "__main__":
    main()
