"""Quickstart: FLRQ-quantize a weight matrix and serve through the fused
kernel path.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import recon_error
from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import QuantSpec, pseudo_quantize
from repro.kernels import ops
from repro.quant import apply as qapply


def main():
    key = jax.random.PRNGKey(0)
    # an LLM-like weight: decaying spectrum + outlier channels
    m, n = 512, 1024
    u = jax.random.normal(key, (m, 16)) * (2.0 ** -jnp.arange(16))
    w = jax.random.normal(jax.random.PRNGKey(1), (m, n)) * 0.02 \
        + u @ jax.random.normal(jax.random.PRNGKey(2), (16, n)) * 0.4
    x_calib = jax.random.normal(jax.random.PRNGKey(3), (128, n))

    for bits in (4, 3, 2):
        cfg = FLRQConfig(bits=bits, blc_epochs=4 if bits > 2 else 10)
        qt, st = quantize_matrix(w, x_calib, cfg, key)
        rtn_err = float(recon_error(w, pseudo_quantize(w, QuantSpec(bits)),
                                    x_calib.T))
        print(f"W{bits}: rank={st.rank:3d} extra_bits={st.extra_bits:.2f}  "
              f"RTN err={rtn_err:.4f}  FLRQ err={st.err_after:.4f}  "
              f"({rtn_err/max(st.err_after,1e-9):.1f}x better)")

    # serve through the fused Pallas kernel (interpret=True on CPU)
    qt, _ = quantize_matrix(w, x_calib, FLRQConfig(bits=4), key)
    x = jax.random.normal(key, (64, n))
    y_kernel = ops.quant_matmul(qt, x, interpret=True)
    y_ref = qapply(qt, x)
    print("kernel vs reference max delta:",
          float(jnp.max(jnp.abs(y_kernel - y_ref))))
    print("vs exact:", float(jnp.linalg.norm(y_kernel - x @ w.T)
                             / jnp.linalg.norm(x @ w.T)))


if __name__ == "__main__":
    main()
