"""Paper appendix Fig. 5 (scaling) + Tables 19/21 (x-budget) analogue on
weight ensembles scaled across "model sizes": as the matrix grows, FLRQ's
extra-bit overhead shrinks while the error win over RTN persists — the
paper's memory-scalability claim.

    PYTHONPATH=src python examples/scaling_ablation.py
"""
import jax
import jax.numpy as jnp

from repro.core.flrq import FLRQConfig, quantize_matrix
from repro.core.quantize import QuantSpec, pseudo_quantize, recon_error


def llmish(key, m, n):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (m, 24)) * (2.0 ** -jnp.arange(24))
    return (jax.random.normal(k2, (m, n)) * 0.02
            + u @ jax.random.normal(k3, (24, n)) * 0.4)


SIZES = [(256, 512), (512, 1024), (1024, 2048), (2048, 4096)]


def main():
    key = jax.random.PRNGKey(0)
    print(f"{'size':>12} {'bits':>4} {'rank':>5} {'extra_bits':>10} "
          f"{'rtn_err':>9} {'flrq_err':>9} {'win':>6}")
    for m, n in SIZES:
        w = llmish(key, m, n)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, n))
        for bits in (4, 2):
            cfg = FLRQConfig(bits=bits, blc_epochs=1 if bits > 2 else 6,
                             max_rank=64)
            qt, st = quantize_matrix(w, x, cfg, key)
            e_rtn = float(recon_error(w, pseudo_quantize(w, QuantSpec(bits)),
                                      x.T))
            print(f"{m}x{n:>6} {bits:>4} {st.rank:>5} {st.extra_bits:>10.3f} "
                  f"{e_rtn:>9.4f} {st.err_after:>9.4f} "
                  f"{e_rtn/max(st.err_after, 1e-9):>5.1f}x")


if __name__ == "__main__":
    main()
