"""End-to-end driver: train a small LM on the synthetic corpus, then
PTQ-quantize it with FLRQ vs RTN at W4/W3/W2 and compare held-out
perplexity — the in-repo analogue of the paper's Table 2.

    PYTHONPATH=src python examples/train_then_quantize.py \
        [--steps 300] [--model opt-proxy-25m] [--bits 4 3 2]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_PROXIES
from repro.core.flrq import FLRQConfig
from repro.core.quantize import QuantSpec, pseudo_quantize
from repro.data.pipeline import DataConfig, SyntheticCorpus, collect_layer_activations
from repro.models import LM
from repro.quant.stacked import quantize_model_stacked, should_quantize
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def eval_ppl(model, params, data, steps=8, offset=10_000):
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(offset + i).items()}
        losses.append(float(model.loss(params, batch)))
    return float(np.exp(np.mean(losses)))


def rtn_quantize_stacked(params, bits):
    """Baseline: plain RTN on the same tensors FLRQ quantizes."""
    spec = QuantSpec(bits, 128)

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if (hasattr(leaf, "ndim") and leaf.ndim in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            flat = leaf.reshape((-1,) + leaf.shape[-2:])
            out = jnp.stack([
                pseudo_quantize(flat[i].T, spec).T for i in range(flat.shape[0])
            ])
            return out.reshape(leaf.shape).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--model", default="opt-proxy-25m")
    ap.add_argument("--bits", type=int, nargs="+", default=[4, 3, 2])
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = PAPER_PROXIES[args.model]
    model = LM(cfg)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(model, opt))

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        if (i + 1) % 50 == 0:
            print(f"step {i+1}: loss={float(m['loss']):.3f} "
                  f"({time.time()-t0:.0f}s)")
    params = state.params

    ppl_fp = eval_ppl(model, params, data)
    print(f"\nFP32 held-out PPL: {ppl_fp:.2f}")

    # calibration activations, as the paper: random segments through embed
    calib_tokens = data.calibration_batch(n_segments=16)
    acts = collect_layer_activations(model, params, calib_tokens)

    print(f"{'bits':>4} {'RTN PPL':>10} {'FLRQ PPL':>10} {'avg rank':>9} "
          f"{'extra bits':>10}")
    for bits in args.bits:
        rtn_params = rtn_quantize_stacked(params, bits)
        ppl_rtn = eval_ppl(model, rtn_params, data)
        qcfg = FLRQConfig(bits=bits, blc_epochs=2 if bits > 2 else 8,
                          max_rank=32)
        qparams, stats = quantize_model_stacked(params, acts, qcfg)
        ppl_flrq = eval_ppl(model, qparams, data)
        ranks = [s.rank for v in stats.values() for s in v]
        xb = [s.extra_bits for v in stats.values() for s in v]
        print(f"{bits:>4} {ppl_rtn:>10.2f} {ppl_flrq:>10.2f} "
              f"{np.mean(ranks):>9.1f} {np.mean(xb):>10.2f}")


if __name__ == "__main__":
    main()
