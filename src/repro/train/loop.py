"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests / examples on CPU):
  * checkpoint/restart — resumes from the latest complete checkpoint,
    including the data-pipeline position (pure (step, host) batching means
    no data replay);
  * preemption handling — SIGTERM (and an injectable ``preempt_flag``)
    triggers a final blocking save before exit;
  * straggler/hang mitigation — per-step wall-clock watchdog: steps
    exceeding ``step_timeout_s`` are logged and counted; after
    ``max_slow_steps`` the loop checkpoints and raises (at cluster scale
    the scheduler restarts the job minus the sick host — here we surface
    the signal);
  * elastic re-mesh — ``restore`` accepts any target shardings, so a loop
    restarted on a smaller mesh continues from the same step.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from .step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    step_timeout_s: float = 600.0
    max_slow_steps: int = 10


@dataclasses.dataclass
class LoopResult:
    final_step: int
    metrics_history: list
    resumed_from: Optional[int]
    slow_steps: int
    preempted: bool


def train_loop(
    step_fn: Callable,
    init_state: TrainState,
    batch_at: Callable[[int], Any],
    ckpt: Optional[Checkpointer],
    cfg: LoopConfig,
    state_shardings=None,
    preempt_flag: Optional[Callable[[], bool]] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> LoopResult:
    """Run (or resume) training. ``batch_at(step)`` must be pure/seekable."""
    state = init_state
    start_step = 0
    resumed_from = None
    if ckpt is not None and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(init_state, shardings=state_shardings)
        resumed_from = start_step

    preempted = {"flag": False}

    def _sig(_signum, _frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _sig)

    history = []
    slow_steps = 0
    try:
        step = start_step
        while step < cfg.total_steps:
            t0 = time.perf_counter()
            batch = batch_at(step)
            state, metrics = step_fn(state, batch)
            # materialize metrics (also acts as the step barrier)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics["step_time_s"] = dt
            if dt > cfg.step_timeout_s:
                slow_steps += 1
                metrics["slow"] = 1.0
                if slow_steps >= cfg.max_slow_steps:
                    if ckpt is not None:
                        ckpt.save(step + 1, state, blocking=True)
                    raise TimeoutError(
                        f"{slow_steps} steps over {cfg.step_timeout_s}s — "
                        "straggler/hang suspected; checkpointed and aborting")
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                history.append((step, metrics))
                if on_metrics:
                    on_metrics(step, metrics)
            want_ckpt = ckpt is not None and (
                step % cfg.ckpt_every == 0 or step == cfg.total_steps)
            if preempted["flag"] or (preempt_flag and preempt_flag()):
                if ckpt is not None:
                    ckpt.save(step, state, blocking=True)
                return LoopResult(step, history, resumed_from, slow_steps, True)
            if want_ckpt:
                ckpt.save(step, state, blocking=(step == cfg.total_steps))
        return LoopResult(step, history, resumed_from, slow_steps, False)
    finally:
        if ckpt is not None:
            ckpt.wait()
        signal.signal(signal.SIGTERM, old_handler)
