"""AdamW + LR schedules + gradient clipping — from scratch (no optax
offline). Optimizer state shards exactly like the parameters (ZeRO-style:
FSDP×TP shardings reuse the param rules), so a 314B model's 3× state fits
512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # scalar int32
    mu: Any             # first moment (pytree like params, f32)
    nu: Any             # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), dict(
        grad_norm=gnorm, lr=lr)
