"""Training substrate: optimizer, train step, loop with fault tolerance."""
