"""Train-step factory: loss → grads → AdamW, with microbatched gradient
accumulation (lax.scan), remat (inside the model stacks), and optional
gradient compression for the data-parallel reduction.

Compression notes (recorded in DESIGN.md §4): with bf16 params under GSPMD
the backward reduce-scatters are already 2-byte; the explicit ``compress``
modes below additionally quantize accumulated gradients before they cross
the data axis when running the pure-DP path (host mesh / examples):

    "none"  : f32 accumulation, bf16 wire (GSPMD default here)
    "bf16"  : cast grads bf16 before reduction
    "int8"  : per-tensor scale + int8 codes, exact int16 accumulation
              (valid for ≤ 256-way DP; asserts otherwise)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def compress_grads(grads, mode: str, dp_size: int = 1):
    """Lossy gradient encoding applied before the DP mean. Returns grads in
    f32 after a quantize-dequantize roundtrip (the wire format is what the
    collective sees; HLO shows the reduced dtype under shard_map paths)."""
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    if mode == "int8":
        assert dp_size <= 256, "int8 compression: int16 accumulator bound"

        def enc(g):
            g32 = g.astype(jnp.float32)
            s = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
            return q.astype(jnp.float32) * s

        return jax.tree.map(enc, grads)
    raise ValueError(mode)


def _split_microbatches(batch, n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    microbatches: int = 1,
    compress: str = "none",
    dp_size: int = 1,
    grad_shardings=None,
):
    """Returns ``step(state, batch) -> (state, metrics)`` ready for jax.jit
    with in/out shardings from repro.distributed.sharding.

    ``grad_shardings``: optional pytree of NamedSharding matching params.
    Without it, XLA's sharding propagation can lose the TP axis on the
    gradient/optimizer segment and materialize full f32 weight gathers over
    the model axis (observed: 3.5 GB × L gathers on qwen3-4b). Pinning the
    grads keeps the whole optimizer elementwise-sharded.
    """

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def step(state: TrainState, batch):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc(carry, mb):
                loss, g = jax.value_and_grad(loss_fn)(state.params, mb)
                g32 = _pin(jax.tree.map(lambda x: x.astype(jnp.float32), g))
                return jax.tree.map(jnp.add, carry, (loss, g32)), None

            zero = (jnp.zeros((), jnp.float32),
                    _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                      state.params)))
            (loss_sum, grad_sum), _ = jax.lax.scan(acc, zero, mbs)
            loss = loss_sum / microbatches
            grads = _pin(jax.tree.map(lambda g: g / microbatches, grad_sum))
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            grads = _pin(jax.tree.map(lambda x: x.astype(jnp.float32), grads))

        grads = compress_grads(grads, compress, dp_size)
        params, opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = dict(loss=loss, **om)
        return TrainState(params, opt), metrics

    return step


def init_train_state(model, key, opt_cfg: Optional[AdamWConfig] = None) -> TrainState:
    params = model.init(key)
    return TrainState(params, adamw_init(params))


def train_state_shapes(model, key):
    """abstract TrainState via eval_shape (dry-run / sharding planning)."""
    return jax.eval_shape(lambda k: init_train_state(model, k), key)
