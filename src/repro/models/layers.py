"""Shared neural building blocks: norms, RoPE/M-RoPE, blockwise (flash)
attention, GQA, MLP — all pure functions over explicit parameter dicts,
sharding-annotated by the distributed layer, scan-over-layers friendly.

Conventions:
  * activations: (B, S, D); weights stored (in_dim, out_dim) so y = x @ w.
  * attention params: q: (D, H*hd), k/v: (D, KV*hd), o: (H*hd, D).
  * every matrix here is a quantization target for FLRQ at serving time.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Activations sharding helpers are injected by repro.distributed; default noop.
_constrain = lambda x, spec: x


def set_constrainer(fn) -> None:
    """Installed by repro.distributed.sharding when running under a mesh."""
    global _constrain
    _constrain = fn


def constrain(x, spec):
    return _constrain(x, spec)


def remat_wrap(fn, cfg, static_argnums=()):
    """jax.checkpoint with the configured policy ("full" recomputes
    everything; "dots" saves matmul outputs — raises the useful-FLOPs
    ratio from 0.75 to ~0.9 at the cost of activation memory)."""
    if not cfg.remat:
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, static_argnums=static_argnums, policy=policy)


def mm(x, w):
    """Matmul dispatching on the weight type. Model weights use the
    (in, out) convention; an FLRQ-quantized weight is a QuantizedLinear
    holding the transposed (out=m, in=n) decomposition and routes through
    the quant backend-dispatch layer (``quant.apply.dispatch``):
        y = deq(W_q)·(α⁻¹⊙x) + U(V·(α⁻¹⊙x))
    The active backend ("ref" jnp path, "fused" Pallas kernel, or "auto")
    is installed by ``quant.apply.backend_scope`` — the serving engine
    wraps its jitted prefill/decode so the whole trace follows one policy.
    """
    from ..quant.qtensor import QuantizedLinear

    if isinstance(w, QuantizedLinear):
        from ..quant.apply import dispatch

        return dispatch(w, x, out_dtype=x.dtype)
    return x @ w


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd), positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10000.0, sections=(2, 1, 1)):
    """Qwen2-VL multimodal RoPE. positions3: (B, 3, S) (t, h, w) position ids;
    the head_dim rotary channels are split between the three components in
    ``sections`` ratio (16, 24, 24 of 64 pairs in the real model — we use the
    same 2:1:1-ish split scaled to head_dim). For pure text all three are the
    token index, reducing to plain RoPE."""
    hd = x.shape[-1]
    half = hd // 2
    s_total = sum(sections)
    cuts = [half * sections[0] // s_total, half * (sections[0] + sections[1]) // s_total]
    freqs = rope_freqs(hd, theta)  # (half,)
    # choose which position stream drives each rotary channel
    chan_src = jnp.zeros((half,), jnp.int32)
    chan_src = chan_src.at[cuts[0]:cuts[1]].set(1)
    chan_src = chan_src.at[cuts[1]:].set(2)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),  # (B, 3, S)
        jnp.broadcast_to(chan_src[None, :, None], (x.shape[0], half, positions3.shape[-1])).astype(jnp.int32),
        axis=1,
    )  # (B, half, S)
    angles = jnp.einsum("bhs,h->bsh", pos, freqs)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise ("flash") attention — pure JAX, O(S) memory.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def flash_attention(
    q, k, v,
    causal: bool = True,
    window=None,                  # traced scalar: sliding-window size (None = off)
    softcap_val: float = 0.0,
    q_offset: int = 0,            # absolute position of q[0] (decode/prefill)
    q_block: int = 512,
    k_block: int = 1024,
):
    """q: (B, S_q, H, hd); k, v: (B, S_k, H, hd) (kv already repeated to H).
    Two-level lax.scan with online softmax; never materializes (S_q, S_k).
    ``window`` may be a traced value (per-layer local/global selection in a
    scanned stack chooses window = S_k for global layers).
    ``q_offset`` is a scalar, or a (B,) vector giving each batch lane its
    OWN absolute offset (batched slot prefill: lane b resumes at its
    slot's position) — per-lane masks, same row-independent einsums, so a
    lane's output is bitwise what the scalar-offset call would produce.
    """
    q_offset = jnp.asarray(q_offset)
    per_lane = q_offset.ndim == 1
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    # pad S to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_block - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * k_block - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * k_block - sk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, q_block, h, hd)
    kp = kp.reshape(b, nk, k_block, h, hd)
    vp = vp.reshape(b, nk, k_block, h, hd)

    def q_step(_, qi):
        q_blk, qidx = qi  # (b, q_block, h, hd), scalar block index
        base = qidx * q_block + jnp.arange(q_block)
        # scalar offset: qpos (q_block,); per-lane offsets: qpos (B, q_block)
        qpos = q_offset[:, None] + base[None, :] if per_lane \
            else q_offset + base

        def k_step(carry, ki):
            acc, m, l = carry
            k_blk, v_blk, kidx = ki
            kpos = kidx * k_block + jnp.arange(k_block)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            ) * scale
            if softcap_val:
                s = softcap(s, softcap_val)
            if per_lane:  # masks carry a lane dim: (B, q_block, k_block)
                mask = jnp.broadcast_to(kpos[None, None, :] < sk,
                                        qpos.shape + (k_block,))
                if causal:
                    mask = mask & (kpos[None, None, :] <= qpos[:, :, None])
                if window is not None:
                    mask = mask & (kpos[None, None, :]
                                   > qpos[:, :, None] - window)
                s = jnp.where(mask[:, None, :, :], s, NEG_INF)
            else:
                mask = kpos[None, :] < sk  # padding
                if causal:
                    mask = mask & (kpos[None, :] <= qpos[:, None])
                if window is not None:
                    mask = mask & (kpos[None, :] > qpos[:, None] - window)
                s = jnp.where(mask[None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            k_step, (acc0, m0, l0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3)  # (b, q_block, h, hd)

    _, outs = jax.lax.scan(
        q_step, None, (qp.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, h, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, window=None,
                     softcap_val: float = 0.0):
    """Single-token attention. q: (B, 1, H, hd); caches: (B, S, KV, hd) with
    valid prefix ``length`` (int array (B,) or scalar). kv repeated to H by
    caller. Linear in S — no flash needed."""
    b, _, h, hd = q.shape
    sk = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap(s, softcap_val)
    kpos = jnp.arange(sk)
    length = jnp.asarray(length)
    lw = length if length.ndim else length[None]
    mask = kpos[None, :] < lw[:, None]  # (B, S)
    if window is not None:
        mask = mask & (kpos[None, :] > lw[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_gqa(q, k_cache, v_cache, length, window=None,
                         softcap_val: float = 0.0):
    """Grouped-query decode attention WITHOUT materializing repeated KV
    heads (beyond-paper perf lever): q (B, 1, H, hd) is viewed as
    (B, KV, G, hd) and contracted directly against the (B, S, KV, hd)
    cache. Numerically identical to repeat_kv + decode_attention; avoids
    the (B, S, H, hd) broadcast and its reshard."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    sk = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q2 = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", q2, k_cache.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap(s, softcap_val)
    kpos = jnp.arange(sk)
    length = jnp.asarray(length)
    lw = length if length.ndim else length[None]
    mask = kpos[None, :] < lw[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > lw[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def verify_attention(q, k_cache, v_cache, lengths, window=None,
                     softcap_val: float = 0.0):
    """Speculative-verify attention: C window queries against the decode
    cache with a *per-query* causal horizon. q: (B, C, H, hd); caches:
    (B, S, KV, hd) holding the window's K/V already inserted at positions
    ``lengths[b] .. lengths[b]+C-1``; query j sees ``lengths[b]+j+1`` keys
    — exactly what C sequential ``decode_attention`` calls would see.

    Op order replicates ``decode_attention`` exactly (f32 einsum × scale →
    softcap → mask → NEG_INF → ``jax.nn.softmax`` → p·V einsum): those ops
    are row-independent per (b, query), and masked lanes contribute exact
    zeros, so each window row computes the SAME function as its
    single-token decode call — op-for-op bitwise at op granularity;
    whole-graph compilation may reorder fused reductions within ~1 ulp
    for the C-wide shapes, which is why the speculative parity oracle is
    stated (and tested) at the greedy-argmax/token level. (The flash
    kernels normalize inside the online loop — divide-after instead of
    softmax's divide-before — which is why verify gets its own formula
    instead of reusing them.)"""
    b, c, h, hd = q.shape
    sk = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap(s, softcap_val)
    kpos = jnp.arange(sk)
    lw = jnp.asarray(lengths)[:, None] + jnp.arange(1, c + 1)[None, :]  # (B, C)
    mask = kpos[None, None, :] < lw[:, :, None]  # (B, C, S)
    if window is not None:
        mask = mask & (kpos[None, None, :] > lw[:, :, None] - 1 - window)
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)  # (B, H, C, S)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def verify_attention_gqa(q, k_cache, v_cache, lengths, window=None,
                         softcap_val: float = 0.0):
    """GQA form of ``verify_attention`` — mirrors ``decode_attention_gqa``
    op-for-op with the same per-query (B, C) horizon, contracting q viewed
    as (B, C, KV, G, hd) straight against the (B, S, KV, hd) cache."""
    b, c, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    sk = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q2 = q.reshape(b, c, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bqkgs", q2,
                   k_cache.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap(s, softcap_val)
    kpos = jnp.arange(sk)
    lw = jnp.asarray(lengths)[:, None] + jnp.arange(1, c + 1)[None, :]
    mask = kpos[None, None, :] < lw[:, :, None]
    if window is not None:
        mask = mask & (kpos[None, None, :] > lw[:, :, None] - 1 - window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)  # (B, C, KV, G, S)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, c, h, hd).astype(q.dtype)


def repeat_kv(x, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, kv, n_rep, hd)
    ).reshape(b, s, kv * n_rep, hd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down, act=jax.nn.silu):
    h = act(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, w_out):
    return jax.nn.gelu(x @ w_in) @ w_out


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_softmax_xent(
    hidden, unembed, labels, mask=None, chunk: int = 512,
    softcap_final: float = 0.0, logits_spec=None,
):
    """Cross-entropy over a large vocab without materializing (B, S, V) at
    once: lax.map over sequence chunks. hidden: (B, S, D); unembed: (D, V);
    labels: (B, S) int32; mask: (B, S) {0,1}. Returns mean loss."""
    b, s, d = hidden.shape
    v = unembed.shape[1]
    chunk = min(chunk, s)
    nch = -(-s // chunk)
    pad = nch * chunk - s
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.ones((b, s), jnp.float32) if mask is None else mask.astype(jnp.float32)
    mp = jnp.pad(mp, ((0, 0), (0, pad)))
    hp = hp.reshape(b, nch, chunk, d).transpose(1, 0, 2, 3)
    lp = lp.reshape(b, nch, chunk).transpose(1, 0, 2)
    mp = mp.reshape(b, nch, chunk).transpose(1, 0, 2)

    def one(args):
        hc, lc, mc = args
        logits = jnp.einsum("bsd,dv->bsv", hc.astype(jnp.float32),
                            unembed.astype(jnp.float32))
        if softcap_final:
            logits = softcap(logits, softcap_final)
        if logits_spec is not None:
            logits = constrain(logits, logits_spec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via a sharded one-hot contraction: take_along_axis over
        # a vocab-sharded dim forces GSPMD to all-gather the full (B,S,V)
        # logits (measured 2.5 GB f32 AG per chunk on qwen3-moe); the
        # one-hot dot keeps everything vocab-local + one tiny (B,S) psum.
        onehot = (jnp.arange(v)[None, None, :] == lc[..., None])
        gold = jnp.sum(logits * onehot.astype(logits.dtype), axis=-1)
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    losses, counts = jax.lax.map(one, (hp, lp, mp))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)
