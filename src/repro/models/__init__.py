"""Architecture zoo (scan-over-layers, remat-able, sharding-annotated)."""
from .config import ModelConfig, small_variant  # noqa: F401
from .model import LM  # noqa: F401
