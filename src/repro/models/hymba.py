"""Hymba: hybrid-head blocks running GQA attention and a Mamba-style
selective SSM *in parallel* on the same input, fusing their (per-path
normalized) outputs by averaging — plus a standard SwiGLU MLP.

Attention is sliding-window except on ``cfg.global_layers`` (the paper uses
3 global layers: first, middle, last). The SSM path keeps O(state) memory,
which is what makes hymba a ``long_500k`` architecture; the KV cache for
local layers is ring-buffer-truncatable (we allocate full length for layer-
stack uniformity; the ring-buffer variant is a recorded perf lever).

Mamba path per layer:
    (z, xm) = x @ W_in                      (each (B, S, Di))
    xm      = causal_depthwise_conv(xm, 4)
    dt      = softplus(xm @ W_dt + b_dt)    (B, S, Di)
    Bc, Cc  = xm @ W_B, xm @ W_C            (B, S, N)
    h_t     = exp(-dt_t · exp(A_log)) h_{t-1} + dt_t · (Bc_t ⊗ xm_t)
    y_t     = (h_t · Cc_t) + D_skip ⊙ xm_t
    out     = (y ⊙ silu(z)) @ W_out
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import (
    constrain,
    mm,
    remat_wrap,
    apply_rope,
    decode_attention,
    decode_attention_gqa,
    flash_attention,
    repeat_kv,
    rms_norm,
)

_SPEC_BSD = P(("pod", "data"), None, None)


def _init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


class HymbaStack:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.d_inner = cfg.d_inner_resolved
        self.conv_k = 4

    def init_layers(self, key):
        cfg = self.cfg
        L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
        Di, N = self.d_inner, cfg.ssm_state
        qd, kvd = cfg.q_dim, cfg.kv_dim
        ks = jax.random.split(key, 20)
        return {
            "in_norm": jnp.zeros((L, D), cfg.dtype),
            # attention path
            "wq": _init(ks[0], (L, D, qd), D, cfg.dtype),
            "wk": _init(ks[1], (L, D, kvd), D, cfg.dtype),
            "wv": _init(ks[2], (L, D, kvd), D, cfg.dtype),
            "wo": _init(ks[3], (L, qd, D), qd, cfg.dtype),
            "attn_out_norm": jnp.zeros((L, D), cfg.dtype),
            # mamba path
            "w_in": _init(ks[4], (L, D, 2 * Di), D, cfg.dtype),
            "conv_w": _init(ks[5], (L, self.conv_k, Di), self.conv_k, cfg.dtype),
            "conv_b": jnp.zeros((L, Di), cfg.dtype),
            "w_dt": _init(ks[6], (L, Di, Di), Di, cfg.dtype),
            "b_dt": jnp.full((L, Di), -4.0, cfg.dtype),  # softplus → small dt
            "w_B": _init(ks[7], (L, Di, N), Di, cfg.dtype),
            "w_C": _init(ks[8], (L, Di, N), Di, cfg.dtype),
            "a_log": jnp.zeros((L, Di, N), cfg.dtype),   # A = -exp(a_log)
            "d_skip": jnp.ones((L, Di), cfg.dtype),
            "w_out": _init(ks[9], (L, Di, D), Di, cfg.dtype),
            "mamba_out_norm": jnp.zeros((L, D), cfg.dtype),
            # mlp
            "mlp_norm": jnp.zeros((L, D), cfg.dtype),
            "w_gate": _init(ks[10], (L, D, F), D, cfg.dtype),
            "w_up": _init(ks[11], (L, D, F), D, cfg.dtype),
            "w_down": _init(ks[12], (L, F, D), F, cfg.dtype),
        }

    # ------------------------------------------------------------- windows
    def _layer_window(self, layer_idx, s_k):
        cfg = self.cfg
        if not cfg.local_window:
            return None
        is_global = jnp.isin(layer_idx, jnp.asarray(cfg.global_layers or (-1,)))
        return jnp.where(is_global, jnp.int32(s_k + 1), jnp.int32(cfg.local_window))

    # --------------------------------------------------------------- mamba
    def _mamba_proj(self, pl, h, conv_state=None):
        """Shared projection work. h: (B, S, D). Returns (z, xm, dt, Bc, Cc)
        and the last conv_k-1 inputs (for decode carry)."""
        zx = mm(h, pl["w_in"])
        z, xm = jnp.split(zx, 2, axis=-1)
        if conv_state is None:
            pad = jnp.zeros((xm.shape[0], self.conv_k - 1, xm.shape[2]), xm.dtype)
        else:
            pad = conv_state
        xm_pad = jnp.concatenate([pad, xm], axis=1)
        new_conv = xm_pad[:, -(self.conv_k - 1):, :]
        # depthwise causal conv: sum_k w[k] * x_{t-k}
        w = pl["conv_w"].astype(jnp.float32)  # (K, Di)
        xm32 = xm_pad.astype(jnp.float32)
        s = xm.shape[1]
        conv = sum(
            xm32[:, i:i + s, :] * w[i][None, None, :] for i in range(self.conv_k)
        ) + pl["conv_b"].astype(jnp.float32)
        xm = jax.nn.silu(conv).astype(h.dtype)
        dt = jax.nn.softplus(
            mm(xm, pl["w_dt"]).astype(jnp.float32) + pl["b_dt"].astype(jnp.float32))
        bc = mm(xm, pl["w_B"]).astype(jnp.float32)
        cc = mm(xm, pl["w_C"]).astype(jnp.float32)
        return z, xm, dt, bc, cc, new_conv

    def _mamba_seq(self, pl, h, h0, conv0):
        """Full-sequence selective scan. h0: (B, Di, N) initial state."""
        z, xm, dt, bc, cc, new_conv = self._mamba_proj(pl, h, conv0)
        a = -jnp.exp(pl["a_log"].astype(jnp.float32))  # (Di, N)

        def step(hst, t):
            xm_t, dt_t, b_t, c_t = t
            decay = jnp.exp(dt_t[..., None] * a[None])        # (B, Di, N)
            hst = decay * hst + (dt_t * xm_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", hst, c_t)
            return hst, y

        xs = (xm.transpose(1, 0, 2), dt.transpose(1, 0, 2),
              bc.transpose(1, 0, 2), cc.transpose(1, 0, 2))
        h_fin, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
        y = ys.transpose(1, 0, 2) + pl["d_skip"].astype(jnp.float32) * xm.astype(jnp.float32)
        y = (y.astype(h.dtype) * jax.nn.silu(z))
        return mm(y, pl["w_out"]), h_fin.astype(h.dtype), new_conv

    # ----------------------------------------------------------- attention
    def _attn_seq(self, pl, h, positions, layer_idx):
        cfg = self.cfg
        b, s, _ = h.shape
        q = mm(h, pl["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = mm(h, pl["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = mm(h, pl["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kr = repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
        vr = repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
        win = self._layer_window(layer_idx, s)
        attn = flash_attention(q, kr, vr, causal=True, window=win)
        return mm(attn.reshape(b, s, cfg.q_dim), pl["wo"]), k, v

    # --------------------------------------------------------------- layer
    def _layer_seq(self, pl, x, positions, layer_idx, h0, conv0):
        cfg = self.cfg
        h = rms_norm(x, pl["in_norm"])
        attn_out, k, v = self._attn_seq(pl, h, positions, layer_idx)
        mamba_out, h_fin, new_conv = self._mamba_seq(pl, h, h0, conv0)
        fused = 0.5 * (rms_norm(attn_out, pl["attn_out_norm"]) +
                       rms_norm(mamba_out, pl["mamba_out_norm"]))
        x = constrain(x + fused, _SPEC_BSD)
        hm = rms_norm(x, pl["mlp_norm"])
        mlp = mm(jax.nn.silu(mm(hm, pl["w_gate"])) * mm(hm, pl["w_up"]), pl["w_down"])
        return constrain(x + mlp, _SPEC_BSD), (k, v, h_fin, new_conv)

    # ----------------------------------------------------------- interfaces
    def _zero_inner(self, batch):
        cfg = self.cfg
        return (
            jnp.zeros((batch, self.d_inner, cfg.ssm_state), cfg.dtype),
            jnp.zeros((batch, self.conv_k - 1, self.d_inner), cfg.dtype),
        )

    def apply_train(self, layers, x, positions):
        cfg = self.cfg
        h0, conv0 = self._zero_inner(x.shape[0])

        def body(h, xs):
            pl, idx = xs
            fn = remat_wrap(self._layer_seq, cfg)
            h, _ = fn(pl, h, positions, idx, h0, conv0)
            return h, None

        h, _ = jax.lax.scan(body, x, (layers, jnp.arange(cfg.n_layers)))
        return h

    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "v": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "ssm": jnp.zeros((L, batch, self.d_inner, cfg.ssm_state), cfg.dtype),
            "conv": jnp.zeros((L, batch, self.conv_k - 1, self.d_inner), cfg.dtype),
        }

    def apply_prefill(self, layers, x, positions):
        h0, conv0 = self._zero_inner(x.shape[0])

        def body(h, xs):
            pl, idx = xs
            h, (k, v, h_fin, new_conv) = self._layer_seq(
                pl, h, positions, idx, h0, conv0)
            return h, (k, v, h_fin, new_conv)

        h, (ks, vs, ssms, convs) = jax.lax.scan(
            body, x, (layers, jnp.arange(self.cfg.n_layers)))
        return h, {"k": ks, "v": vs, "ssm": ssms, "conv": convs}

    def apply_decode(self, layers, x, cache, length):
        cfg = self.cfg
        b = x.shape[0]
        positions = jnp.full((b, 1), length, jnp.int32)

        def body(h, xs):
            pl, idx, k_l, v_l, ssm_l, conv_l = xs
            hn = rms_norm(h, pl["in_norm"])
            # attention: append kv, attend over cache
            q = mm(hn, pl["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            k = mm(hn, pl["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            v = mm(hn, pl["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, length, 0, 0))
            v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, length, 0, 0))
            win = self._layer_window(idx, k_l.shape[1])
            if cfg.grouped_decode_attn:
                attn = decode_attention_gqa(q, k_l, v_l, length + 1, window=win)
            else:
                kr = repeat_kv(k_l, cfg.n_heads // cfg.n_kv_heads)
                vr = repeat_kv(v_l, cfg.n_heads // cfg.n_kv_heads)
                attn = decode_attention(q, kr, vr, length + 1, window=win)
            attn_out = mm(attn.reshape(b, 1, cfg.q_dim), pl["wo"])
            # mamba: single-step
            mamba_out, ssm_l, conv_l = self._mamba_seq(pl, hn, ssm_l, conv_l)
            fused = 0.5 * (rms_norm(attn_out, pl["attn_out_norm"]) +
                           rms_norm(mamba_out, pl["mamba_out_norm"]))
            h = h + fused
            hm = rms_norm(h, pl["mlp_norm"])
            h = h + mm(jax.nn.silu(mm(hm, pl["w_gate"])) * mm(hm, pl["w_up"]), pl["w_down"])
            return h, (k_l, v_l, ssm_l, conv_l)

        h, (ks, vs, ssms, convs) = jax.lax.scan(
            body, x,
            (layers, jnp.arange(cfg.n_layers), cache["k"], cache["v"],
             cache["ssm"], cache["conv"]))
        return h, {"k": ks, "v": vs, "ssm": ssms, "conv": convs}
