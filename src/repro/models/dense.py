"""Dense / MoE / encoder transformer stack (scan-over-layers).

Covers: grok-1, qwen3-moe, gemma2 (local+global, softcaps), internlm2,
qwen3 (qk_norm), mistral-nemo, qwen2-vl (M-RoPE), hubert (encoder).

The stack exposes three entry points used by ``models.model.LM``:
    init_layers(key)                      -> stacked layer params
    apply_train(layers, x, positions)     -> hidden states (B, S, D)
    init_cache(batch, seq)                -> KV cache pytree
    apply_prefill(layers, x, positions)   -> (hidden, cache)
    apply_decode(layers, x, cache, length)-> (hidden, cache)

Serving with FLRQ weights: ``quantize_model_stacked`` leaves the layer
stacks as lane-leading QuantizedLinear pytrees, and every entry point here
``lax.scan``s the layer body straight over them (``cfg.scan_layers``,
default) — ONE compiled layer body per executable for prefill, decode and
train alike, quantized or not. ``scan_layers=False`` unrolls into L
per-layer pytree dispatches (the reference path the serving benchmark
A/Bs against).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import (
    mm,
    remat_wrap,
    apply_mrope,
    apply_rope,
    constrain,
    decode_attention,
    decode_attention_gqa,
    flash_attention,
    repeat_kv,
    rms_norm,
    verify_attention,
    verify_attention_gqa,
)
from ..kernels.decode_attention import flash_decode_gqa_paged
from .moe import moe_ffn

# Activation sharding specs (installed constrainer decides whether they bind).
_SPEC_BSD = P(("pod", "data"), None, None)
_SPEC_BSH = P(("pod", "data"), None, "model", None)
_SPEC_FF = P(("pod", "data"), None, "model")


def _init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


class DenseStack:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init_layers(self, key):
        cfg = self.cfg
        L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
        qd, kvd, hd = cfg.q_dim, cfg.kv_dim, cfg.head_dim
        ks = jax.random.split(key, 16)
        p = {
            "attn_norm": jnp.zeros((L, D), cfg.dtype),
            "wq": _init(ks[0], (L, D, qd), D, cfg.dtype),
            "wk": _init(ks[1], (L, D, kvd), D, cfg.dtype),
            "wv": _init(ks[2], (L, D, kvd), D, cfg.dtype),
            "wo": _init(ks[3], (L, qd, D), qd, cfg.dtype),
            "mlp_norm": jnp.zeros((L, D), cfg.dtype),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((L, hd), cfg.dtype)
            p["k_norm"] = jnp.zeros((L, hd), cfg.dtype)
        if cfg.attn_softcap or cfg.final_softcap:  # gemma2 extra norms
            p["post_attn_norm"] = jnp.zeros((L, D), cfg.dtype)
            p["post_mlp_norm"] = jnp.zeros((L, D), cfg.dtype)
        if cfg.family == "moe":
            E = cfg.n_experts
            p["router"] = _init(ks[4], (L, D, E), D, jnp.float32)
            p["w_gate"] = _init(ks[5], (L, E, D, F), D, cfg.dtype)
            p["w_up"] = _init(ks[6], (L, E, D, F), D, cfg.dtype)
            p["w_down"] = _init(ks[7], (L, E, F, D), F, cfg.dtype)
        elif cfg.family == "encoder":
            p["w_in"] = _init(ks[5], (L, D, F), D, cfg.dtype)
            p["w_out"] = _init(ks[6], (L, F, D), F, cfg.dtype)
        else:
            p["w_gate"] = _init(ks[5], (L, D, F), D, cfg.dtype)
            p["w_up"] = _init(ks[6], (L, D, F), D, cfg.dtype)
            p["w_down"] = _init(ks[7], (L, F, D), F, cfg.dtype)
        return p

    # -------------------------------------------------------------- helpers
    def _layer_window(self, layer_idx, s_k):
        """Per-layer sliding window (traced). None → full attention
        statically; otherwise a traced window size (= s_k on global layers).
        """
        cfg = self.cfg
        if not cfg.local_window:
            return None
        if cfg.global_every:
            is_global = (layer_idx % cfg.global_every) == (cfg.global_every - 1)
        elif cfg.global_layers:
            is_global = jnp.isin(layer_idx, jnp.asarray(cfg.global_layers))
        else:
            is_global = jnp.bool_(False)
        return jnp.where(is_global, jnp.int32(s_k + 1), jnp.int32(cfg.local_window))

    def _qkv(self, pl, x, positions):
        cfg = self.cfg
        b, s, _ = x.shape
        hd = cfg.head_dim
        h = rms_norm(x, pl["attn_norm"])
        q = mm(h, pl["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = mm(h, pl["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = mm(h, pl["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(q, pl["q_norm"])
            k = rms_norm(k, pl["k_norm"])
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q = constrain(q, _SPEC_BSH)
        return q, k, v

    def _ffn(self, pl, x):
        cfg = self.cfg
        h = rms_norm(x, pl["mlp_norm"])
        if cfg.family == "moe":
            out = moe_ffn(h, pl["router"], pl["w_gate"], pl["w_up"],
                          pl["w_down"], cfg.topk, cfg.moe_impl,
                          cfg.capacity_factor, cfg.expert_parallel)
        elif cfg.family == "encoder":
            out = mm(constrain(jax.nn.gelu(mm(h, pl["w_in"])), _SPEC_FF), pl["w_out"])
        else:
            g = constrain(jax.nn.silu(mm(h, pl["w_gate"])), _SPEC_FF)
            out = mm(g * mm(h, pl["w_up"]), pl["w_down"])
        if "post_mlp_norm" in pl:
            out = rms_norm(out, pl["post_mlp_norm"])
        return out

    # ---------------------------------------------------------- full-seq fwd
    def _layer_full(self, pl, x, positions, layer_idx, causal=True):
        cfg = self.cfg
        b, s, _ = x.shape
        q, k, v = self._qkv(pl, x, positions)
        k = repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
        v = repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
        win = self._layer_window(layer_idx, s)
        attn = flash_attention(q, k, v, causal=causal, window=win,
                               softcap_val=cfg.attn_softcap)
        attn = mm(attn.reshape(b, s, cfg.q_dim), pl["wo"])
        if "post_attn_norm" in pl:
            attn = rms_norm(attn, pl["post_attn_norm"])
        x = constrain(x + attn, _SPEC_BSD)
        x = x + self._ffn(pl, x)
        return constrain(x, _SPEC_BSD)

    def apply_train(self, layers, x, positions):
        cfg = self.cfg
        causal = cfg.family != "encoder"

        def body(h, xs):
            pl, idx = xs
            fn = remat_wrap(self._layer_full, cfg, static_argnums=(4,))
            return fn(pl, h, positions, idx, causal), None

        h, _ = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers)), cfg.n_layers,
            cfg.scan_layers)
        return h

    @staticmethod
    def _run_layers(body, x, xs_all, n_layers: int, scan: bool):
        """Run the layer ``body`` over the stacked per-layer inputs
        ``xs_all`` — as ONE compiled body via ``lax.scan`` (``scan=True``;
        stacked QuantizedLinear leaves slice per lane like any other
        stacked param), or unrolled into L per-layer pytree dispatches
        (the pre-runtime reference path, kept for A/B benchmarking)."""
        if scan:
            return jax.lax.scan(body, x, xs_all)
        h = x
        ys = []
        for i in range(n_layers):
            h, y = body(h, jax.tree.map(lambda a: a[i], xs_all))
            ys.append(y)
        return h, jax.tree.map(lambda *a: jnp.stack(a), *ys)

    # ------------------------------------------------------------- prefill
    def apply_prefill(self, layers, x, positions):
        """Returns (hidden, cache). Cache: k/v (L, B, S, KV, hd) + length."""
        cfg = self.cfg

        def body(h, xs):
            pl, idx = xs
            b, s, _ = h.shape
            q, k, v = self._qkv(pl, h, positions)
            kr = repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
            vr = repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
            win = self._layer_window(idx, s)
            attn = flash_attention(q, kr, vr, causal=True, window=win,
                                   softcap_val=cfg.attn_softcap)
            attn = mm(attn.reshape(b, s, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = constrain(h + attn, _SPEC_BSD)
            h = h + self._ffn(pl, h)
            return constrain(h, _SPEC_BSD), (k, v)

        h, (ks, vs) = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers)), cfg.n_layers,
            cfg.scan_layers)
        cache = {"k": ks, "v": vs}
        return h, cache

    # ----------------------------------------------------- chunked prefill
    def apply_prefill_slot(self, layers, x, cache, slot, start):
        """Prefill a chunk of ONE prompt into its slot's decode-cache
        region. x: (1, C, D) chunk embeddings; cache: the full decode cache
        (L, B, S, KV, hd); ``slot``/``start`` traced int32 scalars — the
        slot row and the chunk's absolute offset in it (chunked prefill
        resumes mid-prompt at ``start``). K/V land at
        cache[:, slot, start:start+C] via dynamic_update_slice, so the
        executable's shapes never depend on where the chunk sits; the chunk
        queries attend the whole slot row with ``q_offset=start`` causal
        masking (keys past each query's absolute position — including any
        padded chunk tail and stale retired-request entries — are masked,
        and padded-tail K/V garbage is overwritten by the next write at
        this slot's length before it ever becomes visible).
        Returns (hidden (1, C, D), cache)."""
        cfg = self.cfg
        b, c, _ = x.shape
        s_cache = cache["k"].shape[2]
        positions = jnp.arange(c, dtype=jnp.int32)[None] + start  # (1, C)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, c))
        kv8 = cfg.kv_cache_bits == 8

        def row_update(cache_l, new):
            """Write the chunk into this layer's (B, S, ...) cache at
            (slot, start); returns (updated full cache_l, updated row)."""
            row = jax.lax.dynamic_slice_in_dim(cache_l, slot, 1, axis=0)
            row = jax.lax.dynamic_update_slice_in_dim(
                row, new.astype(row.dtype), start, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                cache_l, row, slot, axis=0), row

        def body(h, xs):
            if kv8:
                pl, idx, k_l, v_l, ks_l, vs_l = xs
            else:
                pl, idx, k_l, v_l = xs
            q, k, v = self._qkv(pl, h, positions)  # k/v: (1, C, KV, hd)
            if kv8:
                kc, kscale = self._quant_kv(k)
                vc, vscale = self._quant_kv(v)
                k_l, k_row = row_update(k_l, kc)
                v_l, v_row = row_update(v_l, vc)
                ks_l, ks_row = row_update(ks_l, kscale)
                vs_l, vs_row = row_update(vs_l, vscale)
                k_row = k_row.astype(cfg.dtype) * ks_row.astype(cfg.dtype)
                v_row = v_row.astype(cfg.dtype) * vs_row.astype(cfg.dtype)
            else:
                k_l, k_row = row_update(k_l, k)
                v_l, v_row = row_update(v_l, v)
            kr = repeat_kv(k_row, cfg.n_heads // cfg.n_kv_heads)
            vr = repeat_kv(v_row, cfg.n_heads // cfg.n_kv_heads)
            win = self._layer_window(idx, s_cache)
            attn = flash_attention(q, kr, vr, causal=True, window=win,
                                   softcap_val=cfg.attn_softcap,
                                   q_offset=start)
            attn = mm(attn.reshape(b, c, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = h + attn
            h = h + self._ffn(pl, h)
            if kv8:
                return h, (k_l, v_l, ks_l, vs_l)
            return h, (k_l, v_l)

        if kv8:
            h, (ks, vs, kss, vss) = self._run_layers(
                body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                          cache["v"], cache["k_scale"], cache["v_scale"]),
                cfg.n_layers, cfg.scan_layers)
            return h, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        h, (ks, vs) = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                      cache["v"]), cfg.n_layers, cfg.scan_layers)
        return h, {"k": ks, "v": vs}

    def apply_prefill_slots(self, layers, x, cache, starts, active):
        """Batched slot prefill: every lane's chunk writes into ITS cache
        row at ITS own offset in one launch (PR 5 follow-up (b) — the last
        O(slots) dispatch in the scheduler step loop). x: (B, C, D) lane-
        stacked chunk embeddings (lane b <-> cache row b); starts: (B,)
        int32 per-lane absolute offsets; active: (B,) bool — inactive
        lanes (idle/decoding slots riding along for the fixed batch shape)
        compute garbage attention but their cache rows are passed through
        bitwise-untouched via a per-lane select, so the launch never
        perturbs a decoding slot's live entries. Per-lane math is bitwise
        identical to ``apply_prefill_slot`` on the same row: batched
        einsums are row-independent and the (B,) ``q_offset`` masks each
        lane at its own positions. Returns (hidden (B, C, D), cache)."""
        cfg = self.cfg
        b, c, _ = x.shape
        s_cache = cache["k"].shape[2]
        positions = jnp.arange(c, dtype=jnp.int32)[None] + starts[:, None]
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, c))
        kv8 = cfg.kv_cache_bits == 8
        lane_on = active.reshape(b, 1, 1, 1)

        def rows_update(cache_l, new):
            """Write lane b's chunk into cache_l (B, S, ...) at
            (b, starts[b]); inactive lanes keep their original row.
            Returns (updated cache_l, updated rows)."""
            upd = jax.vmap(
                lambda row, n, st: jax.lax.dynamic_update_slice_in_dim(
                    row, n, st, axis=0))(cache_l, new.astype(cache_l.dtype),
                                         starts)
            out = jnp.where(lane_on, upd, cache_l)
            return out, out

        def body(h, xs):
            if kv8:
                pl, idx, k_l, v_l, ks_l, vs_l = xs
            else:
                pl, idx, k_l, v_l = xs
            q, k, v = self._qkv(pl, h, positions)  # k/v: (B, C, KV, hd)
            if kv8:
                kc, kscale = self._quant_kv(k)
                vc, vscale = self._quant_kv(v)
                k_l, k_row = rows_update(k_l, kc)
                v_l, v_row = rows_update(v_l, vc)
                ks_l, ks_row = rows_update(ks_l, kscale)
                vs_l, vs_row = rows_update(vs_l, vscale)
                k_row = k_row.astype(cfg.dtype) * ks_row.astype(cfg.dtype)
                v_row = v_row.astype(cfg.dtype) * vs_row.astype(cfg.dtype)
            else:
                k_l, k_row = rows_update(k_l, k)
                v_l, v_row = rows_update(v_l, v)
            kr = repeat_kv(k_row, cfg.n_heads // cfg.n_kv_heads)
            vr = repeat_kv(v_row, cfg.n_heads // cfg.n_kv_heads)
            win = self._layer_window(idx, s_cache)
            attn = flash_attention(q, kr, vr, causal=True, window=win,
                                   softcap_val=cfg.attn_softcap,
                                   q_offset=starts)
            attn = mm(attn.reshape(b, c, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = h + attn
            h = h + self._ffn(pl, h)
            if kv8:
                return h, (k_l, v_l, ks_l, vs_l)
            return h, (k_l, v_l)

        if kv8:
            h, (ks, vs, kss, vss) = self._run_layers(
                body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                          cache["v"], cache["k_scale"], cache["v_scale"]),
                cfg.n_layers, cfg.scan_layers)
            return h, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        h, (ks, vs) = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                      cache["v"]), cfg.n_layers, cfg.scan_layers)
        return h, {"k": ks, "v": vs}

    # -------------------------------------------------------------- decode
    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_bits == 8:
            # int8 cache + per-(token, head) scale: extends the paper's
            # weight quantization to the KV cache, which dominates the
            # decode memory floor at 32k×128 (687 GB vs 7 GB of W4 weights)
            sshape = shape[:-1] + (1,)
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.ones(sshape, jnp.bfloat16),
                "v_scale": jnp.ones(sshape, jnp.bfloat16),
            }
        return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}

    @staticmethod
    def _quant_kv(x):
        """(B, T, KV, hd) -> int8 codes + (B, T, KV, 1) scale."""
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-6) / 127.0
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return codes.astype(jnp.int8), scale.astype(jnp.bfloat16)

    @staticmethod
    def _cache_insert(cache_l, new, pos):
        """Insert ``new`` (B, T, KV, hd) into ``cache_l`` (B, S, KV, hd) at
        sequence offset ``pos`` — a shared scalar (the slot-chunked engine:
        every slot at the same length) or a (B,) vector of per-slot write
        positions (continuous batching: slots advance independently)."""
        new = new.astype(cache_l.dtype)
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            return jax.lax.dynamic_update_slice(cache_l, new, (0, pos, 0, 0))
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
        )(cache_l, new, pos)

    def apply_decode(self, layers, x, cache, length):
        """x: (B, 1, D) embedded token; cache k/v (L, B, S, KV, hd);
        length: number of valid tokens already cached — a scalar int32
        (slot-chunked serving: every slot at the same position) or a (B,)
        int32 vector of per-slot lengths (continuous batching: each slot's
        token writes at its own cache offset and attends its own prefix)."""
        cfg = self.cfg
        b = x.shape[0]
        length = jnp.asarray(length)
        if length.ndim:
            positions = length[:, None].astype(jnp.int32)
        else:
            positions = jnp.full((b, 1), length, jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, 1))

        def body(h, xs):
            if cfg.kv_cache_bits == 8:
                pl, idx, k_l, v_l, ks_l, vs_l = xs
            else:
                pl, idx, k_l, v_l = xs
                ks_l = vs_l = None
            q, k, v = self._qkv(pl, h, positions)  # k/v: (B, 1, KV, hd)
            if cfg.kv_cache_bits == 8:
                kc, ks = self._quant_kv(k)
                vc, vs = self._quant_kv(v)
                k_l = self._cache_insert(k_l, kc, length)
                v_l = self._cache_insert(v_l, vc, length)
                ks_l = self._cache_insert(ks_l, ks, length)
                vs_l = self._cache_insert(vs_l, vs, length)
                k_use = k_l.astype(cfg.dtype) * ks_l.astype(cfg.dtype)
                v_use = v_l.astype(cfg.dtype) * vs_l.astype(cfg.dtype)
            else:
                k_l = self._cache_insert(k_l, k, length)
                v_l = self._cache_insert(v_l, v, length)
                k_use, v_use = k_l, v_l
            win = self._layer_window(idx, k_l.shape[1])
            if cfg.grouped_decode_attn:
                attn = decode_attention_gqa(q, k_use, v_use, length + 1,
                                            window=win,
                                            softcap_val=cfg.attn_softcap)
            else:
                kr = repeat_kv(k_use, cfg.n_heads // cfg.n_kv_heads)
                vr = repeat_kv(v_use, cfg.n_heads // cfg.n_kv_heads)
                attn = decode_attention(q, kr, vr, length + 1, window=win,
                                        softcap_val=cfg.attn_softcap)
            attn = mm(attn.reshape(b, 1, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = h + attn
            h = h + self._ffn(pl, h)
            if cfg.kv_cache_bits == 8:
                return h, (k_l, v_l, ks_l, vs_l)
            return h, (k_l, v_l)

        if cfg.kv_cache_bits == 8:
            h, (ks, vs, kss, vss) = self._run_layers(
                body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                          cache["v"], cache["k_scale"], cache["v_scale"]),
                cfg.n_layers, cfg.scan_layers)
            return h, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        h, (ks, vs) = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                      cache["v"]), cfg.n_layers, cfg.scan_layers)
        return h, {"k": ks, "v": vs}

    def apply_verify_slots(self, layers, x, cache, lengths):
        """Speculative-verify window: x (B, C, D) embeds [cur_tok,
        draft_1..draft_{C-1}]; ``lengths`` (B,) int32 is each slot's cached
        prefix. All C tokens' K/V are inserted at ``lengths[b]..
        lengths[b]+C-1`` BEFORE attention; ``verify_attention``'s per-query
        horizon then shows query j exactly ``lengths[b]+j+1`` keys, so row
        j of the result computes exactly what the j-th sequential
        ``apply_decode`` call would produce (later-position K/V land in
        the masked region, where softmax contributes exact zeros; fused
        reductions may differ within ~1 ulp at C-wide shapes, so the
        parity contract is greedy-argmax identity per row).
        Rejected tokens' K/V simply stay past the accepted length — the
        standard stale-region invariant — so cache rollback is pure
        length bookkeeping. Callers must guarantee lengths[b] + C <=
        max_seq for every lane (the scheduler's k_eff clamp): the write
        is a ``dynamic_update_slice``, whose start-clamping would
        otherwise corrupt live prefix entries."""
        cfg = self.cfg
        b, c, _ = x.shape
        lengths = jnp.asarray(lengths).astype(jnp.int32)
        positions = lengths[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, c))

        def body(h, xs):
            if cfg.kv_cache_bits == 8:
                pl, idx, k_l, v_l, ks_l, vs_l = xs
            else:
                pl, idx, k_l, v_l = xs
                ks_l = vs_l = None
            q, k, v = self._qkv(pl, h, positions)  # k/v: (B, C, KV, hd)
            if cfg.kv_cache_bits == 8:
                kc, ks = self._quant_kv(k)
                vc, vs = self._quant_kv(v)
                k_l = self._cache_insert(k_l, kc, lengths)
                v_l = self._cache_insert(v_l, vc, lengths)
                ks_l = self._cache_insert(ks_l, ks, lengths)
                vs_l = self._cache_insert(vs_l, vs, lengths)
                k_use = k_l.astype(cfg.dtype) * ks_l.astype(cfg.dtype)
                v_use = v_l.astype(cfg.dtype) * vs_l.astype(cfg.dtype)
            else:
                k_l = self._cache_insert(k_l, k, lengths)
                v_l = self._cache_insert(v_l, v, lengths)
                k_use, v_use = k_l, v_l
            win = self._layer_window(idx, k_l.shape[1])
            if cfg.grouped_decode_attn:
                attn = verify_attention_gqa(q, k_use, v_use, lengths,
                                            window=win,
                                            softcap_val=cfg.attn_softcap)
            else:
                kr = repeat_kv(k_use, cfg.n_heads // cfg.n_kv_heads)
                vr = repeat_kv(v_use, cfg.n_heads // cfg.n_kv_heads)
                attn = verify_attention(q, kr, vr, lengths, window=win,
                                        softcap_val=cfg.attn_softcap)
            attn = mm(attn.reshape(b, c, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = h + attn
            h = h + self._ffn(pl, h)
            if cfg.kv_cache_bits == 8:
                return h, (k_l, v_l, ks_l, vs_l)
            return h, (k_l, v_l)

        if cfg.kv_cache_bits == 8:
            h, (ks, vs, kss, vss) = self._run_layers(
                body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                          cache["v"], cache["k_scale"], cache["v_scale"]),
                cfg.n_layers, cfg.scan_layers)
            return h, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        h, (ks, vs) = self._run_layers(
            body, x, (layers, jnp.arange(cfg.n_layers), cache["k"],
                      cache["v"]), cfg.n_layers, cfg.scan_layers)
        return h, {"k": ks, "v": vs}

    def paged_kernel_supported(self):
        """Static support check for routing decode through
        ``flash_decode_gqa_paged``: the kernel has no sliding-window or
        softcap path (configs using either keep the gather route)."""
        cfg = self.cfg
        if cfg.local_window:
            return False, "paged decode kernel has no sliding-window mask"
        if cfg.attn_softcap:
            return False, "paged decode kernel has no softcap path"
        if cfg.n_heads % cfg.n_kv_heads != 0:
            return False, "n_heads not a multiple of n_kv_heads"
        return True, "supported"

    def apply_decode_paged(self, layers, x, pools, table, lengths,
                           interpret: bool = False):
        """Decode ONE token per slot directly against the paged pools — no
        gather-to-dense-view detour. x: (B, 1, D); ``pools`` leaves are
        (L, P+1, page, KV, hd) (last physical page = the scratch sink);
        ``table``: (B, pps) int32 physical page per logical page;
        ``lengths``: (B,) valid tokens per slot. Each layer writes the new
        K/V at (table[b, lengths[b]//page], lengths[b]%page) — free slots
        all route to the scratch page, where write order is irrelevant —
        then attends via the scalar-prefetched ``flash_decode_gqa_paged``
        kernel. NOT bitwise with the gather path (online softmax
        normalizes divide-after vs the decode formula's divide-before);
        parity is allclose-level, verified in interpret mode in tests."""
        cfg = self.cfg
        b = x.shape[0]
        lengths = jnp.asarray(lengths).astype(jnp.int32)
        page = pools["k"].shape[2]
        positions = lengths[:, None]
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, 1))
        table = jnp.asarray(table).astype(jnp.int32)
        pps = table.shape[1]
        page_idx = jnp.minimum(lengths // page, pps - 1)
        phys = jnp.take_along_axis(table, page_idx[:, None], axis=1)[:, 0]
        off = lengths % page

        def body(h, xs):
            if cfg.kv_cache_bits == 8:
                pl, k_l, v_l, ks_l, vs_l = xs
            else:
                pl, k_l, v_l = xs
                ks_l = vs_l = None
            q, k, v = self._qkv(pl, h, positions)  # k/v: (B, 1, KV, hd)
            if cfg.kv_cache_bits == 8:
                kc, ks = self._quant_kv(k)
                vc, vs = self._quant_kv(v)
                k_l = k_l.at[phys, off].set(kc[:, 0].astype(k_l.dtype))
                v_l = v_l.at[phys, off].set(vc[:, 0].astype(v_l.dtype))
                ks_l = ks_l.at[phys, off].set(ks[:, 0].astype(ks_l.dtype))
                vs_l = vs_l.at[phys, off].set(vs[:, 0].astype(vs_l.dtype))
            else:
                k_l = k_l.at[phys, off].set(k[:, 0].astype(k_l.dtype))
                v_l = v_l.at[phys, off].set(v[:, 0].astype(v_l.dtype))
            attn = flash_decode_gqa_paged(q, k_l, v_l, table, lengths + 1,
                                          k_scale_pool=ks_l,
                                          v_scale_pool=vs_l,
                                          interpret=interpret)
            attn = mm(attn.reshape(b, 1, cfg.q_dim), pl["wo"])
            if "post_attn_norm" in pl:
                attn = rms_norm(attn, pl["post_attn_norm"])
            h = h + attn
            h = h + self._ffn(pl, h)
            if cfg.kv_cache_bits == 8:
                return h, (k_l, v_l, ks_l, vs_l)
            return h, (k_l, v_l)

        if cfg.kv_cache_bits == 8:
            h, (ks, vs, kss, vss) = self._run_layers(
                body, x, (layers, pools["k"], pools["v"],
                          pools["k_scale"], pools["v_scale"]),
                cfg.n_layers, cfg.scan_layers)
            return h, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
        h, (ks, vs) = self._run_layers(
            body, x, (layers, pools["k"], pools["v"]),
            cfg.n_layers, cfg.scan_layers)
        return h, {"k": ks, "v": vs}
