"""ModelConfig: one dataclass describing every assigned architecture.

``family`` selects the layer body:
  dense   — llama-style decoder (covers gemma2/internlm2/qwen3/mistral/qwen2-vl
            via flags: softcaps, local+global attention, qk_norm, M-RoPE)
  moe     — dense skeleton with a routed-expert FFN
  rwkv6   — attention-free Finch blocks (token shift + data-dependent decay)
  hymba   — parallel attention + Mamba(SSM) heads per layer
  encoder — bidirectional encoder (HuBERT backbone, masked-unit loss)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # dense variants
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    local_window: int = 0          # >0: sliding-window size for local layers
    global_every: int = 0          # gemma2: every 2nd layer is global
    global_layers: Tuple[int, ...] = ()  # hymba: explicit global layer ids
    mrope: bool = False            # qwen2-vl multimodal rope
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # moe
    n_experts: int = 0
    topk: int = 0
    moe_impl: str = "capacity"     # "capacity" (GSPMD-safe) | "ragged"
    capacity_factor: float = 1.25

    # ssm / rwkv
    ssm_state: int = 16
    rwkv_head_dim: int = 64
    d_inner: int = 0               # hymba mamba inner width (0 -> 2*d_model)

    # modality stubs
    frontend: str = "text"         # text | audio_stub | vision_stub

    # runtime
    dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "full"     # full | dots (save matmul outs) | none
    loss_chunk: int = 256
    scan_layers: bool = True

    # perf levers (beyond-paper; default off = paper-faithful baseline)
    grouped_decode_attn: bool = False  # GQA decode without repeat_kv
    expert_parallel: bool = False      # shard experts over the model axis
    kv_cache_bits: int = 16            # 8 -> int8 KV cache (+per-entry scale)

    # dry-run annotations
    sub_quadratic: bool = False    # supports long_500k decode
    is_encoder: bool = False       # no decode shapes

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner_resolved(self) -> int:
        return self.d_inner or 2 * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "encoder"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.family == "moe":
                ffn = self.n_experts * 3 * d * f + d * self.n_experts
            elif self.family == "encoder":
                ffn = 2 * d * f
            else:
                ffn = 3 * d * f
            return emb + L * (attn + ffn)
        if self.family == "rwkv6":
            tm = 5 * d * d + 2 * d * 64
            cm = d * f + f * d + d * d
            return emb + L * (tm + cm)
        if self.family == "hymba":
            di = self.d_inner_resolved
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            mamba = d * 2 * di + di * self.ssm_state * 2 + di * d + 4 * di
            return emb + L * (attn + mamba)
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only top-k experts."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = self.topk * 3 * d * f + d * self.n_experts
        return emb + L * (attn + ffn)


def small_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    shrunk = dict(
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        dtype=jnp.float32,
        remat=False,
        loss_chunk=64,
        local_window=min(cfg.local_window, 32) if cfg.local_window else 0,
        global_layers=(0,) if cfg.global_layers else (),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        topk=min(cfg.topk, 2) if cfg.topk else 0,
        d_inner=64 if cfg.family == "hymba" else 0,
        ssm_state=8 if cfg.family in ("hymba",) else cfg.ssm_state,
        name=cfg.name + "-smoke",
    )
    shrunk.update(overrides)
    return dataclasses.replace(cfg, **shrunk)
