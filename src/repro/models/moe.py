"""Routed mixture-of-experts FFN (grok-1, qwen3-moe).

Two dispatch implementations:

  * ``capacity`` (default) — GShard-style fixed-capacity gather/scatter.
    Fully dense einsums, GSPMD-partitions cleanly on a (data, model) mesh
    (experts replicated over `model`, expert d_ff sharded over `model`,
    token/capacity dims sharded over `data`). Tokens beyond an expert's
    capacity are dropped (standard at scale; capacity_factor 1.25).

  * ``ragged`` — dropless grouped matmul via ``jax.lax.ragged_dot`` after an
    argsort-by-expert. Exact top-k semantics; used on CPU/single-device and
    in correctness tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import constrain


def _expert_mm(xg, w, eq: str):
    """Expert einsum that also accepts FLRQ-quantized expert weights (a
    QuantizedLinear pytree with a leading E axis): routed through the
    serving runtime's backend dispatch (``quant.apply.dispatch``), so
    experts take the lane-stacked fused kernel on TPU and the ref path
    elsewhere — with every fallback recorded in the dispatch log, exactly
    like the dense layers (``models.layers.mm``)."""
    from ..quant.qtensor import QuantizedLinear

    if isinstance(w, QuantizedLinear):
        from ..quant.apply import dispatch

        if xg.ndim == 4:  # (B, E, c, D): expert is the tensor's lane dim
            y = dispatch(w, jnp.swapaxes(xg, 0, 1), out_dtype=xg.dtype)
            return jnp.swapaxes(y, 0, 1)
        return dispatch(w, xg, out_dtype=xg.dtype)  # (E, c, D)
    return jnp.einsum(eq, xg, w)


def router_topk(x_flat, w_router, topk: int):
    """x_flat: (T, D); returns (weights (T,k), idx (T,k)) with renormalized
    softmax gates (f32 routing as is standard)."""
    logits = x_flat.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, topk)
    vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    return vals, idx


def moe_ffn_capacity(x, w_router, w_gate, w_up, w_down, topk: int,
                     capacity_factor: float = 1.25):
    """x: (B, S, D). Expert weights: (E, D, F) / (E, F, D)."""
    b, s, d = x.shape
    e = w_router.shape[1]
    t = b * s
    xf = x.reshape(t, d)
    vals, idx = router_topk(xf, w_router, topk)

    # combine weights as a dense (T, E) map (zero where not routed)
    comb = jnp.zeros((t, e), jnp.float32)
    comb = comb.at[jnp.arange(t)[:, None], idx].add(vals)

    cap = int(max(1, round(t * topk * capacity_factor / e)))
    cap = min(cap, t)
    # per-expert: top-`cap` tokens by gate weight
    gates_e, tok_e = jax.lax.top_k(comb.T, cap)          # (E, cap)
    xg = jnp.take(xf, tok_e, axis=0)                     # (E, cap, D)
    xg = constrain(xg, P(None, ("pod", "data"), None))
    h = jax.nn.silu(_expert_mm(xg, w_gate, "ecd,edf->ecf")) * _expert_mm(
        xg, w_up, "ecd,edf->ecf")
    h = constrain(h, P(None, ("pod", "data"), "model"))
    ye = _expert_mm(h, w_down, "ecf,efd->ecd")           # (E, cap, D)
    ye = ye * gates_e[..., None].astype(ye.dtype)
    y = jnp.zeros((t, d), ye.dtype).at[tok_e.reshape(-1)].add(
        ye.reshape(-1, d))
    return y.reshape(b, s, d).astype(x.dtype)


def moe_ffn_ragged(x, w_router, w_gate, w_up, w_down, topk: int):
    """Dropless dispatch via sort + ragged grouped matmul."""
    b, s, d = x.shape
    e = w_router.shape[1]
    t = b * s
    xf = x.reshape(t, d)
    vals, idx = router_topk(xf, w_router, topk)

    flat_e = idx.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    xr = jnp.repeat(xf, topk, axis=0)[order]              # (T*k, D) sorted
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    h = jax.nn.silu(jax.lax.ragged_dot(xr, w_gate, group_sizes)) * \
        jax.lax.ragged_dot(xr, w_up, group_sizes)
    y = jax.lax.ragged_dot(h, w_down, group_sizes)        # (T*k, D)
    y = y[inv].reshape(t, topk, d) * vals[..., None].astype(y.dtype)
    return jnp.sum(y, axis=1).reshape(b, s, d).astype(x.dtype)


def moe_ffn_grouped(x, w_router, w_gate, w_up, w_down, topk: int,
                    capacity_factor: float = 1.25,
                    expert_parallel: bool = False):
    """Group-limited (per-batch-row) capacity dispatch — the beyond-paper
    collective fix. The flat ``capacity`` impl top-ks and gathers over the
    *global* token axis, which under a batch-sharded mesh forces an
    all-gather of every token's activations per layer (measured 6.5 s/step
    collective on qwen3-moe train_4k). Routing each batch row against
    row-local capacity keeps every gather/scatter shard-local — the only
    remaining MoE collectives are the expert-weight FSDP gathers. Same
    drop semantics as GShard group dispatch (groups = batch rows)."""
    b, s, d = x.shape
    e = w_router.shape[1]
    cap = int(max(1, round(s * topk * capacity_factor / e)))
    cap = min(cap, s)

    def per_row(xr):  # (S, D) — everything below is row-local
        vals, idx = router_topk(xr, w_router, topk)
        comb = jnp.zeros((s, e), jnp.float32)
        comb = comb.at[jnp.arange(s)[:, None], idx].add(vals)
        gates_e, tok_e = jax.lax.top_k(comb.T, cap)       # (E, cap)
        xg = jnp.take(xr, tok_e, axis=0)                  # (E, cap, D)
        return xg, gates_e, tok_e

    xg, gates_e, tok_e = jax.vmap(per_row)(x)             # (B, E, cap, D)
    if expert_parallel:
        xg = constrain(xg, P(("pod", "data"), "model", None, None))
    else:
        xg = constrain(xg, P(("pod", "data"), None, None, None))
    h = jax.nn.silu(_expert_mm(xg, w_gate, "becd,edf->becf")) * _expert_mm(
        xg, w_up, "becd,edf->becf")
    if expert_parallel:
        h = constrain(h, P(("pod", "data"), "model", None, None))
    else:
        h = constrain(h, P(("pod", "data"), None, None, "model"))
    ye = _expert_mm(h, w_down, "becf,efd->becd")
    # keep the combine in the activation dtype — a f32 gate multiply would
    # double every downstream collective's wire bytes
    ye = ye * gates_e[..., None].astype(ye.dtype)

    def scatter_row(ye_r, tok_r):  # row-local scatter-add
        return jnp.zeros((s, d), ye_r.dtype).at[tok_r.reshape(-1)].add(
            ye_r.reshape(-1, d))

    y = jax.vmap(scatter_row)(ye, tok_e)
    return y.astype(x.dtype)


def moe_ffn(x, w_router, w_gate, w_up, w_down, topk: int,
            impl: str = "capacity", capacity_factor: float = 1.25,
            expert_parallel: bool = False):
    if impl == "ragged":
        return moe_ffn_ragged(x, w_router, w_gate, w_up, w_down, topk)
    if impl == "grouped":
        return moe_ffn_grouped(x, w_router, w_gate, w_up, w_down, topk,
                               capacity_factor, expert_parallel)
    return moe_ffn_capacity(x, w_router, w_gate, w_up, w_down, topk,
                            capacity_factor)
