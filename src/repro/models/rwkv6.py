"""RWKV-6 "Finch" stack (attention-free, data-dependent decay).

Per layer: time-mix (WKV) + channel-mix, both with token-shift. The WKV
recurrence per head (hd = 64):

    kv_t = k_t ⊗ v_t                               (hd_k, hd_v)
    y_t  = r_t · (S_{t-1} + diag(u) kv_t)
    S_t  = diag(w_t) S_{t-1} + kv_t

with data-dependent decay  w_t = exp(-exp(w_base + LoRA(x_t)))  ∈ (0, 1).

All projections are GEMMs computed for the whole sequence in parallel; only
the O(hd²) state update scans over time. Decode carries (S, x_prev) — O(1)
per token, which is why rwkv6 is a ``long_500k`` architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import constrain, mm, remat_wrap, rms_norm

_SPEC_BSD = P(("pod", "data"), None, None)
_LORA_R = 64


def _init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


class RWKV6Stack:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.d_model % cfg.rwkv_head_dim == 0
        self.n_heads = cfg.d_model // cfg.rwkv_head_dim

    def init_layers(self, key):
        cfg = self.cfg
        L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 14)
        return {
            "ln1": jnp.zeros((L, D), cfg.dtype),
            "ln2": jnp.zeros((L, D), cfg.dtype),
            "mu": jnp.full((L, 5, D), 0.5, cfg.dtype),     # r,k,v,w,g shift mix
            "wr": _init(ks[0], (L, D, D), D, cfg.dtype),
            "wk": _init(ks[1], (L, D, D), D, cfg.dtype),
            "wv": _init(ks[2], (L, D, D), D, cfg.dtype),
            "wg": _init(ks[3], (L, D, D), D, cfg.dtype),
            "wo": _init(ks[4], (L, D, D), D, cfg.dtype),
            "w_base": jnp.full((L, D), -1.0, cfg.dtype),
            "w_lora_a": _init(ks[5], (L, D, _LORA_R), D, cfg.dtype),
            "w_lora_b": jnp.zeros((L, _LORA_R, D), cfg.dtype),
            "u_bonus": jnp.zeros((L, D), cfg.dtype),
            "ln_x": jnp.zeros((L, D), cfg.dtype),
            "mu_cm": jnp.full((L, 2, D), 0.5, cfg.dtype),  # channel-mix shift
            "wk_cm": _init(ks[6], (L, D, F), D, cfg.dtype),
            "wv_cm": _init(ks[7], (L, F, D), F, cfg.dtype),
            "wr_cm": _init(ks[8], (L, D, D), D, cfg.dtype),
        }

    # ---------------------------------------------------------------- parts
    def _heads(self, x):
        b, s, d = x.shape
        return x.reshape(b, s, self.n_heads, self.cfg.rwkv_head_dim)

    def _time_mix_seq(self, pl, x, s0, x_prev0):
        """Full-sequence time-mix. x: (B, S, D); s0: (B, H, hd, hd) initial
        state; x_prev0: (B, D) token before x[0]. Returns (y, s_T, x_last)."""
        cfg = self.cfg
        b, s, d = x.shape
        hd = cfg.rwkv_head_dim
        xz = jnp.concatenate([x_prev0[:, None, :], x[:, :-1]], axis=1)
        mu = pl["mu"].astype(jnp.float32)  # (5, D)
        x32, xz32 = x.astype(jnp.float32), xz.astype(jnp.float32)

        def mix(i):
            return (x32 + mu[i] * (xz32 - x32)).astype(x.dtype)

        r = self._heads(mm(mix(0), pl["wr"]))
        k = self._heads(mm(mix(1), pl["wk"]))
        v = self._heads(mm(mix(2), pl["wv"]))
        w_dd = (mix(3).astype(jnp.float32) @ pl["w_lora_a"].astype(jnp.float32)
                ) @ pl["w_lora_b"].astype(jnp.float32)
        w = jnp.exp(-jnp.exp(pl["w_base"].astype(jnp.float32) + w_dd))
        w = self._heads(w)  # (B, S, H, hd) in (0,1)
        g = jax.nn.silu(mm(mix(4), pl["wg"]))
        u = pl["u_bonus"].astype(jnp.float32).reshape(self.n_heads, hd)

        def step(S, t):
            r_t, k_t, v_t, w_t = t
            kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                            v_t.astype(jnp.float32))
            y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                           S + u[None, :, :, None] * kv)
            S = w_t.astype(jnp.float32)[..., None] * S + kv
            return S, y

        xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
        s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
        y = rms_norm(y, pl["ln_x"]) * g
        return mm(y, pl["wo"]), s_fin.astype(x.dtype), x[:, -1]

    def _channel_mix_seq(self, pl, x, x_prev0):
        xz = jnp.concatenate([x_prev0[:, None, :], x[:, :-1]], axis=1)
        mu = pl["mu_cm"].astype(jnp.float32)
        x32, xz32 = x.astype(jnp.float32), xz.astype(jnp.float32)
        xk = (x32 + mu[0] * (xz32 - x32)).astype(x.dtype)
        xr = (x32 + mu[1] * (xz32 - x32)).astype(x.dtype)
        k = jnp.square(jax.nn.relu(mm(xk, pl["wk_cm"])))
        return jax.nn.sigmoid(mm(xr, pl["wr_cm"])) * mm(k, pl["wv_cm"]), x[:, -1]

    def _layer_seq(self, pl, x, s0, xp_tm, xp_cm):
        h = rms_norm(x, pl["ln1"])
        y, s_fin, xl_tm = self._time_mix_seq(pl, h, s0, xp_tm)
        x = constrain(x + y, _SPEC_BSD)
        h = rms_norm(x, pl["ln2"])
        y, xl_cm = self._channel_mix_seq(pl, h, xp_cm)
        return constrain(x + y, _SPEC_BSD), s_fin, xl_tm, xl_cm

    # ----------------------------------------------------------- interfaces
    def _zero_states(self, batch):
        cfg = self.cfg
        hd = cfg.rwkv_head_dim
        return (
            jnp.zeros((batch, self.n_heads, hd, hd), cfg.dtype),
            jnp.zeros((batch, cfg.d_model), cfg.dtype),
            jnp.zeros((batch, cfg.d_model), cfg.dtype),
        )

    def apply_train(self, layers, x, positions):
        cfg = self.cfg
        b = x.shape[0]
        s0, xp, xc = self._zero_states(b)

        def body(h, pl):
            fn = remat_wrap(self._layer_seq, cfg)
            h, _, _, _ = fn(pl, h, s0, xp, xc)
            return h, None

        h, _ = jax.lax.scan(body, x, layers)
        return h

    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        hd = cfg.rwkv_head_dim
        L = cfg.n_layers
        return {
            "state": jnp.zeros((L, batch, self.n_heads, hd, hd), cfg.dtype),
            "xp_tm": jnp.zeros((L, batch, cfg.d_model), cfg.dtype),
            "xp_cm": jnp.zeros((L, batch, cfg.d_model), cfg.dtype),
        }

    def apply_prefill(self, layers, x, positions):
        b = x.shape[0]
        s0, xp, xc = self._zero_states(b)

        def body(h, pl):
            h, s_fin, xl_tm, xl_cm = self._layer_seq(pl, h, s0, xp, xc)
            return h, (s_fin, xl_tm, xl_cm)

        h, (states, xts, xcs) = jax.lax.scan(body, x, layers)
        return h, {"state": states, "xp_tm": xts, "xp_cm": xcs}

    def apply_decode(self, layers, x, cache, length):
        """x: (B, 1, D). O(1) per token: single-step recurrence per layer."""
        del length

        def body(h, xs):
            pl, S, xp_tm, xp_cm = xs
            h2, s_fin, xl_tm, xl_cm = self._layer_seq(
                pl, h, S.astype(jnp.float32), xp_tm, xp_cm)
            return h2, (s_fin, xl_tm, xl_cm)

        h, (states, xts, xcs) = jax.lax.scan(
            body, x, (layers, cache["state"], cache["xp_tm"], cache["xp_cm"]))
        return h, {"state": states, "xp_tm": xts, "xp_cm": xcs}
