"""LM facade: embedding/unembedding/loss plumbing around a family stack.

Public surface used by the launcher, trainer, server and dry-run:

    model = LM(cfg)
    params = model.init(key)                       (or jax.eval_shape(model.init, key))
    loss   = model.loss(params, batch)             batch from data pipeline
    logits, cache = model.prefill(params, tokens)
    logits, cache = model.decode_step(params, tok, cache, length)

Batches:
  text families : {"tokens": (B, S) int32}  — next-token LM loss (shift-in-loss)
  encoder       : {"frames": (B, S, D) dtype, "labels": (B, S) int32,
                   "mask": (B, S) bool}     — masked-unit prediction (HuBERT)
  vision stub   : {"tokens": ...} text-only shapes; ``vision_stub_embeddings``
                  provides precomputed patch embeddings for VLM examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .dense import DenseStack
from .hymba import HymbaStack
from .layers import chunked_softmax_xent, constrain, rms_norm, softcap
from .rwkv6 import RWKV6Stack

_STACKS = {
    "dense": DenseStack,
    "moe": DenseStack,
    "encoder": DenseStack,
    "rwkv6": RWKV6Stack,
    "hymba": HymbaStack,
}

_SPEC_LOGITS = P(("pod", "data"), None, "model")


class LM:
    """``params`` may hold plain stacked weights or the quantized serving
    tree from ``quant.stacked.quantize_model_stacked`` — stacked
    QuantizedLinear leaves ride the same ``lax.scan`` over layers as dense
    weights (one compiled layer body per prefill/decode executable), with
    each matmul routed through the quant backend-dispatch layer
    (``quant.apply``)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stack = _STACKS[cfg.family](cfg)

    def with_scan(self, scan_layers: bool) -> "LM":
        """Same model with scan-over-layers toggled. ``False`` unrolls the
        stack into L per-layer pytree dispatches per step — the reference
        execution the serving benchmark A/Bs the scanned runtime against."""
        return LM(dataclasses.replace(self.cfg, scan_layers=scan_layers))

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        k_emb, k_stack, k_out = jax.random.split(key, 3)
        params = {
            "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
                      * 0.02).astype(cfg.dtype),
            "layers": self.stack.init_layers(k_stack),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(k_out, (cfg.d_model, cfg.vocab), jnp.float32)
                / jnp.sqrt(cfg.d_model)).astype(cfg.dtype)
        return params

    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    def _positions(self, b, s, offset=0):
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None] + offset, (b, s))
        if self.cfg.mrope:
            return jnp.broadcast_to(pos[:, None, :], (b, 3, s))  # text: t=h=w
        return pos

    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.attn_softcap:  # gemma2 embedding normalizer
            x = x * jnp.sqrt(self.cfg.d_model).astype(x.dtype)
        return constrain(x, P(("pod", "data"), None, None))

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "encoder":
            x = batch["frames"].astype(cfg.dtype)
            b, s, _ = x.shape
            h = self.stack.apply_train(params["layers"], x, self._positions(b, s))
            h = rms_norm(h, params["final_norm"])
            return chunked_softmax_xent(
                h, self._unembed(params), batch["labels"], batch["mask"],
                chunk=cfg.loss_chunk, logits_spec=_SPEC_LOGITS)
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens)
        h = self.stack.apply_train(params["layers"], x, self._positions(b, s))
        h = rms_norm(h, params["final_norm"])
        # next-token: hidden[:, :-1] predicts tokens[:, 1:]
        mask = batch.get("mask")
        mask = jnp.ones((b, s - 1), jnp.float32) if mask is None else mask[:, 1:]
        return chunked_softmax_xent(
            h[:, :-1], self._unembed(params), tokens[:, 1:], mask,
            chunk=cfg.loss_chunk, softcap_final=cfg.final_softcap,
            logits_spec=_SPEC_LOGITS)

    # --------------------------------------------------------------- serving
    def _logits_last(self, params, h_last):
        """h_last: (B, 1, D) -> (B, 1, V)."""
        h = rms_norm(h_last, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            self._unembed(params).astype(jnp.float32))
        if self.cfg.final_softcap:
            logits = softcap(logits, self.cfg.final_softcap)
        return logits

    def prefill(self, params, tokens):
        """tokens: (B, S). Returns (last-position logits (B, 1, V), cache)."""
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens)
        h, cache = self.stack.apply_prefill(
            params["layers"], x, self._positions(b, s))
        return self._logits_last(params, h[:, -1:]), cache

    def init_cache(self, batch: int, seq: int):
        return self.stack.init_cache(batch, seq)

    def prefill_slot(self, params, tokens, cache, slot, start, last):
        """Chunked prefill of ONE prompt into its decode-cache slot region:
        tokens (1, C) int32 is the chunk, ``slot`` the cache row it owns,
        ``start`` the chunk's offset in the prompt and ``last`` the chunk
        index of its last REAL token (all traced scalars, so one executable
        serves every slot and every resume point). Returns (logits (1, 1, V)
        for position ``last`` ONLY — the final chunk's seed for the first
        sampled token; unembedding all C chunk positions would burn C·D·V
        FLOPs per chunk for rows nothing reads — and the updated full
        decode cache)."""
        if not hasattr(self.stack, "apply_prefill_slot"):
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no slot-granular prefill "
                f"(continuous batching serves dense-stack families)")
        x = self._embed_tokens(params, tokens)
        h, cache = self.stack.apply_prefill_slot(
            params["layers"], x, cache, slot, start)
        h_last = jax.lax.dynamic_slice_in_dim(h, last, 1, axis=1)
        return self._logits_last(params, h_last), cache

    def prefill_slots(self, params, tokens, cache, starts, lasts, active):
        """Batched slot prefill: one launch writing B chunks, lane b into
        cache row b at its own offset. tokens (B, C) int32; starts (B,)
        per-lane prompt offsets; lasts (B,) per-lane index of the chunk's
        last REAL token; active (B,) bool — inactive lanes compute garbage
        but their cache rows pass through bitwise-untouched (masked
        write), so idle/decoding slots are unaffected by riding along.
        Returns (logits (B, 1, V) at each lane's ``lasts`` position, and
        the updated cache)."""
        if not hasattr(self.stack, "apply_prefill_slots"):
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no slot-granular prefill "
                f"(continuous batching serves dense-stack families)")
        x = self._embed_tokens(params, tokens)
        h, cache = self.stack.apply_prefill_slots(
            params["layers"], x, cache, starts, active)
        h_last = jnp.take_along_axis(h, lasts[:, None, None], axis=1)
        return self._logits_last(params, h_last), cache

    def decode_step(self, params, tokens, cache, length):
        """tokens: (B,) or (B, 1) int32; length: scalar int32 count of valid
        cache entries, or a (B,) int32 vector of per-slot counts (continuous
        batching: each slot writes and attends at its own position).
        Returns (logits (B, 1, V), new cache)."""
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        x = self._embed_tokens(params, tokens)
        h, cache = self.stack.apply_decode(params["layers"], x, cache, length)
        return self._logits_last(params, h), cache

    def verify_slots(self, params, tokens, cache, lengths):
        """Speculative-verify window: tokens (B, C) = [cur_tok,
        draft_1..draft_{C-1}] per slot; ``lengths`` (B,) int32 cached
        prefix per slot. Returns (logits (B, C, V) — EVERY window
        position is unembedded, that is the point: position j's logits
        compute exactly what the j-th sequential ``decode_step`` would
        emit (same insert order and per-query horizon; greedy argmax per
        row is the parity contract — fused reductions can reorder within
        ~1 ulp at C-wide shapes) — and the cache with all C tokens' K/V
        inserted; rejected tokens simply stay past the accepted length
        as stale masked entries)."""
        if not hasattr(self.stack, "apply_verify_slots"):
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no speculative verify "
                f"(self-speculative decode serves dense-stack families)")
        x = self._embed_tokens(params, tokens)
        h, cache = self.stack.apply_verify_slots(
            params["layers"], x, cache, lengths)
        return self._logits_last(params, h), cache

    def decode_step_paged(self, params, tokens, pools, table, lengths,
                          interpret: bool = False):
        """Paged-kernel decode step: like ``decode_step`` but K/V land
        directly in the (L, P+1, page, KV, hd) pools at page-table
        positions and attention runs the ``flash_decode_gqa_paged``
        kernel — no gather-to-dense-view. Returns (logits (B, 1, V),
        updated pools). Allclose (not bitwise) to the gather path."""
        if not hasattr(self.stack, "apply_decode_paged"):
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no paged decode kernel "
                f"path")
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        x = self._embed_tokens(params, tokens)
        h, pools = self.stack.apply_decode_paged(
            params["layers"], x, pools, table, lengths, interpret=interpret)
        return self._logits_last(params, h), pools


# ---------------------------------------------------------------------------
# Modality frontend stubs (per the brief: [audio]/[vlm] backbones only)
# ---------------------------------------------------------------------------

def audio_stub_embeddings(key, batch: int, frames: int, d_model: int, dtype):
    """Stand-in for the HuBERT conv feature extractor: precomputed frame
    embeddings."""
    return jax.random.normal(key, (batch, frames, d_model), jnp.float32).astype(dtype)


def vision_stub_embeddings(key, batch: int, patches: int, d_model: int, dtype):
    """Stand-in for the Qwen2-VL ViT: precomputed patch embeddings (dynamic
    resolution → variable `patches`)."""
    return jax.random.normal(key, (batch, patches, d_model), jnp.float32).astype(dtype)


def mrope_positions_for_image(batch: int, grid_t: int, grid_h: int, grid_w: int):
    """(B, 3, T*H*W) M-RoPE position ids for an image/video patch grid."""
    t = jnp.arange(grid_t).repeat(grid_h * grid_w)
    h = jnp.tile(jnp.arange(grid_h).repeat(grid_w), grid_t)
    w = jnp.tile(jnp.arange(grid_w), grid_t * grid_h)
    pos = jnp.stack([t, h, w]).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, 3, grid_t * grid_h * grid_w))
