"""Deterministic synthetic data pipeline (offline stand-in for WikiText/C4).

Design goals that matter at 1000-node scale and are honored here:
  * deterministic, seekable sharding — batch(step, host) is a pure function,
    so restarts and elastic re-meshing never replay or skip data, and a
    straggler host can recompute any shard without coordination;
  * a "document" distribution with enough structure that a ~100M model has
    something to learn (Zipfian unigrams + a Markov backbone + template
    phrases), so quantization PPL deltas are meaningful;
  * calibration sampling exactly as the paper: N random segments of
    ``seq_len`` tokens (default 128 × 2048).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 16384
    seq_len: int = 512
    global_batch: int = 32
    seed: int = 1234
    markov_order_mix: float = 0.85  # weight of the Markov backbone
    n_templates: int = 64
    template_len: int = 12


class SyntheticCorpus:
    """Zipf + first-order Markov + template-phrase token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # Zipfian unigram distribution
        ranks = np.arange(1, v + 1)
        self.unigram = (1.0 / ranks**1.1)
        self.unigram /= self.unigram.sum()
        # sparse Markov backbone: each token has ~32 plausible successors
        self.n_succ = 32
        self.succ = rng.integers(0, v, size=(v, self.n_succ), dtype=np.int32)
        succ_w = rng.dirichlet(np.ones(self.n_succ) * 0.3, size=v)
        self.succ_w = succ_w.astype(np.float32)
        # template phrases (memorizable n-grams)
        self.templates = rng.integers(
            0, v, size=(cfg.n_templates, cfg.template_len), dtype=np.int32)

    def sample_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(n + cfg.template_len, dtype=np.int32)
        tok = int(rng.choice(cfg.vocab, p=self.unigram))
        i = 0
        while i < n:
            r = rng.random()
            if r < 0.02:  # drop in a template phrase
                t = self.templates[rng.integers(cfg.n_templates)]
                k = min(len(t), n + cfg.template_len - i)
                out[i:i + k] = t[:k]
                i += k
                tok = int(out[i - 1])
            elif r < 0.02 + cfg.markov_order_mix:
                j = rng.choice(self.n_succ, p=self.succ_w[tok])
                tok = int(self.succ[tok, j])
                out[i] = tok
                i += 1
            else:
                tok = int(rng.choice(cfg.vocab, p=self.unigram))
                out[i] = tok
                i += 1
        return out[:n]

    # ------------------------------------------------------------- batching
    def batch_at(self, step: int, host: int = 0, n_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        """Pure function (step, host) -> host-local batch shard."""
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        b_local = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed, step, host))  # seekable & collision-free
        toks = np.stack([
            self.sample_tokens(rng, cfg.seq_len) for _ in range(b_local)])
        return {"tokens": toks}

    def iterate(self, start_step: int = 0, host: int = 0, n_hosts: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host, n_hosts)
            step += 1

    # ---------------------------------------------------------- calibration
    def calibration_batch(self, n_segments: int = 128,
                          seq_len: Optional[int] = None) -> np.ndarray:
        """(n_segments, seq_len) token segments, as the paper's 128×2048
        WikiText sampling."""
        seq_len = seq_len or self.cfg.seq_len
        rng = np.random.default_rng((self.cfg.seed, 0xCA11B))
        return np.stack([self.sample_tokens(rng, seq_len)
                         for _ in range(n_segments)])


def collect_layer_activations(model, params, tokens: np.ndarray,
                              max_tokens: int = 8192) -> Dict[str, jnp.ndarray]:
    """Run calibration tokens through the model, capturing the input
    activation batch for each quantizable matrix (keyed by param path, as
    ``core.flrq.quantize_model`` expects).

    Uses the embedding-stream approximation: per-layer inputs are captured
    from a forward pass via closure interception in the stack (dense family)
    — for other families we fall back to the post-embedding stream, which is
    the dominant statistic for Eq. 11 scaling.
    """
    tok = jnp.asarray(tokens[: max(1, max_tokens // tokens.shape[1])])
    x = jnp.take(params["embed"], tok, axis=0)
    flat = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    acts: Dict[str, jnp.ndarray] = {}

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.shape[-2] == flat.shape[-1]:
            acts[pstr] = flat
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return acts
