"""Pallas kernels: group-wise quantize (+ bit-pack) in one pass.

BLC re-quantizes the residual every epoch (paper Alg. 2 step 3), so the
quantize+pack inner loop is on the quantization-time critical path. One
pass over W per call: per-128-group min/max reduction, scale/zp, round,
clamp, and nibble-packing all in VREGs; W is read exactly once from HBM.

Two entry points share the same in-register quant math (``_block_stats`` /
``_block_qdq`` — also reused by ``kernels.clip_sweep``):

  * ``group_quant``        — codes packed to uint8 (+ scale, zp). Static
    clip ratio (the packing epilogue of the pipeline).
  * ``group_pseudo_quant`` — the dequantized round-trip Q(W; clip) with a
    *traced* clip ratio fed through SMEM: this is what the clip-grid sweep
    calls ONCE at its argmin (the winning clip is data-dependent, so it
    cannot be baked into the kernel like ``group_quant``'s).

Supports bits ∈ {2, 4, 8} (the 3-bit pack crosses byte boundaries — it
stays on the jnp path, ``ref.group_quant_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _block_stats(g, *, bits, symmetric):
    """Per-group range stats of a grouped block g: (bm, bk//group, group)
    -> tuple of (bm, bk//group, 1) arrays. Mirrors core.quantize.group_stats
    exactly (one reduction, reused by every clip ratio)."""
    del bits
    if symmetric:
        return (jnp.max(jnp.abs(g), axis=-1, keepdims=True),)
    return (jnp.min(g, axis=-1, keepdims=True),
            jnp.max(g, axis=-1, keepdims=True))


def _block_qdq(g, stats, clip_ratio, *, bits, symmetric):
    """Quantize-dequantize a grouped block under ``clip_ratio`` using
    precomputed stats. Returns (deq, scale, zp, codes_unsigned); op order
    matches core.quantize.qparams_from_stats/quantize_codes bit for bit."""
    qmax_sym = (1 << (bits - 1)) - 1
    levels = (1 << bits) - 1
    if symmetric:
        amax = stats[0] * clip_ratio
        scale = amax / qmax_sym
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.zeros_like(scale)
        q = jnp.clip(jnp.round(g / scale), -(qmax_sym + 1), qmax_sym)
        deq = q * scale
        codes = (q + (1 << (bits - 1))).astype(jnp.uint32)
    else:
        wmin = stats[0] * clip_ratio
        wmax = stats[1] * clip_ratio
        scale = (wmax - wmin) / levels
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.round(-wmin / scale)
        q = jnp.clip(jnp.round(g / scale) + zp, 0, levels)
        deq = (q - zp) * scale
        codes = q.astype(jnp.uint32)
    return deq, scale, zp, codes


def _kernel(w_ref, packed_ref, scale_ref, zp_ref, *, bits, group,
            symmetric, clip_ratio):
    w = w_ref[...].astype(jnp.float32)
    bm, bk = w.shape
    g = w.reshape(bm, bk // group, group)
    stats = _block_stats(g, bits=bits, symmetric=symmetric)
    _, scale, zp, codes = _block_qdq(g, stats, clip_ratio, bits=bits,
                                     symmetric=symmetric)
    scale_ref[...] = scale
    zp_ref[...] = zp
    per = 8 // bits
    c = codes.reshape(bm, bk // per, per)
    byte = jnp.zeros((bm, bk // per), jnp.uint32)
    for i in range(per):
        byte = byte | (c[..., i] << (bits * i))
    packed_ref[...] = byte.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("bits", "group", "symmetric", "bm", "bk",
                              "interpret"))
def group_quant(w, *, bits: int, group: int = 128, symmetric: bool = False,
                clip_ratio: float = 1.0, bm: int = 256, bk: int = 1024,
                interpret: bool = False):
    """w: (m, n) -> (packed (m, n//group, group*bits/8) uint8,
    scale (m, n//group, 1) f32, zp (m, n//group, 1) f32)."""
    assert bits in (2, 4, 8), "3-bit packing crosses bytes; use ref path"
    m, n = w.shape
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and m % bm == 0 and n % bk == 0
    per = 8 // bits
    packed, scale, zp = pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group,
                          symmetric=symmetric, clip_ratio=clip_ratio),
        grid=(m // bm, n // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk // per), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n // per), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // group, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, n // group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w)
    pg = group * bits // 8
    return packed.reshape(m, n // group, pg), scale, zp


def _pseudo_kernel(clip_ref, w_ref, out_ref, *, bits, group, symmetric):
    w = w_ref[...].astype(jnp.float32)
    bm, bk = w.shape
    g = w.reshape(bm, bk // group, group)
    stats = _block_stats(g, bits=bits, symmetric=symmetric)
    deq, _, _, _ = _block_qdq(g, stats, clip_ref[0], bits=bits,
                              symmetric=symmetric)
    out_ref[...] = deq.reshape(bm, bk)


@functools.partial(
    jax.jit, static_argnames=("bits", "group", "symmetric", "bm", "bk",
                              "interpret"))
def group_pseudo_quant(w, clip_ratio, *, bits: int, group: int = 128,
                       symmetric: bool = False, bm: int = 256,
                       bk: int = 1024, interpret: bool = False):
    """Dequantized round-trip Q(W; clip) with a TRACED scalar clip ratio
    (scalar-prefetched through SMEM). w: (m, n) -> (m, n) f32. One HBM pass
    over W — the clip sweep's single re-quantization at its argmin."""
    assert bits in (2, 4, 8), "3-bit has no kernel path; use ref path"
    m, n = w.shape
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and m % bm == 0 and n % bk == 0
    clip = jnp.asarray(clip_ratio, jnp.float32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // bm, n // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, clip: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, clip: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_pseudo_kernel, bits=bits, group=group,
                          symmetric=symmetric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(clip, w)
