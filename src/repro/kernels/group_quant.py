"""Pallas kernel: group-wise quantize + bit-pack in one pass.

BLC re-quantizes the residual every epoch (paper Alg. 2 step 3), so the
quantize+pack inner loop is on the quantization-time critical path. One
pass over W per call: per-128-group min/max reduction, scale/zp, round,
clamp, and nibble-packing all in VREGs; W is read exactly once from HBM.

Supports bits ∈ {2, 4, 8} (the 3-bit pack crosses byte boundaries — it
stays on the jnp path, ``ref.group_quant_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, packed_ref, scale_ref, zp_ref, *, bits, group,
            symmetric, clip_ratio):
    w = w_ref[...].astype(jnp.float32)
    bm, bk = w.shape
    g = w.reshape(bm, bk // group, group)
    qmax_sym = (1 << (bits - 1)) - 1
    levels = (1 << bits) - 1
    if symmetric:
        amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True) * clip_ratio
        scale = jnp.where(amax <= 0, 1.0, amax / qmax_sym)
        zp = jnp.zeros_like(scale)
        q = jnp.clip(jnp.round(g / scale), -(qmax_sym + 1), qmax_sym)
        codes = (q + (1 << (bits - 1))).astype(jnp.uint32)
    else:
        wmax = jnp.max(g, axis=-1, keepdims=True) * clip_ratio
        wmin = jnp.min(g, axis=-1, keepdims=True) * clip_ratio
        scale = (wmax - wmin) / levels
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.round(-wmin / scale)
        codes = jnp.clip(jnp.round(g / scale) + zp, 0, levels).astype(jnp.uint32)
    scale_ref[...] = scale
    zp_ref[...] = zp
    per = 8 // bits
    c = codes.reshape(bm, bk // per, per)
    byte = jnp.zeros((bm, bk // per), jnp.uint32)
    for i in range(per):
        byte = byte | (c[..., i] << (bits * i))
    packed_ref[...] = byte.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("bits", "group", "symmetric", "bm", "bk",
                              "interpret"))
def group_quant(w, *, bits: int, group: int = 128, symmetric: bool = False,
                clip_ratio: float = 1.0, bm: int = 256, bk: int = 1024,
                interpret: bool = False):
    """w: (m, n) -> (packed (m, n//group, group*bits/8) uint8,
    scale (m, n//group, 1) f32, zp (m, n//group, 1) f32)."""
    assert bits in (2, 4, 8), "3-bit packing crosses bytes; use ref path"
    m, n = w.shape
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and m % bm == 0 and n % bk == 0
    per = 8 // bits
    packed, scale, zp = pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group,
                          symmetric=symmetric, clip_ratio=clip_ratio),
        grid=(m // bm, n // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk // per), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n // per), jnp.uint8),
            jax.ShapeDtypeStruct((m, n // group, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, n // group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w)
    pg = group * bits // 8
    return packed.reshape(m, n // group, pg), scale, zp
