"""Pallas TPU kernels for the R1-Sketch power-iteration chain.

TPU adaptation (DESIGN.md §3): the paper's GPU GEMV (BLAS-2) chain becomes
bandwidth-centric on TPU — the sketch reads A once per contraction, so the
kernels below focus on (a) streaming A through VMEM in MXU-aligned tiles
with the vector operand pinned in VMEM, and (b) a *batched* variant where
the "vector" is (n, b) with b ∈ {1..16} — the beyond-paper block sketch —
which turns the same kernel into a skinny GEMM that feeds the MXU.

Two kernels (each one pass over A):
    sketch_gemv   : y (m, b) = A (m, n) @ x (n, b)
    sketch_gemv_t : z (n, b) = Aᵀ @ y      — A streamed in its native
                    layout; no transposed copy of A is ever materialized.

``power_iter`` chains them (2·it + 2 passes, the paper's cost) with the
normalization fused between passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemv_kernel(a_ref, x_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), x_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "interpret"))
def sketch_gemv(a, x, *, bm: int = 256, bk: int = 512, interpret: bool = False):
    """y = A @ x. a: (m, n); x: (n, b) with small b (1 for the paper's
    rank-1 sketch, 8/16 for the block variant)."""
    m, n = a.shape
    b = x.shape[1]
    bm = min(bm, m)
    bk = min(bk, n)
    assert m % bm == 0 and n % bk == 0
    nk = n // bk
    return pl.pallas_call(
        functools.partial(_gemv_kernel, nk=nk),
        grid=(m // bm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, b), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, b), jnp.float32)],
        interpret=interpret,
    )(a, x)


def _gemv_t_kernel(a_ref, y_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(1)  # here k walks the *m* dim of A

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # contraction over the row dim: (bm, bn)ᵀ @ (bm, b) -> (bn, b)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), y_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def sketch_gemv_t(a, y, *, bn: int = 512, bk: int = 256, interpret: bool = False):
    """z = Aᵀ @ y without materializing Aᵀ. a: (m, n); y: (m, b)."""
    m, n = a.shape
    b = y.shape[1]
    bn = min(bn, n)
    bk = min(bk, m)
    assert n % bn == 0 and m % bk == 0
    nk = m // bk
    return pl.pallas_call(
        functools.partial(_gemv_t_kernel, nk=nk),
        grid=(n // bn, nk),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, k: (k, i)),
            pl.BlockSpec((bk, b), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, b), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), a.dtype),
        scratch_shapes=[pltpu.VMEM((bn, b), jnp.float32)],
        interpret=interpret,
    )(a, y)


def power_iter(a, s, it: int = 2, interpret: bool = False):
    """Kernel-backed equivalent of core.r1_sketch power iteration:
    returns (p, k) with p normalized, k = Aᵀp. s: (n,) or (n, b)."""
    sb = s[:, None] if s.ndim == 1 else s
    p = sketch_gemv(a, sb.astype(a.dtype), interpret=interpret)
    p = p / jnp.maximum(jnp.linalg.norm(p, axis=0, keepdims=True), 1e-20)
    for _ in range(it):
        z = sketch_gemv_t(a, p, interpret=interpret)
        p = sketch_gemv(a, z, interpret=interpret)
        p = p / jnp.maximum(jnp.linalg.norm(p, axis=0, keepdims=True), 1e-20)
    k = sketch_gemv_t(a, p, interpret=interpret)
    if s.ndim == 1:
        return p[:, 0], k[:, 0]
    return p, k
