"""Pallas TPU kernels (validated with interpret=True on CPU against ref.py):
  quant_matmul — fused dequant-int matmul + low-rank correction (serving)
  r1_sketch    — tiled power-iteration GEMV/GEMM chain (quantization)
  group_quant  — fused group quantize + bit-pack (BLC inner loop)
"""
