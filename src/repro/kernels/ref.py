"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``*_ref`` takes exactly the same arguments as its kernel counterpart
and computes the answer with plain jnp ops — no tiling, no packing tricks
beyond what the data format requires.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..quant import packing


def quant_matmul_ref(x, packed, scale, zp, u, v, act_scale_inv,
                     *, bits, group=128, symmetric=False, out_dtype=None,
                     **_):
    """Oracle for kernels.quant_matmul.quant_matmul_fused."""
    out_dtype = out_dtype or x.dtype
    m, ng, _ = packed.shape
    n = ng * group
    codes = packing.unpack(packed, bits, group)  # (m, ng, group)
    offs = (1 << (bits - 1)) if symmetric else 0
    wq = ((codes - offs).astype(jnp.float32) - zp.astype(jnp.float32)) \
        * scale.astype(jnp.float32)
    wq = wq.reshape(m, n)
    xs = x.astype(jnp.float32) * act_scale_inv.astype(jnp.float32)[None, :]
    y = xs @ wq.T
    if u.shape[1] > 0:
        y = y + (xs @ v.astype(jnp.float32).T) @ u.astype(jnp.float32).T
    return y.astype(out_dtype)


def group_quant_ref(w, *, bits, group=128, symmetric=False, clip_ratio=1.0):
    """Oracle for kernels.group_quant: returns (packed, scale, zp)."""
    from ..core.quantize import QuantSpec, compute_qparams, quantize_codes

    spec = QuantSpec(bits, group, symmetric)
    scale, zp = compute_qparams(w, spec, clip_ratio)
    codes = quantize_codes(w, spec, scale, zp)
    offs = (1 << (bits - 1)) if symmetric else 0
    return packing.pack(codes + offs, bits), scale, zp


def clip_errors_ref(w, x, *, clips, bits, group=128, symmetric=False):
    """Oracle for kernels.clip_sweep.clip_sweep_errors — the SEED
    formulation of the clip-grid sweep: re-quantize the full matrix and run
    the dense objective GEMM once per grid point (lax.map), with the group
    range reduction recomputed inside every iteration. ``x``: (n, b) or
    None (Frobenius objective, scored through an explicit eye(n) batch just
    like the seed pipeline did)."""
    import jax.lax
    from ..core.quantize import QuantSpec, pseudo_quantize

    spec = QuantSpec(bits, group, symmetric)
    if x is None:
        x = jnp.eye(w.shape[1], dtype=jnp.float32)

    def err(c):
        wq = pseudo_quantize(w, spec, c)
        d = (w - wq).astype(jnp.float32)
        dx = d @ x.astype(jnp.float32)
        return jnp.sum(dx * dx)

    return jax.lax.map(err, jnp.asarray(clips, jnp.float32))


def sketch_gemv_ref(a, x):
    """Oracle for kernels.r1_sketch.sketch_gemv: y = A @ x."""
    return (a.astype(jnp.float32) @ x.astype(jnp.float32)).astype(a.dtype)


def sketch_gemv_t_ref(a, y):
    """Oracle for kernels.r1_sketch.sketch_gemv_t: x = A^T @ y."""
    return (a.astype(jnp.float32).T @ y.astype(jnp.float32)).astype(a.dtype)


def power_iter_ref(a, s, it=2):
    """Oracle for the fused power-iteration chain (normalized, as in
    core.r1_sketch.rank1_sketch)."""
    a32 = a.astype(jnp.float32)
    p = a32 @ s.astype(jnp.float32)
    p = p / jnp.maximum(jnp.linalg.norm(p), 1e-20)
    for _ in range(it):
        p = a32 @ (a32.T @ p)
        p = p / jnp.maximum(jnp.linalg.norm(p), 1e-20)
    k = a32.T @ p
    return p, k
