"""Pallas TPU flash attention (forward) — the prefill/serving compute hot
spot of every attention arch in the zoo.

Not a paper contribution (FLRQ is weight quantization), but the fused
quant_matmul kernel feeds attention directly, and at 32k prefill the
attention inner loop is the dominant MXU consumer — so the framework ships
a TPU-native kernel with the same online-softmax algorithm as the pure-JAX
``models.layers.flash_attention`` (which remains the oracle and the CPU
path).

Tiling: grid (B, H, S_q/bq) with an inner fori_loop over k blocks; the
(bq, hd) query tile, running max/denominator and the f32 accumulator stay
in VMEM for the whole row of k blocks — one HBM pass over K/V per q tile.
Causal masking skips fully-masked k blocks via the loop upper bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, sk, causal, scale):
    # refs: q (1, 1, bq, hd); k/v (1, 1, sk, hd); o (1, 1, bq, hd)
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale
    hd = q.shape[-1]
    nk = sk // bk
    if causal:
        # highest k block that intersects [qi*bq, qi*bq + bq)
        nk_eff = jnp.minimum(nk, (qi + 1) * bq // bk + 1)
    else:
        nk_eff = nk

    def body(kb, carry):
        acc, m, l = carry
        k_blk = k_ref[0, 0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[0, 0, pl.ds(kb * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk_eff, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_tpu(q, k, v, causal: bool = True,
                        bq: int = 256, bk: int = 512,
                        interpret: bool = False):
    """q/k/v: (B, S, H, hd) with kv already head-matched. Returns (B, S, H,
    hd). S must divide by the block sizes (models pad)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    scale = 1.0 / (hd ** 0.5)
    # layout: (B, H, S, hd) so the kernel works on contiguous (S, hd) tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, sk=sk, causal=causal,
                          scale=scale),
        grid=(b, h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
