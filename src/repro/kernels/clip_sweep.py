"""Pallas kernel: the whole BLC clip-grid sweep in ONE pass over W.

The clip search (paper Alg. 2 step 3) scores every clip ratio c by the
output error ||(W - Q(W; c)) X||². The seed formulation re-quantized the
full (m, n) matrix and ran a dense d @ x GEMM once per grid point — at
production shapes that is |grid| full HBM passes over the weight, per
epoch, per layer, and the GEMM traffic (not its FLOPs) is what the sweep
pays for.

This kernel streams W through VMEM ONCE for the entire grid: for each
(bm, bn) weight block it computes the per-128-group range stats a single
time, then produces the dequantization error under *every* clip ratio
in-register (a clip only rescales the same group stats — no re-reduction,
no materialized candidate matrices) and accumulates the per-clip partial
d @ x products into a (n_clips, bm, b) output block that stays resident
across the n sweep. The grid's output errors fall out of one HBM read of
W; the winner is re-quantized once via ``group_quant.group_pseudo_quant``.

Two scoring modes (mirroring ``core.quantize._clip_errors``):
  * calibrated — x: (n, b) column batch; per-clip dx accumulated over the
    n-blocks, errors Σ dx² computed by the (tiny) epilogue outside.
  * Frobenius  — x is None; per-clip per-row Σ d² accumulated directly
    (no GEMM at all — the identity objective never materializes eye(n)).

Quant math is shared with ``kernels.group_quant`` (``_block_stats`` /
``_block_qdq``), so the sweep scores exactly what the re-quantization
produces. bits ∈ {2, 4, 8}; blocks must tile (m % bm == 0, n % bn == 0,
bn % group == 0) — ``kernel_shape_ok`` gates the auto fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .group_quant import _block_qdq, _block_stats


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def kernel_shape_ok(m: int, n: int, group: int = 128,
                    bm: int = 256, bn: int = 512) -> bool:
    """Whether (m, n, group) tiles the clip-path kernels' (min(bm,m),
    min(bn,n)) blocks with group-aligned n-blocks and f32-sublane-aligned
    rows. This is the single gate for BOTH kernels the clip backend
    dispatches to (the sweep here and ``group_quant.group_pseudo_quant``
    at the argmin — ``_best_clip_quant`` passes the same bn as bk), so a
    shape it approves can never trip either kernel's tiling asserts."""
    bm, bn = min(bm, m), min(bn, n)
    return (m % 8 == 0 and m % bm == 0 and n % bn == 0
            and bn % group == 0)


def _sweep_dx_kernel(w_ref, x_ref, dx_ref, *, clips, bits, group, symmetric):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    w = w_ref[...].astype(jnp.float32)
    bm, bn = w.shape
    g = w.reshape(bm, bn // group, group)
    stats = _block_stats(g, bits=bits, symmetric=symmetric)  # once per block
    x = x_ref[...].astype(jnp.float32)
    for ci, c in enumerate(clips):  # static unroll: W stays in VMEM/VREGs
        deq, _, _, _ = _block_qdq(g, stats, c, bits=bits, symmetric=symmetric)
        d = w - deq.reshape(bm, bn)
        dx_ref[ci] += jnp.dot(d, x, preferred_element_type=jnp.float32)


def _sweep_frob_kernel(w_ref, err_ref, *, clips, bits, group, symmetric):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        err_ref[...] = jnp.zeros_like(err_ref)

    w = w_ref[...].astype(jnp.float32)
    bm, bn = w.shape
    g = w.reshape(bm, bn // group, group)
    stats = _block_stats(g, bits=bits, symmetric=symmetric)
    for ci, c in enumerate(clips):
        deq, _, _, _ = _block_qdq(g, stats, c, bits=bits, symmetric=symmetric)
        d = w - deq.reshape(bm, bn)
        err_ref[ci] += jnp.sum(d * d, axis=1)


@functools.partial(
    jax.jit, static_argnames=("clips", "bits", "group", "symmetric",
                              "bm", "bn", "interpret"))
def clip_sweep_dx(w, x, *, clips, bits: int, group: int = 128,
                  symmetric: bool = False, bm: int = 256, bn: int = 512,
                  interpret: bool = False):
    """Per-clip output-error products: w (m, n), x (n, b) ->
    dx (n_clips, m, b) with dx[c] = (w - Q(w; clips[c])) @ x, all clips
    from one HBM read of W (one ``pallas_call``; n is the inner grid dim
    so each (n_clips, bm, b) output block accumulates in place)."""
    assert bits in (2, 4, 8), "3-bit has no kernel path; use the XLA path"
    m, n = w.shape
    b = x.shape[1]
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0 and bn % group == 0, (m, n, bm, bn)
    b_pad = max(_round_up(b, 128), 128)
    if b_pad != b:  # zero columns contribute exact zeros to dx
        x = jnp.pad(x, ((0, 0), (0, b_pad - b)))
    nc = len(clips)
    dx = pl.pallas_call(
        functools.partial(_sweep_dx_kernel, clips=clips, bits=bits,
                          group=group, symmetric=symmetric),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, b_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nc, bm, b_pad), lambda i, j: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, m, b_pad), jnp.float32),
        interpret=interpret,
    )(w, x)
    return dx[:, :, :b] if b_pad != b else dx


@functools.partial(
    jax.jit, static_argnames=("clips", "bits", "group", "symmetric",
                              "bm", "bn", "interpret"))
def clip_sweep_frob(w, *, clips, bits: int, group: int = 128,
                    symmetric: bool = False, bm: int = 256, bn: int = 512,
                    interpret: bool = False):
    """Per-clip per-row Frobenius errors: w (m, n) -> (n_clips, m) with
    out[c, i] = Σ_j (w - Q(w; clips[c]))[i, j]² — the identity-objective
    sweep without the (m, n) @ (n, n) GEMM the eye(n) formulation paid."""
    assert bits in (2, 4, 8), "3-bit has no kernel path; use the XLA path"
    m, n = w.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0 and bn % group == 0, (m, n, bm, bn)
    nc = len(clips)
    return pl.pallas_call(
        functools.partial(_sweep_frob_kernel, clips=clips, bits=bits,
                          group=group, symmetric=symmetric),
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((nc, bm), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nc, m), jnp.float32),
        interpret=interpret,
    )(w)


def clip_sweep_errors(w, x, *, clips, bits: int, group: int = 128,
                      symmetric: bool = False, interpret: bool = False):
    """(n_clips,) total errors for the grid — the kernel path's drop-in for
    ``core.quantize._clip_errors`` (x=None ≡ Frobenius objective)."""
    if x is None:
        part = clip_sweep_frob(w, clips=clips, bits=bits, group=group,
                               symmetric=symmetric, interpret=interpret)
        return jnp.sum(part, axis=1)
    dx = clip_sweep_dx(w, x, clips=clips, bits=bits, group=group,
                       symmetric=symmetric, interpret=interpret)
    return jnp.sum(dx * dx, axis=(1, 2))
