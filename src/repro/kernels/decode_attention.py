"""Pallas flash-decode kernels: grouped-query single-token attention over
a long KV cache — the serving-side hot loop that pairs with quant_matmul.

One program per (batch, kv-head): the (G, hd) query group tile stays in
VMEM while the (S, hd) K/V cache streams through in ``bk`` blocks with an
online softmax — one HBM pass over the cache per token, no (B, S, H, hd)
repeat_kv materialization (the same insight as models.layers.
decode_attention_gqa, here with explicit VMEM control for TPU).

Supports the int8 KV cache (kv_int8 lever): codes and per-entry scales
stream together and dequantize in VREGs — cache HBM traffic stays 1 byte/
element end-to-end.

``flash_decode_gqa_paged`` is the block-table variant for the paged cache
(serve.kv_cache.PagedCacheBackend): K/V live in one pooled
``(num_pages, page, KV, hd)`` buffer and each slot's logical row is a
list of physical page indices. The page table rides scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so each grid step's BlockSpec index
map DMAs the RIGHT physical page directly from HBM — attention gathers
by page table with no materialized (B, S, KV, hd) dense view at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
            *, bk, sk, scale, quantized):
    # q (1, KV=1-slice, G, hd); k/v (1, sk, 1, hd); scales (1, sk, 1, 1)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
    g, hd = q.shape
    length = len_ref[0]
    nk = sk // bk

    def body(kb, carry):
        acc, m, l = carry
        k_blk = k_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        if quantized:
            k_blk = k_blk * ks_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
            v_blk = v_blk * vs_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l

    acc0 = jnp.zeros((g, hd), jnp.float32)
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode_gqa(q, k_cache, v_cache, length, k_scale=None, v_scale=None,
                     bk: int = 512, interpret: bool = False):
    """q: (B, 1, H, hd); caches: (B, S, KV, hd) (bf16, or int8 with
    (B, S, KV, 1) scales). length: scalar int32 valid prefix. Returns
    (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    sk, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bk = min(bk, sk)
    assert sk % bk == 0
    quantized = k_scale is not None
    if not quantized:  # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((b, sk, kv, 1), jnp.bfloat16)
        v_scale = jnp.ones((b, sk, kv, 1), jnp.bfloat16)
    scale = 1.0 / (hd ** 0.5)
    q4 = q.reshape(b, kv, g, hd)
    length_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, sk=sk, scale=scale,
                          quantized=quantized),
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, 1), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, 1), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),  # length scalar
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(q4, k_cache, v_cache, k_scale, v_scale, length_arr)
    return out.reshape(b, 1, h, hd)


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, page, scale, quantized):
    """One grid step = one (batch, kv-head, logical-page) visit. The
    BlockSpec index maps already routed k/v/scale blocks to the PHYSICAL
    page (scalar-prefetched table), so the body is a plain online-softmax
    block update into VMEM scratch that persists across the page walk."""
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
    g = q.shape[0]
    k_blk = k_ref[0, :, 0, :].astype(jnp.float32)          # (page, hd)
    v_blk = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        k_blk = k_blk * ks_ref[0, :, 0, :].astype(jnp.float32)
        v_blk = v_blk * vs_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    # mask by the slot's LOGICAL position: this physical page holds
    # logical positions [pi*page, (pi+1)*page) of slot bi's row
    kpos = pi * page + jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
    s = jnp.where(kpos < len_ref[bi], s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]                # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pi == npages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_gqa_paged(q, k_pool, v_pool, page_table, lengths,
                           k_scale_pool=None, v_scale_pool=None,
                           interpret: bool = False):
    """Gather-by-page-table flash decode. q: (B, 1, H, hd); pools:
    (P, page, KV, hd) (fp, or int8 with (P, page, KV, 1) scale pools);
    page_table: (B, pps) int32 physical page per logical page (entries
    past a slot's allocation may point anywhere — masking by ``lengths``
    keeps them invisible, matching the paged backend's scratch-page
    convention); lengths: (B,) int32 valid prefix per slot, each >= 1
    (same first-block-not-fully-masked precondition as
    ``flash_decode_gqa``). Returns (B, 1, H, hd).

    Grid (B, KV, pps) with the logical-page walk innermost: VMEM scratch
    carries the online softmax across pages and the output tile is
    written once on the last page."""
    b, _, h, hd = q.shape
    _, page, kv, _ = k_pool.shape
    pps = page_table.shape[1]
    g = h // kv
    quantized = k_scale_pool is not None
    if not quantized:  # dummy scale operands keep one kernel signature
        p_total = k_pool.shape[0]
        k_scale_pool = jnp.ones((p_total, page, kv, 1), jnp.bfloat16)
        v_scale_pool = jnp.ones((p_total, page, kv, 1), jnp.bfloat16)
    scale = 1.0 / (hd ** 0.5)
    q4 = q.reshape(b, kv, g, hd)
    flat = page_table.reshape(-1).astype(jnp.int32)

    def page_map(bi, ki, pi, table_ref, len_ref):
        return (table_ref[bi * pps + pi], 0, ki, 0)

    def scale_map(bi, ki, pi, table_ref, len_ref):
        return (table_ref[bi * pps + pi], 0, ki, 0)

    def q_map(bi, ki, pi, table_ref, len_ref):
        return (bi, ki, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page table + lengths drive the DMA routing
        grid=(b, kv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), page_map),
            pl.BlockSpec((1, page, 1, hd), page_map),
            pl.BlockSpec((1, page, 1, 1), scale_map),
            pl.BlockSpec((1, page, 1, 1), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running sum
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page=page, scale=scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(flat, jnp.asarray(lengths, jnp.int32), q4, k_pool, v_pool,
      k_scale_pool, v_scale_pool)
    return out.reshape(b, 1, h, hd)
