"""Pallas flash-decode kernel: grouped-query single-token attention over a
long KV cache — the serving-side hot loop that pairs with quant_matmul.

One program per (batch, kv-head): the (G, hd) query group tile stays in
VMEM while the (S, hd) K/V cache streams through in ``bk`` blocks with an
online softmax — one HBM pass over the cache per token, no (B, S, H, hd)
repeat_kv materialization (the same insight as models.layers.
decode_attention_gqa, here with explicit VMEM control for TPU).

Supports the int8 KV cache (kv_int8 lever): codes and per-entry scales
stream together and dequantize in VREGs — cache HBM traffic stays 1 byte/
element end-to-end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
            *, bk, sk, scale, quantized):
    # q (1, KV=1-slice, G, hd); k/v (1, sk, 1, hd); scales (1, sk, 1, 1)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, hd)
    g, hd = q.shape
    length = len_ref[0]
    nk = sk // bk

    def body(kb, carry):
        acc, m, l = carry
        k_blk = k_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        if quantized:
            k_blk = k_blk * ks_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
            v_blk = v_blk * vs_ref[0, pl.ds(kb * bk, bk), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l

    acc0 = jnp.zeros((g, hd), jnp.float32)
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode_gqa(q, k_cache, v_cache, length, k_scale=None, v_scale=None,
                     bk: int = 512, interpret: bool = False):
    """q: (B, 1, H, hd); caches: (B, S, KV, hd) (bf16, or int8 with
    (B, S, KV, 1) scales). length: scalar int32 valid prefix. Returns
    (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    sk, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bk = min(bk, sk)
    assert sk % bk == 0
    quantized = k_scale is not None
    if not quantized:  # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((b, sk, kv, 1), jnp.bfloat16)
        v_scale = jnp.ones((b, sk, kv, 1), jnp.bfloat16)
    scale = 1.0 / (hd ** 0.5)
    q4 = q.reshape(b, kv, g, hd)
    length_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, sk=sk, scale=scale,
                          quantized=quantized),
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, 1), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec((1, sk, 1, 1), lambda bi, ki: (bi, 0, ki, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),  # length scalar
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(q4, k_cache, v_cache, k_scale, v_scale, length_arr)
    return out.reshape(b, 1, h, hd)
