"""Fused FLRQ serving kernel: int-code dequant × bf16 matmul + low-rank
correction, in one Pallas pass (the TPU analogue of the paper's AutoGPTQ
CUDA fusion, Fig. 3).

    y[t, m] = Σ_k deq(codes[m, k]) · xs[t, k]  +  Σ_r U[m, r] · (V[r, :] @ xs[t, :])
    xs      = act_scale_inv ⊙ x

Design for the MXU/VMEM hierarchy:
  * grid (T/bt, M/bm, N/bk), k innermost ("arbitrary") so the f32 out
    accumulator lives in VMEM scratch across the contraction;
  * codes stay packed (uint8) through HBM→VMEM — 4×/2× less weight traffic
    than bf16 (this is the serving-bandwidth win quantization buys) — and
    are unpacked in VREGs right before the dot;
  * per-128-group scales/zeros are blocked along with the codes;
  * the low-rank term accumulates t = xs @ Vᵀ (bt, r) in scratch over the
    same k sweep and lands U·t in the epilogue of the final k step — rank ≤
    128 keeps the U tile resident, so the correction costs no extra HBM
    pass over the weights.

Block sizes default to MXU-aligned (bt, bm, bk) = (128, 128, 512); bk must
be a multiple of the quantization group (128).

``quant_matmul_fused_stacked`` is the lane-stacked variant: one launch
computes y[l] = FLRQ-apply(qt[l], x[l]) for every lane of a stacked
(L, m, n) QuantizedLinear — the serving layout ``quantize_model_stacked``
emits — by prepending a parallel lane dim to the grid. One executable,
one weight-stack pass, no per-lane dispatch loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _unpack_block(codes_u8, bits: int, bk: int):
    """(bm, bk*bits/8) uint8 -> (bm, bk) int32 (unsigned code domain)."""
    c = codes_u8.astype(jnp.uint32)
    bm = codes_u8.shape[0]
    if bits == 8:
        return c.astype(jnp.int32)
    if bits == 4:
        lo = c & 0xF
        hi = (c >> 4) & 0xF
        return jnp.stack([lo, hi], axis=-1).reshape(bm, bk).astype(jnp.int32)
    if bits == 2:
        parts = [(c >> (2 * i)) & 0x3 for i in range(4)]
        return jnp.stack(parts, axis=-1).reshape(bm, bk).astype(jnp.int32)
    if bits == 3:
        b = c.reshape(bm, bk // 8, 3)
        word = b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
        parts = [(word >> (3 * i)) & 0x7 for i in range(8)]
        return jnp.stack(parts, axis=-1).reshape(bm, bk).astype(jnp.int32)
    raise ValueError(bits)


def _fused_body(k, x_blk, asi_blk, packed_blk, scale_blk, zp_blk, u_blk,
                v_blk, o_write, o_dtype, acc_ref, t_ref, *, bits, group,
                offs, nk, rank):
    """The one definition of the fused dequant-matmul math, shared by the
    per-tensor and lane-stacked kernels (which differ only in how they
    index their refs). All ``*_blk`` arguments are already-loaded 2-D/3-D
    blocks; ``o_write`` stores the (bt, bm) result on the final k step."""
    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if rank:
            t_ref[...] = jnp.zeros_like(t_ref)

    xs = x_blk.astype(jnp.float32) * asi_blk.astype(jnp.float32)[None, :]
    bm = packed_blk.shape[0]
    bk = xs.shape[1]
    codes = _unpack_block(packed_blk, bits, bk)               # (bm, bk)
    scale = scale_blk.astype(jnp.float32)                     # (bm, bk//g, 1)
    zp = zp_blk.astype(jnp.float32)
    wq = ((codes - offs).astype(jnp.float32).reshape(bm, bk // group, group)
          - zp) * scale
    wq = wq.reshape(bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        xs, wq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (bt, bm)
    if rank:
        t_ref[...] += jax.lax.dot_general(
            xs, v_blk.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bt, r)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if rank:
            out = out + jax.lax.dot_general(
                t_ref[...], u_blk.astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        o_write(out.astype(o_dtype))


def _kernel(x_ref, packed_ref, scale_ref, zp_ref, u_ref, v_ref, asi_ref,
            o_ref, acc_ref, t_ref, **statics):
    def o_write(out):
        o_ref[...] = out

    _fused_body(pl.program_id(2), x_ref[...], asi_ref[...], packed_ref[...],
                scale_ref[...], zp_ref[...], u_ref[...], v_ref[...],
                o_write, o_ref.dtype, acc_ref, t_ref, **statics)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "symmetric", "bt", "bm", "bk",
                     "interpret", "out_dtype"))
def quant_matmul_fused(
    x, packed, scale, zp, u, v, act_scale_inv,
    *, bits: int, group: int = 128, symmetric: bool = False,
    bt: int = 128, bm: int = 128, bk: int = 512,
    interpret: bool = False, out_dtype=None,
):
    """x: (T, N); packed: (M, N//group, group*bits//8) uint8;
    scale/zp: (M, N//group, 1); u: (M, R); v: (R, N); act_scale_inv: (N,).
    Returns (T, M)."""
    t_dim, n = x.shape
    m = packed.shape[0]
    rank = u.shape[1]
    out_dtype = out_dtype or x.dtype
    bt = min(bt, t_dim)
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and n % bk == 0, (bk, group, n)
    assert t_dim % bt == 0 and m % bm == 0, (t_dim, bt, m, bm)
    nk = n // bk
    offs = (1 << (bits - 1)) if symmetric else 0
    pg = group * bits // 8
    # flatten packed trailing dims for clean BlockSpec tiling
    packed2 = packed.reshape(m, (n // group) * pg)
    bpk = (bk // group) * pg
    rank_pad = max(rank, 1)
    if rank == 0:  # dummy 1-wide factors (kernel skips them via rank=0)
        u = jnp.zeros((m, 1), x.dtype)
        v = jnp.zeros((1, n), x.dtype)

    grid = (t_dim // bt, m // bm, nk)
    kernel = functools.partial(
        _kernel, bits=bits, group=group, offs=offs, nk=nk, rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),          # x
            pl.BlockSpec((bm, bpk), lambda i, j, k: (j, k)),         # packed
            pl.BlockSpec((bm, bk // group, 1), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((bm, rank_pad), lambda i, j, k: (j, 0)),    # u
            pl.BlockSpec((rank_pad, bk), lambda i, j, k: (0, k)),    # v
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),               # asi
        ],
        out_specs=pl.BlockSpec((bt, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t_dim, m), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, bm), jnp.float32),   # acc
            pltpu.VMEM((bt, rank_pad), jnp.float32),  # t
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed2, scale, zp, u, v, act_scale_inv)


def _kernel_lanes(x_ref, packed_ref, scale_ref, zp_ref, u_ref, v_ref,
                  asi_ref, o_ref, acc_ref, t_ref, **statics):
    """Stacked-kernel body: the same ``_fused_body`` math with every ref
    carrying a leading size-1 lane block and the k step in grid axis 3."""
    def o_write(out):
        o_ref[0] = out

    _fused_body(pl.program_id(3), x_ref[0], asi_ref[0], packed_ref[0],
                scale_ref[0], zp_ref[0], u_ref[0], v_ref[0],
                o_write, o_ref.dtype, acc_ref, t_ref, **statics)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "symmetric", "bt", "bm", "bk",
                     "interpret", "out_dtype"))
def quant_matmul_fused_stacked(
    x, packed, scale, zp, u, v, act_scale_inv,
    *, bits: int, group: int = 128, symmetric: bool = False,
    bt: int = 128, bm: int = 128, bk: int = 512,
    interpret: bool = False, out_dtype=None,
):
    """Lane-stacked fused FLRQ matmul: x: (L, T, N);
    packed: (L, M, N//group, group*bits//8) uint8; scale/zp: (L, M, N//group,
    1); u: (L, M, R); v: (L, R, N); act_scale_inv: (L, N). Returns (L, T, M)
    with y[l] = deq(W_q[l])·xs[l] + U[l](V[l]·xs[l]).

    The lane dim is an outer *parallel* grid axis — each (lane, t-block,
    m-block) owns its own accumulator sweep over k, so the launch is the
    exact per-lane kernel replicated L times with no cross-lane traffic.
    """
    l, t_dim, n = x.shape
    m = packed.shape[1]
    rank = u.shape[2]
    out_dtype = out_dtype or x.dtype
    bt = min(bt, t_dim)
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and n % bk == 0, (bk, group, n)
    assert t_dim % bt == 0 and m % bm == 0, (t_dim, bt, m, bm)
    nk = n // bk
    offs = (1 << (bits - 1)) if symmetric else 0
    pg = group * bits // 8
    packed2 = packed.reshape(l, m, (n // group) * pg)
    bpk = (bk // group) * pg
    rank_pad = max(rank, 1)
    if rank == 0:
        u = jnp.zeros((l, m, 1), x.dtype)
        v = jnp.zeros((l, 1, n), x.dtype)

    grid = (l, t_dim // bt, m // bm, nk)
    kernel = functools.partial(
        _kernel_lanes, bits=bits, group=group, offs=offs, nk=nk, rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bk), lambda h, i, j, k: (h, i, k)),   # x
            pl.BlockSpec((1, bm, bpk), lambda h, i, j, k: (h, j, k)),  # packed
            pl.BlockSpec((1, bm, bk // group, 1),
                         lambda h, i, j, k: (h, j, k, 0)),             # scale
            pl.BlockSpec((1, bm, bk // group, 1),
                         lambda h, i, j, k: (h, j, k, 0)),             # zp
            pl.BlockSpec((1, bm, rank_pad), lambda h, i, j, k: (h, j, 0)),
            pl.BlockSpec((1, rank_pad, bk), lambda h, i, j, k: (h, 0, k)),
            pl.BlockSpec((1, bk), lambda h, i, j, k: (h, k)),          # asi
        ],
        out_specs=pl.BlockSpec((1, bt, bm), lambda h, i, j, k: (h, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, t_dim, m), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, bm), jnp.float32),        # acc
            pltpu.VMEM((bt, rank_pad), jnp.float32),  # t
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed2, scale, zp, u, v, act_scale_inv)
