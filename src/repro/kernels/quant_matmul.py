"""Fused FLRQ serving kernel: int-code dequant × bf16 matmul + low-rank
correction, in one Pallas pass (the TPU analogue of the paper's AutoGPTQ
CUDA fusion, Fig. 3).

    y[t, m] = Σ_k deq(codes[m, k]) · xs[t, k]  +  Σ_r U[m, r] · (V[r, :] @ xs[t, :])
    xs      = act_scale_inv ⊙ x

Design for the MXU/VMEM hierarchy:
  * grid (T/bt, M/bm, N/bk), k innermost ("arbitrary") so the f32 out
    accumulator lives in VMEM scratch across the contraction;
  * codes stay packed (uint8) through HBM→VMEM — 4×/2× less weight traffic
    than bf16 (this is the serving-bandwidth win quantization buys) — and
    are unpacked in VREGs right before the dot;
  * per-128-group scales/zeros are blocked along with the codes;
  * the low-rank term accumulates t = xs @ Vᵀ (bt, r) in scratch over the
    same k sweep and lands U·t in the epilogue of the final k step — rank ≤
    128 keeps the U tile resident, so the correction costs no extra HBM
    pass over the weights.

Block sizes default to MXU-aligned (bt, bm, bk) = (128, 128, 512); bk must
be a multiple of the quantization group (128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _unpack_block(codes_u8, bits: int, bk: int):
    """(bm, bk*bits/8) uint8 -> (bm, bk) int32 (unsigned code domain)."""
    c = codes_u8.astype(jnp.uint32)
    bm = codes_u8.shape[0]
    if bits == 8:
        return c.astype(jnp.int32)
    if bits == 4:
        lo = c & 0xF
        hi = (c >> 4) & 0xF
        return jnp.stack([lo, hi], axis=-1).reshape(bm, bk).astype(jnp.int32)
    if bits == 2:
        parts = [(c >> (2 * i)) & 0x3 for i in range(4)]
        return jnp.stack(parts, axis=-1).reshape(bm, bk).astype(jnp.int32)
    if bits == 3:
        b = c.reshape(bm, bk // 8, 3)
        word = b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
        parts = [(word >> (3 * i)) & 0x7 for i in range(8)]
        return jnp.stack(parts, axis=-1).reshape(bm, bk).astype(jnp.int32)
    raise ValueError(bits)


def _kernel(x_ref, packed_ref, scale_ref, zp_ref, u_ref, v_ref, asi_ref,
            o_ref, acc_ref, t_ref, *, bits, group, offs, nk, rank):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if rank:
            t_ref[...] = jnp.zeros_like(t_ref)

    xs = x_ref[...].astype(jnp.float32) * asi_ref[...].astype(jnp.float32)[None, :]
    bm = packed_ref.shape[0]
    bk = xs.shape[1]
    codes = _unpack_block(packed_ref[...], bits, bk)          # (bm, bk)
    scale = scale_ref[...].astype(jnp.float32)                # (bm, bk//g, 1)
    zp = zp_ref[...].astype(jnp.float32)
    wq = ((codes - offs).astype(jnp.float32).reshape(bm, bk // group, group)
          - zp) * scale
    wq = wq.reshape(bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        xs, wq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (bt, bm)
    if rank:
        t_ref[...] += jax.lax.dot_general(
            xs, v_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bt, r)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if rank:
            out = out + jax.lax.dot_general(
                t_ref[...], u_ref[...].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "symmetric", "bt", "bm", "bk",
                     "interpret", "out_dtype"))
def quant_matmul_fused(
    x, packed, scale, zp, u, v, act_scale_inv,
    *, bits: int, group: int = 128, symmetric: bool = False,
    bt: int = 128, bm: int = 128, bk: int = 512,
    interpret: bool = False, out_dtype=None,
):
    """x: (T, N); packed: (M, N//group, group*bits//8) uint8;
    scale/zp: (M, N//group, 1); u: (M, R); v: (R, N); act_scale_inv: (N,).
    Returns (T, M)."""
    t_dim, n = x.shape
    m = packed.shape[0]
    rank = u.shape[1]
    out_dtype = out_dtype or x.dtype
    bt = min(bt, t_dim)
    bm = min(bm, m)
    bk = min(bk, n)
    assert bk % group == 0 and n % bk == 0, (bk, group, n)
    assert t_dim % bt == 0 and m % bm == 0, (t_dim, bt, m, bm)
    nk = n // bk
    offs = (1 << (bits - 1)) if symmetric else 0
    pg = group * bits // 8
    # flatten packed trailing dims for clean BlockSpec tiling
    packed2 = packed.reshape(m, (n // group) * pg)
    bpk = (bk // group) * pg
    rank_pad = max(rank, 1)
    if rank == 0:  # dummy 1-wide factors (kernel skips them via rank=0)
        u = jnp.zeros((m, 1), x.dtype)
        v = jnp.zeros((1, n), x.dtype)

    grid = (t_dim // bt, m // bm, nk)
    kernel = functools.partial(
        _kernel, bits=bits, group=group, offs=offs, nk=nk, rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),          # x
            pl.BlockSpec((bm, bpk), lambda i, j, k: (j, k)),         # packed
            pl.BlockSpec((bm, bk // group, 1), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((bm, bk // group, 1), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((bm, rank_pad), lambda i, j, k: (j, 0)),    # u
            pl.BlockSpec((rank_pad, bk), lambda i, j, k: (0, k)),    # v
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),               # asi
        ],
        out_specs=pl.BlockSpec((bt, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t_dim, m), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, bm), jnp.float32),   # acc
            pltpu.VMEM((bt, rank_pad), jnp.float32),  # t
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed2, scale, zp, u, v, act_scale_inv)
