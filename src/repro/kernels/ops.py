"""Jit'd public wrappers for the Pallas kernels: accept the framework's
high-level types (QuantizedLinear, weight matrices), pick block sizes,
handle padding/fallbacks, and route to ref implementations where a
configuration is outside kernel support.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..quant.qtensor import QuantizedLinear, is_stacked, num_lanes
from . import group_quant as gq
from . import quant_matmul as qm
from . import r1_sketch as rs
from . import ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _t_blocking(t: int):
    """(bt, t_pad) for a T-dim of ``t`` tokens. bt must divide the padded T
    and respect the f32 (8, 128) VMEM tile — decode-shaped calls (T = slots,
    often 1..8) pad up to one 8-row sublane block instead of degenerating to
    1-row blocks, and T > 128 pads to the 128 t-block."""
    bt = min(128, _round_up(t, 8))
    return bt, _round_up(t, bt)


def quant_matmul(qt: QuantizedLinear, x, out_dtype=None,
                 interpret: bool = False):
    """y = FLRQ-apply(qt, x) via the fused kernel. x: (..., n) -> (..., m).

    Stacked (lane-leading) tensors take the lane-stacked kernel: x must
    carry the same leading lane dims, (lanes..., ..., n) -> (lanes..., ...,
    m), one launch for all lanes.
    """
    out_dtype = out_dtype or x.dtype
    if is_stacked(qt):
        return _quant_matmul_stacked(qt, x, out_dtype, interpret)
    lead = x.shape[:-1]
    t = 1
    for d in lead:
        t *= d
    x2 = x.reshape(t, qt.n)
    kwargs = dict(bits=qt.bits, group=qt.group_size, symmetric=qt.symmetric,
                  out_dtype=out_dtype)
    if qt.bits == 3:
        y2 = ref.quant_matmul_ref(x2, qt.packed, qt.scale, qt.zp, qt.u, qt.v,
                                  qt.act_scale_inv, **kwargs)
    else:
        bt, t_pad = _t_blocking(t)
        if t_pad != t:
            x2 = jnp.pad(x2, ((0, t_pad - t), (0, 0)))
        y2 = qm.quant_matmul_fused(
            x2, qt.packed, qt.scale, qt.zp, qt.u, qt.v, qt.act_scale_inv,
            bt=bt, interpret=interpret, **kwargs)
        if t_pad != t:
            y2 = y2[:t]
    return y2.reshape(*lead, qt.m)


def _quant_matmul_stacked(qt: QuantizedLinear, x, out_dtype,
                          interpret: bool):
    """Lane-stacked path: flatten the leading lane dims of both the tensor
    and x, run one multi-lane launch, restore the lane layout."""
    lane_dims = qt.packed.shape[:-3]
    nl = len(lane_dims)
    if x.shape[:nl] != lane_dims:
        raise ValueError(
            f"stacked quant_matmul: x leading dims {x.shape[:nl]} != "
            f"tensor lane dims {lane_dims}")
    lanes = num_lanes(qt)
    inner = x.shape[nl:-1]  # per-lane batch dims
    t = 1
    for d in inner:
        t *= d
    x3 = x.reshape(lanes, t, qt.n)
    flat = lambda a: a.reshape((lanes,) + a.shape[nl:])
    kwargs = dict(bits=qt.bits, group=qt.group_size, symmetric=qt.symmetric,
                  out_dtype=out_dtype)
    if qt.bits == 3:
        y3 = jax.vmap(
            lambda xl, pk, sc, zp, u, v, asi: ref.quant_matmul_ref(
                xl, pk, sc, zp, u, v, asi, **kwargs)
        )(x3, flat(qt.packed), flat(qt.scale), flat(qt.zp), flat(qt.u),
          flat(qt.v), flat(qt.act_scale_inv))
    else:
        bt, t_pad = _t_blocking(t)
        if t_pad != t:
            x3 = jnp.pad(x3, ((0, 0), (0, t_pad - t), (0, 0)))
        y3 = qm.quant_matmul_fused_stacked(
            x3, flat(qt.packed), flat(qt.scale), flat(qt.zp), flat(qt.u),
            flat(qt.v), flat(qt.act_scale_inv), bt=bt, interpret=interpret,
            **kwargs)
        if t_pad != t:
            y3 = y3[:, :t]
    return y3.reshape(lane_dims + inner + (qt.m,))


def sketch_power_iter(a, s, it: int = 2, interpret: bool = False):
    """Kernel-backed (p, k) for one R1-Sketch step; pads A to tile
    multiples when needed."""
    m, n = a.shape
    pm, pn = (-m) % 256, (-n) % 512
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
        s = jnp.pad(s, ((0, pn),) if s.ndim == 1 else ((0, pn), (0, 0)))
    p, k = rs.power_iter(a, s, it=it, interpret=interpret)
    if s.ndim == 1:
        return p[:m], k[:n]
    return p[:m], k[:n]


def quantize_pack(w, bits: int, group: int = 128, symmetric: bool = False,
                  clip_ratio: float = 1.0, interpret: bool = False):
    """(packed, scale, zp) via the fused group-quant kernel (jnp ref for
    3-bit)."""
    if bits == 3:
        return ref.group_quant_ref(w, bits=bits, group=group,
                                   symmetric=symmetric, clip_ratio=clip_ratio)
    return gq.group_quant(w, bits=bits, group=group, symmetric=symmetric,
                          clip_ratio=clip_ratio, interpret=interpret)
