"""Jit'd public wrappers for the Pallas kernels: accept the framework's
high-level types (QuantizedLinear, weight matrices), pick block sizes,
handle padding/fallbacks, and route to ref implementations where a
configuration is outside kernel support.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..quant.qtensor import QuantizedLinear
from . import group_quant as gq
from . import quant_matmul as qm
from . import r1_sketch as rs
from . import ref


def quant_matmul(qt: QuantizedLinear, x, out_dtype=None,
                 interpret: bool = False):
    """y = FLRQ-apply(qt, x) via the fused kernel. x: (..., n) -> (..., m)."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    t = 1
    for d in lead:
        t *= d
    x2 = x.reshape(t, qt.n)
    kwargs = dict(bits=qt.bits, group=qt.group_size, symmetric=qt.symmetric,
                  out_dtype=out_dtype)
    # kernel constraints: t % bt == 0 with bt<=128; pad T up
    bt = min(128, t) if t % min(128, t) == 0 else 1
    pad_t = (-t) % 128 if t > 128 else 0
    if qt.bits == 3:
        y2 = ref.quant_matmul_ref(x2, qt.packed, qt.scale, qt.zp, qt.u, qt.v,
                                  qt.act_scale_inv, **kwargs)
    else:
        if pad_t:
            x2 = jnp.pad(x2, ((0, pad_t), (0, 0)))
        y2 = qm.quant_matmul_fused(
            x2, qt.packed, qt.scale, qt.zp, qt.u, qt.v, qt.act_scale_inv,
            interpret=interpret, **kwargs)
        if pad_t:
            y2 = y2[:t]
    return y2.reshape(*lead, qt.m)


def sketch_power_iter(a, s, it: int = 2, interpret: bool = False):
    """Kernel-backed (p, k) for one R1-Sketch step; pads A to tile
    multiples when needed."""
    m, n = a.shape
    pm, pn = (-m) % 256, (-n) % 512
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
        s = jnp.pad(s, ((0, pn),) if s.ndim == 1 else ((0, pn), (0, 0)))
    p, k = rs.power_iter(a, s, it=it, interpret=interpret)
    if s.ndim == 1:
        return p[:m], k[:n]
    return p[:m], k[:n]


def quantize_pack(w, bits: int, group: int = 128, symmetric: bool = False,
                  clip_ratio: float = 1.0, interpret: bool = False):
    """(packed, scale, zp) via the fused group-quant kernel (jnp ref for
    3-bit)."""
    if bits == 3:
        return ref.group_quant_ref(w, bits=bits, group=group,
                                   symmetric=symmetric, clip_ratio=clip_ratio)
    return gq.group_quant(w, bits=bits, group=group, symmetric=symmetric,
                          clip_ratio=clip_ratio, interpret=interpret)
