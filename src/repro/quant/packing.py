"""Bit-packing of integer quantization codes into uint8 words.

Codes arrive as int32 in the *unsigned* code domain (0 .. 2^bits - 1; the
symmetric case is offset by 2^(bits-1) before packing). Supported widths:
2, 3, 4, 8 bits. Packing is along the last axis; for b ∈ {2,4,8} each byte
holds 8/b codes; for b = 3, every 8 codes become 3 bytes.

These layouts are what the Pallas ``quant_matmul`` kernel consumes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

SUPPORTED_BITS = (2, 3, 4, 8)


def packed_size(n: int, bits: int) -> int:
    if bits == 3:
        assert n % 8 == 0
        return (n // 8) * 3
    per = 8 // bits
    assert n % per == 0
    return n // per


@partial(jax.jit, static_argnames=("bits",))
def pack(codes: jax.Array, bits: int) -> jax.Array:
    """(..., n) int codes -> (..., packed_size(n, bits)) uint8."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits={bits} not in {SUPPORTED_BITS}")
    c = codes.astype(jnp.uint32)
    if bits == 8:
        return c.astype(jnp.uint8)
    if bits == 3:
        *lead, n = c.shape
        g = c.reshape(*lead, n // 8, 8)
        # 8 codes * 3 bits = 24 bits -> 3 bytes, little-endian bit order.
        word = jnp.zeros(g.shape[:-1], jnp.uint32)
        for i in range(8):
            word = word | (g[..., i] << (3 * i))
        b0 = (word & 0xFF).astype(jnp.uint8)
        b1 = ((word >> 8) & 0xFF).astype(jnp.uint8)
        b2 = ((word >> 16) & 0xFF).astype(jnp.uint8)
        return jnp.stack([b0, b1, b2], axis=-1).reshape(*lead, (n // 8) * 3)
    per = 8 // bits
    *lead, n = c.shape
    g = c.reshape(*lead, n // per, per)
    byte = jnp.zeros(g.shape[:-1], jnp.uint32)
    for i in range(per):
        byte = byte | (g[..., i] << (bits * i))
    return byte.astype(jnp.uint8)


@partial(jax.jit, static_argnames=("bits", "n"))
def unpack(packed: jax.Array, bits: int, n: int) -> jax.Array:
    """(..., packed) uint8 -> (..., n) int32 codes (unsigned domain)."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits={bits} not in {SUPPORTED_BITS}")
    p = packed.astype(jnp.uint32)
    if bits == 8:
        return p.astype(jnp.int32)
    *lead, _ = p.shape
    if bits == 3:
        b = p.reshape(*lead, n // 8, 3)
        word = b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
        outs = [(word >> (3 * i)) & 0x7 for i in range(8)]
        return jnp.stack(outs, axis=-1).reshape(*lead, n).astype(jnp.int32)
    per = 8 // bits
    mask = (1 << bits) - 1
    b = p.reshape(*lead, n // per)
    outs = [(b >> (bits * i)) & mask for i in range(per)]
    return jnp.stack(outs, axis=-1).reshape(*lead, n).astype(jnp.int32)
