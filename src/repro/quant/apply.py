"""Applying a QuantizedLinear: y = deq(W_q)·xs + U(V·xs), xs = α⁻¹⊙x.

Two paths:
  * ``apply``        — pure-jnp reference (used everywhere on CPU and as the
    oracle for the Pallas kernel).
  * ``apply_kernel`` — routes to the fused Pallas kernel
    (``repro.kernels.ops.quant_matmul``) on TPU; falls back to ``apply``
    when the kernel doesn't support the configuration.

Convention: x has shape (..., n) and the result (..., m) — matching
``x @ W.T`` for a (m=out, n=in) weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from .qtensor import QuantizedLinear, dequantize


def apply(qt: QuantizedLinear, x, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    w = dequantize(qt, dtype=jnp.float32)  # (m, n) incl. low-rank + act scale
    y = jnp.einsum("...n,mn->...m", x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def apply_lowrank_separate(qt: QuantizedLinear, x, out_dtype=None):
    """Serving-shaped computation: never materializes deq + UV together.
    This is the FLOP/byte structure the fused kernel implements."""
    out_dtype = out_dtype or x.dtype
    from .qtensor import dequantize_qpart

    xs = x.astype(jnp.float32) * qt.act_scale_inv.astype(jnp.float32)
    wq = dequantize_qpart(qt, dtype=jnp.float32)
    y = jnp.einsum("...n,mn->...m", xs, wq)
    if qt.rank > 0:
        t = jnp.einsum("...n,rn->...r", xs, qt.v.astype(jnp.float32))
        y = y + jnp.einsum("...r,mr->...m", t, qt.u.astype(jnp.float32))
    return y.astype(out_dtype)


def apply_kernel(qt: QuantizedLinear, x, out_dtype=None, interpret: bool = False):
    """Fused Pallas path (interpret=True on CPU for validation)."""
    from ..kernels import ops as kernel_ops

    return kernel_ops.quant_matmul(qt, x, out_dtype=out_dtype, interpret=interpret)
