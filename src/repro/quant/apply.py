"""Applying a QuantizedLinear: y = deq(W_q)·xs + U(V·xs), xs = α⁻¹⊙x.

Execution backends (the serving runtime's dispatch layer):

  * ``"ref"``   — pure-jnp low-rank-separate path (``apply_lowrank_separate``):
    the FLOP/byte structure of the fused kernel, computed with plain einsums.
    The numerical oracle, and the fastest choice on CPU.
  * ``"fused"`` — the Pallas kernel (``repro.kernels.ops.quant_matmul``):
    packed codes stay uint8 through HBM→VMEM and the low-rank correction
    rides the same pass. Off-TPU it runs in interpret mode (validation, not
    speed). Configurations outside kernel support fall back to ``"ref"``
    and the fallback is *recorded* in the dispatch log — never silent.
  * ``"auto"``  — ``"fused"`` on a real TPU when the config is supported,
    ``"ref"`` everywhere else. This is the serving default: bit-identical
    to the reference path on CPU, kernel-fused on hardware.

Every resolution appends a ``BackendDecision`` to the dispatch log (one
entry per trace, since decisions are static under jit). ``dispatch_report``
summarises which tensors hit the kernel and which fell back, and why —
the bits=3 ref fallback and any shape-constraint miss surface here.

The active backend is either passed explicitly (``dispatch(..., backend=)``)
or installed for a code region with ``backend_scope`` — the serving engine
wraps its jitted prefill/decode in a scope so the whole model traces under
one policy (see ``serve.engine.Engine``).

Convention: x has shape (..., n) and the result (..., m) — matching
``x @ W.T`` for a (m=out, n=in) weight.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..obs.metrics import default_registry
from .qtensor import QuantizedLinear, dequantize, is_stacked, truncate_rank

BACKENDS = ("ref", "fused", "auto")

# Kernel support envelope (mirrors kernels/quant_matmul.py constraints).
_KERNEL_BITS = (2, 4, 8)
_KERNEL_MAX_RANK = 128  # U tile must stay VMEM-resident across the k sweep


def apply(qt: QuantizedLinear, x, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    w = dequantize(qt, dtype=jnp.float32)  # (m, n) incl. low-rank + act scale
    y = jnp.einsum("...n,mn->...m", x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def apply_lowrank_separate(qt: QuantizedLinear, x, out_dtype=None):
    """Serving-shaped computation: never materializes deq + UV together.
    This is the FLOP/byte structure the fused kernel implements. Accepts
    stacked (lane-leading) tensors with x carrying matching lane dims."""
    out_dtype = out_dtype or x.dtype
    from .qtensor import dequantize_qpart

    if is_stacked(qt):
        # (L, ..., n) inputs against an (L,)-stacked tensor: one lane each.
        return jax.vmap(
            lambda q, xl: apply_lowrank_separate(q, xl, out_dtype=out_dtype)
        )(qt, x)

    xs = x.astype(jnp.float32) * qt.act_scale_inv.astype(jnp.float32)
    wq = dequantize_qpart(qt, dtype=jnp.float32)
    y = jnp.einsum("...n,mn->...m", xs, wq)
    if qt.rank > 0:
        t = jnp.einsum("...n,rn->...r", xs, qt.v.astype(jnp.float32))
        y = y + jnp.einsum("...r,mr->...m", t, qt.u.astype(jnp.float32))
    return y.astype(out_dtype)


def apply_kernel(qt: QuantizedLinear, x, out_dtype=None, interpret: bool = False):
    """Fused Pallas path (interpret=True on CPU for validation)."""
    from ..kernels import ops as kernel_ops

    return kernel_ops.quant_matmul(qt, x, out_dtype=out_dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# Backend dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendDecision:
    """One trace-time routing decision: which path served a QuantizedLinear."""
    requested: str          # what the caller asked for ("ref"/"fused"/"auto")
    chosen: str             # "ref" | "fused" | "fused-interpret"
    reason: str             # why (support miss, platform, explicit request)
    shape: Tuple[int, int]  # (m, n) of the tensor
    bits: int


_DISPATCH_LOG: List[BackendDecision] = []

# Route counts live in the process-wide default metrics registry
# (``obs.metrics.default_registry``) so a --metrics-json snapshot carries
# the same numbers dispatch_report() prints. The log keeps the per-config
# detail (shape/reason); the counters keep the totals.
_DISPATCH_COUNTERS: dict = {}


def _count_dispatch(requested: str, chosen: str) -> None:
    c = _DISPATCH_COUNTERS.get((requested, chosen))
    if c is None:
        c = default_registry().counter("quant.dispatch",
                                       requested=requested, chosen=chosen)
        _DISPATCH_COUNTERS[(requested, chosen)] = c
    c.inc()


def clear_dispatch_log() -> None:
    _DISPATCH_LOG.clear()
    for c in _DISPATCH_COUNTERS.values():
        c.reset()


def dispatch_log() -> List[BackendDecision]:
    """Decisions recorded since the last clear (one per traced config —
    jit caches traces, so steady-state serving adds nothing)."""
    return list(_DISPATCH_LOG)


def dispatch_report() -> str:
    """Human-readable summary of the routing decisions (the launcher prints
    this after building the engine so fallbacks are never silent)."""
    if not _DISPATCH_LOG:
        return "quant-matmul dispatch: no quantized matmuls traced"
    lines = ["quant-matmul dispatch:"]
    seen = set()
    for d in _DISPATCH_LOG:
        key = (d.requested, d.chosen, d.reason, d.shape, d.bits)
        if key in seen:
            continue
        seen.add(key)
        lines.append(f"  ({d.shape[0]}x{d.shape[1]}, w{d.bits}) "
                     f"{d.requested} -> {d.chosen}: {d.reason}")
    routes = ", ".join(
        f"{req}->{ch}: {c.value}"
        for (req, ch), c in sorted(_DISPATCH_COUNTERS.items())
        if c.value > 0)
    if routes:
        lines.append(f"  traced calls by route: {routes}")
    return "\n".join(lines)


def kernel_supported(qt: QuantizedLinear) -> Tuple[bool, str]:
    """Static support check for the fused kernel on this QuantizedLinear
    (per-config, not per-call: everything here is trace-time metadata)."""
    if qt.bits not in _KERNEL_BITS:
        return False, (f"bits={qt.bits} has no packed-unpack path in the "
                       f"kernel (supported: {_KERNEL_BITS})")
    if qt.n % qt.group_size != 0:
        return False, f"n={qt.n} not divisible by group={qt.group_size}"
    bk = min(512, qt.n)
    if bk % qt.group_size != 0 or qt.n % bk != 0:
        return False, (f"n={qt.n} not tileable into group-aligned k-blocks "
                       f"(group={qt.group_size})")
    if qt.m > 128 and qt.m % 128 != 0:
        return False, f"m={qt.m} > 128 and not a multiple of the 128 m-block"
    if qt.rank > _KERNEL_MAX_RANK:
        return False, (f"rank={qt.rank} > {_KERNEL_MAX_RANK}: U tile would "
                       f"not stay VMEM-resident")
    return True, "supported"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(
    requested: str,
    qt: QuantizedLinear,
    interpret: Optional[bool] = None,
) -> Tuple[str, str]:
    """(chosen, reason) for one QuantizedLinear under ``requested`` policy.
    ``chosen`` is "ref", "fused" or "fused-interpret"."""
    if requested not in BACKENDS:
        raise ValueError(f"backend={requested!r} not in {BACKENDS}")
    if requested == "ref":
        return "ref", "explicitly requested"
    ok, why = kernel_supported(qt)
    if not ok:
        return "ref", f"fused unsupported for this config: {why}"
    run_interpret = (not _on_tpu()) if interpret is None else interpret
    if requested == "fused":
        if run_interpret:
            return "fused-interpret", ("requested fused; interpret mode "
                                       "(no TPU backend)")
        if not _on_tpu():
            # interpret explicitly disabled but no TPU to lower for — a
            # real pallas_call would die at lowering; serve ref instead
            # and say so.
            return "ref", (f"fused with interpret=False on "
                           f"{jax.default_backend()}: real kernel needs "
                           f"a TPU")
        return "fused", "requested fused"
    # auto: the kernel only wins on real hardware — interpret mode is a
    # validation tool, orders of magnitude slower than the jnp reference.
    if _on_tpu():
        return "fused", "auto: TPU available and config supported"
    if interpret:
        return "fused-interpret", "auto with interpret forced"
    return "ref", (f"auto on {jax.default_backend()}: fused kernel needs a "
                   f"TPU (interpret mode is validation-only)")


_ACTIVE: List[Tuple[str, Optional[bool]]] = [("ref", None)]


@contextlib.contextmanager
def backend_scope(backend: str, interpret: Optional[bool] = None):
    """Install ``backend`` as the active policy for quantized matmuls traced
    inside the scope (``models.layers.mm`` reads it). Decisions are made at
    trace time, so wrap the *tracing* of a jitted function — the serving
    engine does this for its prefill/decode executables."""
    if backend not in BACKENDS:
        raise ValueError(f"backend={backend!r} not in {BACKENDS}")
    _ACTIVE.append((backend, interpret))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_backend() -> str:
    return _ACTIVE[-1][0]


# Draft-model rank cap for self-speculative decoding. ``None`` = serve the
# full tensor; an int r = serve ``truncate_rank(qt, r)`` — a view sharing
# the packed int4 payload, so the SAME ref/fused kernels run the draft pass
# with a narrower (or absent) low-rank correction. A stack, like the
# backend stack, so nested scopes restore correctly.
_DRAFT_RANK: List[Optional[int]] = [None]


@contextlib.contextmanager
def draft_scope(rank: int):
    """Serve every quantized matmul traced inside the scope from its
    rank-``rank`` draft view (rank 0 = int4 backbone only). Trace-time,
    like ``backend_scope``: the speculative engine wraps the *tracing* of
    its draft executable so one policy covers the whole model. Plain
    (non-quantized) parameters are untouched — under fp weights the draft
    degenerates to the target model."""
    if rank < 0:
        raise ValueError(f"draft rank must be >= 0, got {rank}")
    _DRAFT_RANK.append(int(rank))
    try:
        yield
    finally:
        _DRAFT_RANK.pop()


def active_draft_rank() -> Optional[int]:
    return _DRAFT_RANK[-1]


def dispatch(qt: QuantizedLinear, x, out_dtype=None,
             backend: Optional[str] = None,
             interpret: Optional[bool] = None):
    """Route one quantized matmul through the active (or given) backend,
    recording the decision. This is THE serving entry point — everything
    from ``models.layers.mm`` down lands here."""
    scope_backend, scope_interp = _ACTIVE[-1]
    requested = backend or scope_backend
    if interpret is None:
        interpret = scope_interp
    if _DRAFT_RANK[-1] is not None:
        qt = truncate_rank(qt, _DRAFT_RANK[-1])
    chosen, reason = resolve_backend(requested, qt, interpret)
    _count_dispatch(requested, chosen)
    _DISPATCH_LOG.append(BackendDecision(
        requested=requested, chosen=chosen, reason=reason,
        shape=(qt.m, qt.n), bits=qt.bits))
    if chosen == "ref":
        return apply_lowrank_separate(qt, x, out_dtype=out_dtype)
    return apply_kernel(qt, x, out_dtype=out_dtype,
                        interpret=(chosen == "fused-interpret"))
