"""QuantizedLinear: the runtime representation of an FLRQ-quantized matrix.

    W ≈ ( deq(codes) + U @ V ) @ diag(act_scale_inv)

so  y = W x  is served as

    xs = act_scale_inv ⊙ x
    y  = deq(codes) @ xs + U @ (V @ xs)

Registered as a JAX pytree so it shards/jits/checkpoints like any other
parameter. All static metadata (bits, group size, logical shape) lives in
the aux data, all arrays are leaves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.quantize import QuantSpec
from . import packing


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedLinear:
    packed: jax.Array           # (m, n_groups, packed_group) uint8
    scale: jax.Array            # (m, n_groups, 1) f32
    zp: jax.Array               # (m, n_groups, 1) f32
    u: jax.Array                # (m, r) low-rank left factor (bf16/f32)
    v: jax.Array                # (r, n) low-rank right factor
    act_scale_inv: jax.Array    # (n,) inverse activation scaling (ones if off)
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    group_size: int = dataclasses.field(metadata=dict(static=True), default=128)
    symmetric: bool = dataclasses.field(metadata=dict(static=True), default=False)
    m: int = dataclasses.field(metadata=dict(static=True), default=0)
    n: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def spec(self) -> QuantSpec:
        return QuantSpec(self.bits, self.group_size, self.symmetric)

    @property
    def rank(self) -> int:
        # last axis, not [1]: stacked (lane-leading) tensors carry u as
        # (..., m, r) and must report r, not m
        return self.u.shape[-1]

    # --- storage accounting (paper Eq. 9 / Tables 3, 19-20) ----------------
    def storage_bits(self) -> int:
        lowrank = 16 * self.rank * (self.m + self.n)
        scales = 32 * 2 * self.m * (self.n // self.group_size)
        return self.bits * self.m * self.n + lowrank + scales

    def extra_avg_bits(self) -> float:
        """Average extra bits per weight from the low-rank factors."""
        return extra_avg_bits(self.rank, self.m, self.n)


# ---------------------------------------------------------------------------
# Stacked (lane-leading) QuantizedLinear: the serving layout.
#
# ``quantize_model_stacked`` emits one QuantizedLinear per weight *family*
# with every per-layer tensor stacked on a leading lane dim (L, ...) —
# exactly the shape ``lax.scan`` slices per layer in the transformer stacks,
# so the stacked form survives from the quantizer all the way into the
# scanned decode step without ever being split into per-layer pytrees.
# ---------------------------------------------------------------------------

def is_stacked(qt: QuantizedLinear) -> bool:
    """True when ``qt`` carries leading lane dims (packed is (..., m, ng, pg)
    with at least one extra axis). A per-layer tensor — what a scan body or
    ``lane`` yields — has a 3-D packed buffer."""
    return qt.packed.ndim > 3


def num_lanes(qt: QuantizedLinear) -> int:
    """Product of the leading lane dims (1 for an unstacked tensor)."""
    lanes = 1
    for d in qt.packed.shape[:-3]:
        lanes *= d
    return lanes


def lane(qt: QuantizedLinear, i) -> QuantizedLinear:
    """Index one lane out of a stacked QuantizedLinear — the explicit form
    of what ``lax.scan`` does implicitly when scanning a layer stack
    (``i`` may be a traced index; static metadata is untouched)."""
    if not is_stacked(qt):
        raise ValueError("lane() on an unstacked QuantizedLinear")
    take = lambda a: a[i]
    return dataclasses.replace(
        qt, packed=take(qt.packed), scale=take(qt.scale), zp=take(qt.zp),
        u=take(qt.u), v=take(qt.v), act_scale_inv=take(qt.act_scale_inv))


def stack_qtensors(qts) -> QuantizedLinear:
    """Stack per-layer QuantizedLinear tensors into the lane-leading serving
    form. Ranks are zero-padded to the stack max (zero U columns / V rows
    are numerically inert; storage accounting keeps true per-layer ranks in
    LayerStats). All members must share the quant config and logical shape."""
    qts = list(qts)
    if not qts:
        raise ValueError("stack_qtensors of an empty sequence")
    q0 = qts[0]
    for q in qts[1:]:
        if (q.bits, q.group_size, q.symmetric, q.m, q.n) != (
                q0.bits, q0.group_size, q0.symmetric, q0.m, q0.n):
            raise ValueError(
                "stack_qtensors needs uniform (bits, group, symmetric, m, n); "
                f"got {(q.bits, q.group_size, q.symmetric, q.m, q.n)} vs "
                f"{(q0.bits, q0.group_size, q0.symmetric, q0.m, q0.n)}")
    rmax = max(max(q.rank for q in qts), 1)

    def pad_u(q):
        u = q.u.astype(jnp.float32)
        return jnp.pad(u, ((0, 0), (0, rmax - u.shape[1])))

    def pad_v(q):
        v = q.v.astype(jnp.float32)
        return jnp.pad(v, ((0, rmax - v.shape[0]), (0, 0)))

    store_dtype = q0.u.dtype
    return QuantizedLinear(
        packed=jnp.stack([q.packed for q in qts]),
        scale=jnp.stack([q.scale for q in qts]),
        zp=jnp.stack([q.zp for q in qts]),
        u=jnp.stack([pad_u(q) for q in qts]).astype(store_dtype),
        v=jnp.stack([pad_v(q) for q in qts]).astype(store_dtype),
        act_scale_inv=jnp.stack([q.act_scale_inv for q in qts]),
        bits=q0.bits, group_size=q0.group_size, symmetric=q0.symmetric,
        m=q0.m, n=q0.n,
    )


def slice_stack(qt: QuantizedLinear, start: int, stop: int,
                rank: Optional[int] = None) -> QuantizedLinear:
    """Slice lanes [start:stop) out of a stacked QuantizedLinear — the
    inverse of same-shape stack fusion (one (G·L, m, n) launch split back
    into per-tensor stacks). ``rank``: re-trim the U/V buffers to this
    sub-stack's own realized max rank (fused launches pad every member to
    the fused-global max; after splitting each tensor keeps only its own)."""
    r = qt.u.shape[-1] if rank is None else max(int(rank), 1)
    return dataclasses.replace(
        qt,
        packed=qt.packed[start:stop],
        scale=qt.scale[start:stop],
        zp=qt.zp[start:stop],
        u=qt.u[start:stop, :, :r],
        v=qt.v[start:stop, :r, :],
        act_scale_inv=qt.act_scale_inv[start:stop],
    )


def truncate_rank(qt: QuantizedLinear, r: int) -> QuantizedLinear:
    """Rank-truncated *view* of a QuantizedLinear — the self-speculative
    draft model. Keeps the leading ``r`` low-rank columns (``r=0`` drops the
    correction entirely, leaving the int4 backbone); the packed codes,
    scales, zero points and activation scaling are shared by reference, so
    a draft view costs no copies of the 4-bit payload. Works on both
    unstacked (m, r)/(r, n) and lane-stacked (..., m, r)/(..., r, n)
    factors. ``r`` above the stored rank is clamped, not padded."""
    r = max(0, min(int(r), qt.rank))
    return dataclasses.replace(qt, u=qt.u[..., :r], v=qt.v[..., :r, :])


def dequantize_stacked(qt: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    """``dequantize`` over every lane of a stacked tensor: (..., m, n).
    ``dequantize`` reshapes to the static (m, n), so lane dims must be
    vmapped off one at a time; an unstacked tensor passes straight
    through."""
    fn = lambda q: dequantize(q, dtype)
    for _ in range(qt.packed.ndim - 3):
        fn = jax.vmap(fn)
    return fn(qt)


def extra_avg_bits(rank: int, m: int, n: int, d_fp: int = 16) -> float:
    """Average extra bits per weight from rank-``rank`` factors stored at
    ``d_fp`` bits (paper Eq. 9 storage accounting — single definition)."""
    return float(d_fp) * rank * (m + n) / (m * n)


def pack_codes(w_q_codes: jax.Array, spec: QuantSpec) -> jax.Array:
    """Integer codes → packed uint8. THE code-domain convention: asymmetric
    codes are already unsigned; symmetric codes are signed and shifted by
    2^(bits-1) into the unsigned packing domain. Every packer/unpacker
    (from_parts, dequantize*, the batched stack engine) goes through the
    offset defined here."""
    offs = (1 << (spec.bits - 1)) if spec.symmetric else 0
    return packing.pack(w_q_codes + offs, spec.bits)


def from_parts(
    w_q_codes: jax.Array,       # (m, ng, g) int32 unsigned-domain codes
    scale: jax.Array,
    zp: jax.Array,
    u: jax.Array,
    v: jax.Array,
    spec: QuantSpec,
    act_scale_inv: Optional[jax.Array] = None,
    store_dtype=jnp.bfloat16,
) -> QuantizedLinear:
    m, ng, g = w_q_codes.shape
    n = ng * g
    packed = pack_codes(w_q_codes, spec)
    if act_scale_inv is None:
        act_scale_inv = jnp.ones((n,), store_dtype)
    return QuantizedLinear(
        packed=packed,
        scale=scale.astype(jnp.float32),
        zp=zp.astype(jnp.float32),
        u=u.astype(store_dtype),
        v=v.astype(store_dtype),
        act_scale_inv=act_scale_inv.astype(store_dtype),
        bits=spec.bits,
        group_size=spec.group_size,
        symmetric=spec.symmetric,
        m=m,
        n=n,
    )


def dequantize(qt: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    """Materialize the effective full-precision matrix (m, n), including the
    low-rank correction and activation scaling."""
    codes = packing.unpack(qt.packed, qt.bits, qt.group_size)
    offs = (1 << (qt.bits - 1)) if qt.symmetric else 0
    wq = ((codes - offs).astype(jnp.float32) - qt.zp) * qt.scale
    wq = wq.reshape(qt.m, qt.n)
    w = wq + qt.u.astype(jnp.float32) @ qt.v.astype(jnp.float32)
    return (w * qt.act_scale_inv.astype(jnp.float32)[None, :]).astype(dtype)


def dequantize_qpart(qt: QuantizedLinear, dtype=jnp.float32) -> jax.Array:
    """Only deq(codes) (m, n) — what the Pallas kernel reconstructs on-chip."""
    codes = packing.unpack(qt.packed, qt.bits, qt.group_size)
    offs = (1 << (qt.bits - 1)) if qt.symmetric else 0
    wq = ((codes - offs).astype(jnp.float32) - qt.zp) * qt.scale
    return wq.reshape(qt.m, qt.n).astype(dtype)
