"""Quantized-weight runtime representation (packing, pytree, apply) and the
serving backend-dispatch layer (ref | fused | auto)."""
from .qtensor import (  # noqa: F401
    QuantizedLinear,
    from_parts,
    dequantize,
    is_stacked,
    lane,
    num_lanes,
    stack_qtensors,
)
from .apply import (  # noqa: F401
    BACKENDS,
    BackendDecision,
    apply,
    apply_kernel,
    apply_lowrank_separate,
    backend_scope,
    clear_dispatch_log,
    dispatch,
    dispatch_log,
    dispatch_report,
    kernel_supported,
    resolve_backend,
)
