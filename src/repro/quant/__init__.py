"""Quantized-weight runtime representation (packing, pytree, apply)."""
from .qtensor import QuantizedLinear, from_parts, dequantize  # noqa: F401
from .apply import apply, apply_lowrank_separate, apply_kernel  # noqa: F401
