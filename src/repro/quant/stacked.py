"""Quantizing whole *stacked* model parameter trees for serving.

Model stacks store layer weights stacked as (L, in, out) (MoE: (L, E, in,
out)) so lax.scan slices them per layer. FLRQ selects a *different* rank per
layer (the paper's point), but a scanned executable needs uniform shapes —
the production answer is rank bucketing: zero-pad every layer's (U, V) to
the per-tensor max rank. Zero columns contribute nothing numerically;
storage accounting keeps the true per-layer ranks.

Two engines:

``engine="batched"`` (default) — ``repro.core.flrq.quantize_stack``: all L
layers of a stacked tensor go through scaling → vmapped R1-FLR → batched
BLC → batched packing as ONE jitted device program. No per-peel host
syncs, no per-layer dispatch loop; rank padding falls out of the fixed
FLR buffers.

``engine="sequential"`` — the reference oracle: a python loop of
``quantize_matrix`` per layer (each layer's R1-FLR syncs ``amax`` to the
host after every peel), then pad-and-stack. Same PRNG key chain as the
batched engine, so the two agree layer-for-layer up to sketch-order
stochasticity. Note both engines share the blocked BLC re-sketch
(``core.blc``, block=8 default); pass ``block=1`` there for the paper's
literal rank-1 peel.

``quantize_model_stacked``  — real quantization (CPU-sized models, examples)
``abstract_quantized_params`` — ShapeDtypeStruct tree of the same layout at
full production scale, for the quantized-serving dry-run cells.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.flrq import (
    FLRQConfig,
    LayerStats,
    layer_key_chain,
    quantize_matrix,
    quantize_stack,
)
from .qtensor import QuantizedLinear
from . import packing

# stacked params we quantize: every big 2-D matrix inside 'layers'
_QUANT_PAT = re.compile(
    r"wq$|wk$|wv$|wo$|w_gate$|w_up$|w_down$|w_in$|w_out$|"
    r"\bwr$|\bwg$|wk_cm$|wv_cm$|wr_cm$|w_dt$")

ENGINES = ("batched", "sequential")


def should_quantize(path: str, shape) -> bool:
    if "layers" not in path:
        return False
    if not _QUANT_PAT.search(path.replace("'", "").replace("]", "")):
        return False
    a, b = shape[-2], shape[-1]
    return a >= 128 and b >= 128 and a % 128 == 0


def _stack_qts(qts, store_dtype):
    """Pad ranks to max and stack a list of per-layer QuantizedLinear."""
    rmax = max(max(q.rank for q in qts), 1)

    def pad_u(q):
        u = np.asarray(q.u.astype(jnp.float32))
        return np.pad(u, ((0, 0), (0, rmax - u.shape[1])))

    def pad_v(q):
        v = np.asarray(q.v.astype(jnp.float32))
        return np.pad(v, ((0, rmax - v.shape[0]), (0, 0)))

    q0 = qts[0]
    return QuantizedLinear(
        packed=jnp.stack([q.packed for q in qts]),
        scale=jnp.stack([q.scale for q in qts]),
        zp=jnp.stack([q.zp for q in qts]),
        u=jnp.asarray(np.stack([pad_u(q) for q in qts])).astype(store_dtype),
        v=jnp.asarray(np.stack([pad_v(q) for q in qts])).astype(store_dtype),
        act_scale_inv=jnp.stack([q.act_scale_inv for q in qts]),
        bits=q0.bits, group_size=q0.group_size, symmetric=q0.symmetric,
        m=q0.m, n=q0.n,
    )


def quantize_model_stacked(
    params,
    calib_acts: Optional[Dict[str, jax.Array]],
    cfg: FLRQConfig,
    progress=None,
    engine: str = "batched",
):
    """Returns (serving params tree with QuantizedLinear leaves, stats).

    ``engine="batched"`` quantizes each stacked tensor's L layers in one
    jitted launch; ``engine="sequential"`` is the per-layer reference
    oracle (kept for parity testing and as the paper-verbatim fallback).
    """
    if engine not in ENGINES:
        raise ValueError(f"engine={engine!r} not in {ENGINES}")
    key = jax.random.PRNGKey(cfg.seed)
    stats: Dict[str, list] = {}

    def visit(path, leaf):
        nonlocal key
        pstr = jax.tree_util.keystr(path)
        if not (hasattr(leaf, "ndim") and leaf.ndim in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            return leaf
        lead = leaf.shape[:-2]
        flat = leaf.reshape((-1,) + leaf.shape[-2:])
        xc = calib_acts.get(pstr) if calib_acts else None
        if engine == "batched":
            # transpose: model (in, out) -> quantizer (out=m, in=n)
            w_stack = jnp.swapaxes(flat, -1, -2)
            layer_keys, key = layer_key_chain(key, flat.shape[0])
            stacked, lstats = quantize_stack(w_stack, xc, cfg, name=pstr,
                                             keys=layer_keys)
            if progress:
                for st in lstats:
                    progress(st.name, st)
        else:
            qts, lstats = [], []
            for i in range(flat.shape[0]):
                key, sub = jax.random.split(key)
                qt, st = quantize_matrix(flat[i].T, xc, cfg, sub,
                                         name=f"{pstr}[{i}]")
                qts.append(qt)
                lstats.append(st)
                if progress:
                    progress(f"{pstr}[{i}]", st)
            stacked = _stack_qts(qts, cfg.store_dtype)
        stats[pstr] = lstats
        if len(lead) == 2:  # MoE (L, E, ...) — restack leading dims
            def reshape_lead(x):
                return x.reshape(lead + x.shape[1:])
            stacked = dataclasses.replace(
                stacked,
                packed=reshape_lead(stacked.packed),
                scale=reshape_lead(stacked.scale),
                zp=reshape_lead(stacked.zp),
                u=reshape_lead(stacked.u),
                v=reshape_lead(stacked.v),
                act_scale_inv=reshape_lead(stacked.act_scale_inv),
            )
        return stacked

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, stats


def abstract_quantized_params(params_shapes, cfg: FLRQConfig,
                              nominal_rank: int = 40):
    """ShapeDtypeStruct tree for quantized serving at full scale (dry-run
    only — no weights exist). ``nominal_rank``: the paper's ~40 average
    rank (Table 3/4) padded per tensor."""
    SDS = jax.ShapeDtypeStruct
    spec = cfg.spec()

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not (hasattr(leaf, "shape") and len(leaf.shape) in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            return leaf
        lead = leaf.shape[:-2]
        n_in, m_out = leaf.shape[-2], leaf.shape[-1]  # model (in, out)
        m, n = m_out, n_in
        ng = n // cfg.group_size
        pg = packing.packed_size(cfg.group_size, cfg.bits)
        r = min(nominal_rank, m, n)
        return QuantizedLinear(
            packed=SDS(lead + (m, ng, pg), jnp.uint8),
            scale=SDS(lead + (m, ng, 1), jnp.float32),
            zp=SDS(lead + (m, ng, 1), jnp.float32),
            u=SDS(lead + (m, r), cfg.store_dtype),
            v=SDS(lead + (r, n), cfg.store_dtype),
            act_scale_inv=SDS(lead + (n,), cfg.store_dtype),
            bits=cfg.bits, group_size=cfg.group_size,
            symmetric=cfg.symmetric, m=m, n=n,
        )

    return jax.tree_util.tree_map_with_path(visit, params_shapes)
