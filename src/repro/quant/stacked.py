"""Quantizing whole *stacked* model parameter trees for serving.

Model stacks store layer weights stacked as (L, in, out) (MoE: (L, E, in,
out)) so lax.scan slices them per layer. FLRQ selects a *different* rank per
layer (the paper's point), but a scanned executable needs uniform shapes —
the production answer is rank bucketing: zero-pad every layer's (U, V) to
the per-tensor max rank. Zero columns contribute nothing numerically;
storage accounting keeps the true per-layer ranks.

Two engines:

``engine="batched"`` (default) — ``repro.core.flrq.quantize_stack``: all L
layers of a stacked tensor go through scaling → vmapped R1-FLR → batched
BLC → batched packing as ONE jitted device program. No per-peel host
syncs, no per-layer dispatch loop; rank padding falls out of the fixed
FLR buffers. Two scale-out levers on top:

  * same-shape stack fusion (``fuse_stacks=True``): stacked tensors whose
    quantizer shape (m, n) matches — Q/K/V/O, gate/up — are concatenated
    into one (G·L, m, n) launch and split back on return, amortizing
    compile time and filling the machine at small layer counts. Tensors
    that see different calibration activations ride a per-lane calibration
    batch through the same launch.
  * mesh sharding (``mesh=``/``axis=``): the fused stack's leading dim is
    ``shard_map``-ed over the quantization mesh so whole-model quantization
    time scales with the pod, not one chip. Results are bit-identical to
    the single-device batched engine.

``engine="sequential"`` — the reference oracle: a python loop of
``quantize_matrix`` per layer (each layer's R1-FLR syncs ``amax`` to the
host after every peel), then pad-and-stack. Same PRNG key chain as the
batched engine, so the two agree layer-for-layer up to sketch-order
stochasticity. Note both engines share the blocked BLC re-sketch
(``core.blc``, block=8 default); pass ``block=1`` there for the paper's
literal rank-1 peel.

``quantize_model_stacked``  — real quantization (CPU-sized models, examples)
``abstract_quantized_params`` — ShapeDtypeStruct tree of the same layout at
full production scale, for the quantized-serving dry-run cells.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core.flrq import (
    FLRQConfig,
    LayerStats,
    layer_key_chain,
    quantize_matrix,
    quantize_stack,
)
from .qtensor import QuantizedLinear
from . import packing, qtensor

# stacked params we quantize: every big 2-D matrix inside 'layers'
_QUANT_PAT = re.compile(
    r"wq$|wk$|wv$|wo$|w_gate$|w_up$|w_down$|w_in$|w_out$|"
    r"\bwr$|\bwg$|wk_cm$|wv_cm$|wr_cm$|w_dt$")

ENGINES = ("batched", "sequential")


def should_quantize(path: str, shape) -> bool:
    if "layers" not in path:
        return False
    if not _QUANT_PAT.search(path.replace("'", "").replace("]", "")):
        return False
    a, b = shape[-2], shape[-1]
    return a >= 128 and b >= 128 and a % 128 == 0


def _restack_lead(stacked: QuantizedLinear, lead) -> QuantizedLinear:
    """MoE (L, E, ...) tensors: restore the flattened leading dims."""
    def reshape_lead(x):
        return x.reshape(lead + x.shape[1:])
    return dataclasses.replace(
        stacked,
        packed=reshape_lead(stacked.packed),
        scale=reshape_lead(stacked.scale),
        zp=reshape_lead(stacked.zp),
        u=reshape_lead(stacked.u),
        v=reshape_lead(stacked.v),
        act_scale_inv=reshape_lead(stacked.act_scale_inv),
    )


@dataclasses.dataclass
class _StackEntry:
    path: str
    leaf: jax.Array          # original model-layout tensor (L[, E], in, out)
    xc: Optional[jax.Array]  # (tokens, n) calibration acts or None
    keys: jax.Array          # (L, 2) per-layer PRNG keys

    @property
    def lanes(self) -> int:
        lanes = 1
        for d in self.leaf.shape[:-2]:
            lanes *= d
        return lanes

    @property
    def quant_shape(self):
        # transpose convention: model (in, out) -> quantizer (out=m, in=n)
        return self.leaf.shape[-1], self.leaf.shape[-2]

    def w_stack(self) -> jax.Array:
        """(lanes, m, n) quantizer-orientation copy — built on demand so
        the transposed duplicate of each tensor lives only for its own
        group's launch, not the whole model walk (at production scale a
        second full-model fp32 copy is the dominant transient)."""
        flat = self.leaf.reshape((-1,) + self.leaf.shape[-2:])
        return jnp.swapaxes(flat, -1, -2)


def _collect_entries(params, calib_acts, cfg: FLRQConfig) -> List[_StackEntry]:
    """First pass: every quantizable stacked tensor, in tree-traversal
    order, with its slice of the global PRNG key chain (the chain advances
    per tensor exactly as the unfused engine's visit order — fusion only
    regroups launches, never key derivation)."""
    key = jax.random.PRNGKey(cfg.seed)
    entries: List[_StackEntry] = []

    def visit(path, leaf):
        nonlocal key
        pstr = jax.tree_util.keystr(path)
        if not (hasattr(leaf, "ndim") and leaf.ndim in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            return leaf
        n_lanes = 1
        for d in leaf.shape[:-2]:
            n_lanes *= d
        layer_keys, key = layer_key_chain(key, n_lanes)
        xc = calib_acts.get(pstr) if calib_acts else None
        entries.append(_StackEntry(pstr, leaf, xc, layer_keys))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return entries


def _group_calib(group: List[_StackEntry]):
    """The calibration objective for one fused launch, as ``(x, x_index)``:
    ``(None, None)`` (Frobenius), ``((tokens, n), None)`` when every member
    sees the same activations, or — when they differ — a (U, tokens, n)
    stack of the U *unique* batches plus a (ΣL,) lane→batch index that the
    launch gathers device-side (``quantize_stack(x_index=...)``). The old
    formulation broadcast each member's batch to all of its lanes, shipping
    a ~G·L× copy of the calibration set through host memory, the shard
    scatter, and every chunked launch; one copy per unique batch + a tiny
    index is equivalent bit for bit. Sameness is checked by identity first,
    then by content — value-equal batches from different loads must not
    silently land in separate unique slots."""
    if all(e.xc is None for e in group):
        return None, None
    x0 = group[0].xc
    if all(e.xc is x0
           or (e.xc.shape == x0.shape and bool(jnp.array_equal(e.xc, x0)))
           for e in group[1:]):
        return x0, None
    uniques: List[jax.Array] = []
    lane_idx: List[int] = []
    for e in group:
        slot = None
        for u_i, xu in enumerate(uniques):
            if e.xc is xu or (e.xc.shape == xu.shape
                              and bool(jnp.array_equal(e.xc, xu))):
                slot = u_i
                break
        if slot is None:
            slot = len(uniques)
            uniques.append(e.xc)
        lane_idx.extend([slot] * e.lanes)
    return (jnp.stack(uniques),
            jnp.asarray(lane_idx, jnp.int32))


def _quantize_batched(params, calib_acts, cfg: FLRQConfig, progress,
                      mesh, axis, fuse_stacks: bool,
                      layer_chunk: Optional[int] = None):
    entries = _collect_entries(params, calib_acts, cfg)

    # --- group same-shape stacks for fusion --------------------------------
    # Fusable = same quantizer (m, n) and same calibration arity (tokens
    # count, or no calibration at all) — the launch needs one uniform
    # objective shape per lane.
    groups: Dict[Any, List[_StackEntry]] = {}
    order: List[Any] = []
    for e in entries:
        m, n = e.quant_shape
        gk = (m, n, None if e.xc is None else e.xc.shape[0])
        if not fuse_stacks:
            gk = (e.path,)
        if gk not in groups:
            groups[gk] = []
            order.append(gk)
        groups[gk].append(e)

    results: Dict[str, QuantizedLinear] = {}
    stats: Dict[str, List[LayerStats]] = {}

    def report(path):
        # stream per-layer progress as each group finishes, not post-hoc —
        # whole-model runs are long and the callback is the live log
        if progress:
            for st in stats[path]:
                progress(st.name, st)

    for gk in order:
        group = groups[gk]
        if len(group) == 1:
            e = group[0]
            # donate=True: w_stack() is this launch's private transposed
            # copy — donating it lets XLA recycle the one transient that
            # doubles the model footprint during quantization.
            qt, lst = quantize_stack(e.w_stack(), e.xc, cfg, name=e.path,
                                     keys=e.keys, mesh=mesh, axis=axis,
                                     donate=True, layer_chunk=layer_chunk)
            results[e.path] = qt
            stats[e.path] = lst
            report(e.path)
            continue
        # fused launch: concat along the lane dim, split back on return
        w_cat = jnp.concatenate([e.w_stack() for e in group])
        keys_cat = jnp.concatenate([e.keys for e in group])
        x_cat, x_idx = _group_calib(group)
        fused_name = "+".join(e.path for e in group)
        qt, lst = quantize_stack(w_cat, x_cat, cfg, name=fused_name,
                                 keys=keys_cat, mesh=mesh, axis=axis,
                                 donate=True, x_index=x_idx,
                                 layer_chunk=layer_chunk)
        off = 0
        for e in group:
            L = e.lanes
            sub = lst[off:off + L]
            rmax = max(max(s.rank for s in sub), 1)
            results[e.path] = qtensor.slice_stack(qt, off, off + L, rank=rmax)
            stats[e.path] = [
                dataclasses.replace(s, name=f"{e.path}[{j}]")
                for j, s in enumerate(sub)]
            off += L
            report(e.path)

    # --- rebuild the tree in original traversal order ----------------------
    def rebuild(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if pstr not in results:
            return leaf
        stacked = results[pstr]
        if len(leaf.shape[:-2]) == 2:  # MoE (L, E, ...)
            stacked = _restack_lead(stacked, leaf.shape[:-2])
        return stacked

    qtree = jax.tree_util.tree_map_with_path(rebuild, params)
    return qtree, stats


def quantize_model_stacked(
    params,
    calib_acts: Optional[Dict[str, jax.Array]],
    cfg: FLRQConfig,
    progress=None,
    engine: str = "batched",
    mesh=None,
    axis: Optional[str] = None,
    fuse_stacks: bool = True,
    layer_chunk: Optional[int] = None,
):
    """Returns (serving params tree with QuantizedLinear leaves, stats).

    ``engine="batched"`` quantizes each stacked tensor's L layers in one
    jitted launch — same-shape tensors fuse into a single launch
    (``fuse_stacks``) and the lane dim shards over ``mesh``/``axis`` when
    given; ``layer_chunk=K`` splits every launch into ceil(L/K) lane chunks
    so the engine's transient f32 residuals are bounded at (K, m, n)
    (bit-identical output — production-shape memory lever).
    ``engine="sequential"`` is the per-layer reference oracle (kept
    for parity testing and as the paper-verbatim fallback).
    """
    if engine not in ENGINES:
        raise ValueError(f"engine={engine!r} not in {ENGINES}")
    if engine == "batched":
        return _quantize_batched(params, calib_acts, cfg, progress,
                                 mesh, axis, fuse_stacks, layer_chunk)
    if mesh is not None:
        raise ValueError("mesh sharding requires engine='batched'")
    if layer_chunk is not None:
        raise ValueError("layer_chunk requires engine='batched'")

    key = jax.random.PRNGKey(cfg.seed)
    stats: Dict[str, list] = {}

    def visit(path, leaf):
        nonlocal key
        pstr = jax.tree_util.keystr(path)
        if not (hasattr(leaf, "ndim") and leaf.ndim in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            return leaf
        lead = leaf.shape[:-2]
        flat = leaf.reshape((-1,) + leaf.shape[-2:])
        xc = calib_acts.get(pstr) if calib_acts else None
        qts, lstats = [], []
        for i in range(flat.shape[0]):
            key, sub = jax.random.split(key)
            qt, st = quantize_matrix(flat[i].T, xc, cfg, sub,
                                     name=f"{pstr}[{i}]")
            qts.append(qt)
            lstats.append(st)
            if progress:
                progress(f"{pstr}[{i}]", st)
        stacked = qtensor.stack_qtensors(qts)
        stats[pstr] = lstats
        if len(lead) == 2:  # MoE (L, E, ...) — restack leading dims
            stacked = _restack_lead(stacked, lead)
        return stacked

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, stats


def abstract_quantized_params(params_shapes, cfg: FLRQConfig,
                              nominal_rank: int = 40):
    """ShapeDtypeStruct tree for quantized serving at full scale (dry-run
    only — no weights exist). ``nominal_rank``: the paper's ~40 average
    rank (Table 3/4) padded per tensor."""
    SDS = jax.ShapeDtypeStruct
    spec = cfg.spec()

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if not (hasattr(leaf, "shape") and len(leaf.shape) in (3, 4)
                and should_quantize(pstr, leaf.shape)):
            return leaf
        lead = leaf.shape[:-2]
        n_in, m_out = leaf.shape[-2], leaf.shape[-1]  # model (in, out)
        m, n = m_out, n_in
        ng = n // cfg.group_size
        pg = packing.packed_size(cfg.group_size, cfg.bits)
        r = min(nominal_rank, m, n)
        return QuantizedLinear(
            packed=SDS(lead + (m, ng, pg), jnp.uint8),
            scale=SDS(lead + (m, ng, 1), jnp.float32),
            zp=SDS(lead + (m, ng, 1), jnp.float32),
            u=SDS(lead + (m, r), cfg.store_dtype),
            v=SDS(lead + (r, n), cfg.store_dtype),
            act_scale_inv=SDS(lead + (n,), cfg.store_dtype),
            bits=cfg.bits, group_size=cfg.group_size,
            symmetric=cfg.symmetric, m=m, n=n,
        )

    return jax.tree_util.tree_map_with_path(visit, params_shapes)
