"""Sharding rules: parameters (2-D FSDP×TP), activations, batches, caches.

Layout policy (v5e 16×16 pod, optionally ×2 pods):
  * TP ("model")            — attention heads / d_ff / vocab
  * FSDP ("pod","data")     — the other weight dim, gathered layer-by-layer
                              inside lax.scan (XLA overlaps gather & compute)
  * batch ("pod","data")    — data parallel on the batch dim
  * decode KV cache         — batch on data, *sequence* on model (flash-
                              decode style distributed softmax; KV heads are
                              rarely divisible by 16, sequence always is)

Every rule degrades gracefully: an axis is applied only if it divides the
dim (e.g. hymba's 25 heads stay replicated on the head dim; its 1600-wide
d_model still FSDP-shards 32 ways).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


# ---------------------------------------------------------------------------
# Spec resolution with divisibility guards
# ---------------------------------------------------------------------------

def _axis_size(mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _filter_axis(mesh, axis):
    """Drop mesh axes that don't exist (e.g. 'pod' on a single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def resolve_spec(spec: P, mesh, shape: Optional[Tuple[int, ...]] = None) -> P:
    """Adapt a written-for-multipod PartitionSpec to ``mesh``: filter missing
    axes and (if ``shape`` given) drop axes that don't divide the dim."""
    out = []
    for i, axis in enumerate(spec):
        axis = _filter_axis(mesh, axis)
        if axis is not None and shape is not None:
            if shape[i] % _axis_size(mesh, axis) != 0:
                axis = None
        out.append(axis)
    return P(*out)


def make_constrainer(mesh):
    """Build the fn installed into models.layers.set_constrainer."""

    def constrain(x, spec: P):
        spec = resolve_spec(spec, mesh, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def install(mesh) -> None:
    from ..models import layers

    layers.set_constrainer(make_constrainer(mesh))


def uninstall() -> None:
    from ..models import layers

    layers.set_constrainer(lambda x, spec: x)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

FSDP = ("pod", "data")
TP = "model"

# (regex on param path) -> PartitionSpec for the *unstacked* dims.
# Stacked layer params get a leading None prepended automatically.
_RULES = [
    # embeddings / output head
    (r"embed$", P(TP, FSDP)),
    (r"unembed$", P(FSDP, TP)),
    # attention
    (r"\bwq$", P(FSDP, TP)),
    (r"\bwk$", P(FSDP, TP)),
    (r"\bwv$", P(FSDP, TP)),
    (r"\bwo$", P(TP, FSDP)),
    # dense mlp
    (r"w_gate$", P(FSDP, TP)),
    (r"w_up$", P(FSDP, TP)),
    (r"w_down$", P(TP, FSDP)),
    (r"\bw_in$", P(FSDP, TP)),
    (r"\bw_out$", P(TP, FSDP)),
    # moe (leading expert dim replicated; experts are few (8) or many (128),
    # neither matches the 16-way model axis — d_ff shards instead)
    (r"router$", P(FSDP, None)),
    # rwkv6
    (r"\bwr$|\bwg$", P(FSDP, TP)),
    (r"w_lora_a$", P(FSDP, None)),
    (r"w_lora_b$", P(None, FSDP)),
    (r"wk_cm$", P(FSDP, TP)),
    (r"wv_cm$", P(TP, FSDP)),
    (r"wr_cm$", P(FSDP, TP)),
    # hymba mamba
    (r"conv_w$", P(None, TP)),
    (r"conv_b$", P(TP)),
    (r"w_dt$", P(None, TP)),
    (r"w_B$|w_C$", P(TP, None)),
    (r"a_log$", P(TP, None)),
    (r"d_skip$", P(TP)),
]

_MOE_RULES = [
    (r"w_gate$", P(None, FSDP, TP)),
    (r"w_up$", P(None, FSDP, TP)),
    (r"w_down$", P(None, TP, FSDP)),
]

# expert parallelism: experts over the model axis (when divisible), d_ff
# unsharded -> the expert einsum contracts unsharded dims only (no
# model-axis partial-sum ARs on (B,E,cap,*) tensors; the combine is one
# (B,S,D) reduction per layer instead).
_MOE_EP_RULES = [
    (r"w_gate$", P(TP, FSDP, None)),
    (r"w_up$", P(TP, FSDP, None)),
    (r"w_down$", P(TP, None, FSDP)),
]


def _rule_for(path: str, ndim_unstacked: int, is_moe_expert: bool,
              expert_parallel: bool = False) -> P:
    if is_moe_expert:
        rules = (_MOE_EP_RULES if expert_parallel else _MOE_RULES) + _RULES
    else:
        rules = _RULES
    for pat, spec in rules:
        if re.search(pat, path):
            if len(spec) == ndim_unstacked:
                return spec
    return P(*([None] * ndim_unstacked))  # norms, biases, mu, scalars


_QFIELD = re.compile(r"\.(packed|scale|zp|u|v|act_scale_inv)$")


def _norm(path: str) -> str:
    """keystr gives "['layers']['wq'].packed" — normalize to
    ".layers.wq.packed" so the $-anchored rules match."""
    return re.sub(r"\[['\"]?([^'\"\]]+)['\"]?\]", r".\1", path)


def param_spec(path: str, leaf_shape: Tuple[int, ...], cfg: ModelConfig) -> P:
    path = _norm(path)
    qm = _QFIELD.search(path)
    if qm:
        return _quantized_spec(path[: qm.start()], qm.group(1), leaf_shape, cfg)
    stacked = ".layers" in path
    is_moe_expert = (
        cfg.family == "moe"
        and re.search(r"w_gate$|w_up$|w_down$", path) is not None
        and len(leaf_shape) == (4 if stacked else 3)
    )
    nd = len(leaf_shape) - (1 if stacked else 0)
    spec = _rule_for(path, nd, is_moe_expert,
                     getattr(cfg, "expert_parallel", False))
    if stacked:
        spec = P(None, *spec)
    return spec


def _quantized_spec(parent: str, field: str, leaf_shape, cfg: ModelConfig) -> P:
    """Sharding for a QuantizedLinear field, derived from the parent
    matrix's (in, out) rule: the quantizer stores the transpose, so the
    packed codes (m=out, n_groups=in/g) shard (a_out, a_in)."""
    base = _rule_for(parent, 2, False)
    a_in, a_out = base[0], base[1]
    if field in ("packed", "scale", "zp"):
        spec, nd = (a_out, a_in, None), 3
    elif field == "u":
        spec, nd = (a_out, None), 2
    elif field == "v":
        spec, nd = (None, a_in), 2
    else:  # act_scale_inv
        spec, nd = (a_in,), 1
    lead = len(leaf_shape) - nd
    return P(*([None] * lead), *spec)


def _strip_fsdp(spec: P) -> P:
    """Serving layout: drop the FSDP axes (weights replicate over data,
    shard TP-only) so decode never re-gathers weights per token."""
    def strip(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x not in FSDP)
            return kept if kept else None
        return None if a in FSDP else a

    return P(*[strip(a) for a in spec])


def param_shardings(cfg: ModelConfig, params_shapes, mesh,
                    serving_tp_only: bool = False,
                    tp_only_max_bytes: float = 12e9):
    """pytree of NamedSharding matching a params (shape) pytree.

    ``serving_tp_only``: beyond-paper serving layout — weights shard TP-only
    (replicated over the data axis) when the total TP-sharded footprint per
    chip stays under ``tp_only_max_bytes``; oversized models (grok-1 bf16)
    keep the 2-D layout. Eliminates the per-token FSDP all-gather that
    dominates the decode collective term.
    """
    use_tp_only = False
    if serving_tp_only:
        total = sum(
            l.size * getattr(l.dtype, "itemsize", 2)
            for l in jax.tree_util.tree_leaves(params_shapes))
        tp = _axis_size(mesh, TP)
        use_tp_only = total / tp <= tp_only_max_bytes

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        spec = param_spec(pstr, leaf.shape, cfg)
        if use_tp_only:
            spec = _strip_fsdp(spec)
        spec = resolve_spec(spec, mesh, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_shapes)


# ---------------------------------------------------------------------------
# Offline-quantizer stack placement
# ---------------------------------------------------------------------------

def stack_lane_shardings(mesh, axis: str, params):
    """NamedSharding tree for a *stacked* params tree on the quantization
    mesh: every (L, ...) tensor with ndim >= 3 shards its leading (layer)
    dim over ``axis`` when it divides; everything else replicates.

    This is the input placement for the mesh-sharded batched engine — at
    production scale the unquantized weight stacks are the dominant
    footprint, and pre-placing them lane-sharded means no single device
    ever has to hold a whole model tensor before quantization starts.
    """
    size = _axis_size(mesh, axis)

    def visit(leaf):
        nd = len(leaf.shape)
        if nd >= 3 and leaf.shape[0] % size == 0:
            spec = P(axis, *([None] * (nd - 1)))
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, spec)

    return jax.tree.map(visit, params)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(batch_shapes, mesh):
    """Shard the batch dim over (pod, data); everything else replicated."""

    def visit(leaf):
        spec = P(FSDP, *([None] * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, resolve_spec(spec, mesh, leaf.shape))

    return jax.tree.map(visit, batch_shapes)


_CACHE_SPECS = {
    # decode KV cache: batch->data, sequence->model (distributed softmax)
    "k": P(None, FSDP, TP, None, None),
    "v": P(None, FSDP, TP, None, None),
    "k_scale": P(None, FSDP, TP, None, None),
    "v_scale": P(None, FSDP, TP, None, None),
    # rwkv6: state heads -> model
    "state": P(None, FSDP, TP, None, None),
    "xp_tm": P(None, FSDP, None),
    "xp_cm": P(None, FSDP, None),
    # hymba mamba state: inner channels -> model
    "ssm": P(None, FSDP, TP, None),
    "conv": P(None, FSDP, None, TP),
}


def cache_shardings(cache_shapes, mesh):
    def visit(path, leaf):
        # keystr looks like "['k']" — take the last quoted dict key
        m = re.findall(r"'([^']+)'", jax.tree_util.keystr(path))
        key = m[-1] if m else ""
        spec = _CACHE_SPECS.get(key, P(*([None] * len(leaf.shape))))
        return NamedSharding(mesh, resolve_spec(spec, mesh, leaf.shape))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


def replicated(mesh):
    return NamedSharding(mesh, P())
