"""Cluster-health substrate: heartbeats, failure detection, recovery plans.

On a real 1000-node deployment this runs next to the training driver: every
host reports a heartbeat; the (replicated, deterministic) monitor declares
hosts dead after ``timeout`` missed beats, classifies stragglers from step-
time quantiles, and emits a recovery plan — which surviving mesh to re-mesh
onto (checkpoint restore handles the resharding, see
``checkpoint.Checkpointer.restore(shardings=...)``).

This container has one host, so the module is exercised by simulation in
tests — the logic (quantile straggler detection, largest-rectangle mesh
survivor selection) is the deployable part.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass
class RecoveryPlan:
    dead_hosts: List[int]
    straggler_hosts: List[int]
    action: str                 # "none" | "mitigate_stragglers" | "remesh"
    new_mesh_shape: Optional[Tuple[int, ...]] = None


class HealthMonitor:
    """Deterministic health tracking over host heartbeats + step timings."""

    def __init__(self, n_hosts: int, hosts_per_pod: int = 16,
                 timeout_s: float = 60.0, straggler_factor: float = 2.0,
                 window: int = 16, model_axis: int = 16):
        self.hosts = {i: HostState(i) for i in range(n_hosts)}
        self.hosts_per_pod = hosts_per_pod
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.window = window
        self.model_axis = model_axis

    def heartbeat(self, host_id: int, step_time_s: Optional[float] = None,
                  now: Optional[float] = None) -> None:
        h = self.hosts[host_id]
        h.last_beat = time.monotonic() if now is None else now
        h.alive = True
        if step_time_s is not None:
            h.step_times.append(step_time_s)
            del h.step_times[:-self.window]

    def _median_step(self) -> float:
        all_t = sorted(t for h in self.hosts.values() for t in h.step_times)
        return all_t[len(all_t) // 2] if all_t else 0.0

    def check(self, now: Optional[float] = None) -> RecoveryPlan:
        now = time.monotonic() if now is None else now
        dead, slow = [], []
        med = self._median_step()
        for h in self.hosts.values():
            if h.alive and now - h.last_beat > self.timeout_s:
                h.alive = False
            if not h.alive:
                dead.append(h.host_id)
                continue
            if (med > 0 and h.step_times
                    and h.step_times[-1] > self.straggler_factor * med):
                slow.append(h.host_id)
        if dead:
            return RecoveryPlan(dead, slow, "remesh",
                                self.survivor_mesh(dead))
        if slow:
            return RecoveryPlan(dead, slow, "mitigate_stragglers")
        return RecoveryPlan([], [], "none")

    def survivor_mesh(self, dead: Sequence[int]) -> Tuple[int, ...]:
        """Largest power-of-two data axis that the surviving host count
        supports, keeping the model axis (``model_axis``, the sharding
        degree the checkpoint was written for) intact — the elastic
        re-mesh target. E.g. 32 hosts (512 chips as (2,16,16)), one dead
        pod-half -> (16, 16) single-pod mesh."""
        alive = sum(1 for h in self.hosts.values() if h.alive
                    and h.host_id not in dead)
        chips = alive * self.hosts_per_pod
        model = self.model_axis
        data = 1
        while data * 2 * model <= chips:
            data *= 2
        return (data, model)


def backoff_delay(attempt: int, base_s: float = 0.05, factor: float = 2.0,
                  jitter: float = 0.25,
                  rng: Optional[np.random.Generator] = None) -> float:
    """Exponential backoff with seeded multiplicative jitter:
    ``base * factor**attempt * (1 ± jitter)``. Pass the caller's PRNG for
    deterministic jitter (thundering-herd spread that still replays
    bitwise in tests); no rng -> no jitter. Shared by ``run_with_retries``
    and the serving supervisor's replica-restart scheduling."""
    d = base_s * (factor ** max(0, int(attempt)))
    if jitter and rng is not None:
        d *= 1.0 + jitter * float(rng.uniform(-1.0, 1.0))
    return max(0.0, d)


def run_with_retries(fn, max_restarts: int = 3, on_restart=None,
                     retryable: Tuple[type, ...] = (TimeoutError, OSError),
                     backoff_base_s: float = 0.0, backoff_factor: float = 2.0,
                     backoff_jitter: float = 0.0, seed: int = 0,
                     sleep=time.sleep) -> Tuple[int, object]:
    """Driver-level restart wrapper: re-invokes ``fn(attempt)`` after
    recoverable failures (the checkpointed train_loop resumes itself).
    ``retryable`` configures which exception classes count as recoverable
    — anything else propagates immediately. ``backoff_base_s > 0`` turns
    on seeded exponential backoff between attempts (``backoff_delay``;
    ``sleep`` is injectable so tests use a virtual clock). Defaults keep
    the historical behavior: retry TimeoutError/OSError with no delay.
    Returns (attempts_used, result)."""
    rng = np.random.default_rng(seed)
    last_exc = None
    for attempt in range(max_restarts + 1):
        try:
            return attempt, fn(attempt)
        except retryable as e:
            last_exc = e
            if on_restart:
                on_restart(attempt, e)
            if attempt < max_restarts and backoff_base_s > 0:
                sleep(backoff_delay(attempt, backoff_base_s, backoff_factor,
                                    backoff_jitter, rng))
    raise RuntimeError(f"exhausted {max_restarts} restarts") from last_exc
