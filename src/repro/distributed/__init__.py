"""Distribution: sharding rules, roofline analysis, fault tolerance."""
