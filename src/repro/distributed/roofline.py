"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs            / (chips × peak_FLOP/s)
    memory     = HLO_bytes            / (chips × HBM_bw)
    collective = Σ wire_bytes(op)     / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed from the optimized HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the *result* shape
and model ring-algorithm wire traffic per participating device:

    all-gather          (n-1)/n × result_bytes
    all-reduce          2 (n-1)/n × result_bytes
    reduce-scatter      (n-1) × result_bytes          (operand = n × result)
    all-to-all          (n-1)/n × result_bytes
    collective-permute  result_bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the brief).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of one (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # per-device wire traffic (ring model)
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        # NB: use m.end() — the leading ^\s* of the pattern consumes the
        # previous newline, so slicing from m.start() would return "".
        eol = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():eol if eol != -1 else len(hlo_text)]
        if "-done(" in line:
            continue  # paired with -start; counted once
        rb = _shape_bytes(type_str)
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        if kind == "all-gather":
            wb = rb * (n - 1) / n
        elif kind == "all-reduce":
            wb = 2 * rb * (n - 1) / n
        elif kind == "reduce-scatter":
            wb = rb * (n - 1)
        elif kind == "all-to-all":
            wb = rb * (n - 1) / n
        else:  # collective-permute
            wb = rb
        stats.wire_bytes += wb
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wb
        stats.count += 1
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                 # total HLO flops (all devices)
    hbm_bytes: float             # total HLO bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    n_devices: int
    model_flops: float = 0.0     # 6·N·D useful flops
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    per_device_hbm: float = 0.0  # peak memory per device (memory_analysis)
    xla_flops: float = 0.0       # raw cost_analysis (scan bodies counted once)
    xla_bytes: float = 0.0
    min_bytes: float = 0.0       # irreducible HBM traffic (weights [+cache])

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_devices * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_devices * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def t_intrinsic(self) -> float:
        """Lower bound on step time from physics: useful FLOPs on the MXU
        vs. irreducible bytes (weights + KV cache for decode) through HBM —
        whichever is larger."""
        t_model = self.model_flops / (self.n_devices * PEAK_FLOPS)
        t_bytes = self.min_bytes / (self.n_devices * HBM_BW)
        return max(t_model, t_bytes)

    @property
    def roofline_fraction(self) -> float:
        """intrinsic step time / achieved (bound) step time. 1.0 = at the
        roofline. For compute-bound training this is MFU-like; for memory-
        bound decode it is the achieved-bandwidth fraction."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_intrinsic / t_bound if t_bound else 0.0

    def to_dict(self) -> dict:
        return dict(
            flops=self.flops, hbm_bytes=self.hbm_bytes,
            wire_bytes=self.wire_bytes, n_devices=self.n_devices,
            model_flops=self.model_flops,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
            coll_by_kind=self.coll_by_kind, coll_count=self.coll_count,
            per_device_hbm=self.per_device_hbm,
            xla_flops=self.xla_flops, xla_bytes=self.xla_bytes,
            min_bytes=self.min_bytes, t_intrinsic=self.t_intrinsic,
        )


def analyze(compiled, n_devices: int, model_flops: float = 0.0,
            cfg=None, shape=None, quantized: bool = False) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    # XLA:CPU reports per-program flops; bytes accessed similarly.
    hbm = float(cost.get("bytes accessed", 0.0))
    xla_flops, xla_bytes = flops, hbm
    minb = 0.0
    if cfg is not None and shape is not None:
        # CPU cost_analysis counts scan bodies once — use the analytic model
        # (see module docstring) and keep the XLA numbers for reference.
        flops = analytic_flops(cfg, shape, quantized)
        hbm = analytic_hbm_bytes(cfg, shape, quantized)
        minb = min_hbm_bytes(cfg, shape, quantized)
    text = compiled.as_text()
    coll = collective_stats(text, n_devices)
    per_dev = 0.0
    try:
        ma = compiled.memory_analysis()
        per_dev = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )
    except Exception:
        pass
    r = Roofline(
        flops=flops, hbm_bytes=hbm, wire_bytes=coll.wire_bytes,
        n_devices=n_devices, model_flops=model_flops,
        coll_by_kind=coll.by_kind, coll_count=coll.count,
        per_device_hbm=per_dev,
    )
    r.xla_flops = xla_flops
    r.xla_bytes = xla_bytes
    r.min_bytes = minb
    return r


# ---------------------------------------------------------------------------
# Analytic cost model.
#
# XLA:CPU's cost_analysis() counts a lax.scan/while body ONCE (verified:
# qwen3-4b train_4k reports 4.0e12 flops where the true count is ~2.6e19),
# so on this CPU-only container the compute and memory roofline terms come
# from the analytic model below (structure-exact: matmul/attention/ssm flops
# per layer × layers × tokens; bytes from params/activations/cache traffic).
# The *collective* term and the optimization profile (gather/reshard
# patterns, remat duplicates) still come from the compiled HLO, which is
# shape-faithful. cost_analysis values are reported alongside for
# transparency.
# ---------------------------------------------------------------------------

def analytic_flops(cfg, shape, quantized: bool = False,
                   include_remat: bool = True) -> float:
    """Structure-exact FLOPs for one step of this cell (all devices).
    ``include_remat=False`` gives the *useful* count (fwd + bwd only) used
    as MODEL_FLOPS; the default adds the remat re-forward overhead."""
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    qd, kvd, hd, H = cfg.q_dim, cfg.kv_dim, cfg.head_dim, cfg.n_heads
    tokens = B * S

    def attn_ctx(s_q, s_ctx, layer_frac_local=None):
        """attention score+value flops for one layer."""
        if cfg.local_window and kind != "decode":
            w = min(cfg.local_window, s_ctx)
            if cfg.global_every:  # gemma2: half local, half global
                ctx = 0.5 * w + 0.5 * s_ctx * 0.5  # causal halves global
                return 2 * 2 * B * H * s_q * ctx * hd
            if cfg.global_layers:  # hymba: few global layers
                ng = len(cfg.global_layers)
                frac_g = ng / L
                ctx = (1 - frac_g) * w + frac_g * s_ctx * 0.5
                return 2 * 2 * B * H * s_q * ctx * hd
        causal_frac = 0.5 if (kind != "decode" and not cfg.is_encoder) else 1.0
        return 2 * 2 * B * H * s_q * s_ctx * causal_frac * hd

    # per-token matmul flops in one layer
    if cfg.family in ("dense", "moe", "encoder"):
        attn_proj = 2 * (D * qd + 2 * D * kvd + qd * D)
        if cfg.family == "moe":
            ffn = 2 * (cfg.topk * 3 * D * F + D * cfg.n_experts)
        elif cfg.family == "encoder":
            ffn = 2 * 2 * D * F
        else:
            ffn = 2 * 3 * D * F
        per_tok_layer = attn_proj + ffn
    elif cfg.family == "rwkv6":
        tm = 2 * 5 * D * D + 2 * 2 * D * 64          # 5 proj + decay lora
        wkv = 3 * 2 * D * cfg.rwkv_head_dim          # state update + readout
        cm = 2 * (D * F + F * D + D * D)
        per_tok_layer = tm + wkv + cm
    elif cfg.family == "hymba":
        Di, N = cfg.d_inner_resolved, cfg.ssm_state
        attn_proj = 2 * (D * qd + 2 * D * kvd + qd * D)
        mamba = 2 * (D * 2 * Di + Di * Di + 2 * Di * N + Di * D) + 8 * Di * N
        mlp = 2 * 3 * D * F
        per_tok_layer = attn_proj + mamba + mlp
    else:
        raise ValueError(cfg.family)

    unembed = 2 * D * V

    if kind == "train":
        fwd = tokens * (L * per_tok_layer + unembed)
        if cfg.family in ("dense", "moe", "encoder", "hymba"):
            fwd += L * attn_ctx(S, S)
        remat_factor = 0.0
        if cfg.remat and include_remat:
            remat_factor = {"full": 1.0, "dots": 0.33, "none": 0.0}.get(
                getattr(cfg, "remat_policy", "full"), 1.0)
        return fwd * (3.0 + remat_factor)
    if kind == "prefill":
        fwd = tokens * (L * per_tok_layer + unembed)
        if cfg.family in ("dense", "moe", "encoder", "hymba"):
            fwd += L * attn_ctx(S, S)
        return fwd
    # decode: 1 token per sequence, attention over the full cache
    fwd = B * (L * per_tok_layer + unembed)
    if cfg.family in ("dense", "moe", "hymba"):
        fwd += L * attn_ctx(1, S)
    return fwd


def analytic_hbm_bytes(cfg, shape, quantized: bool = False,
                       weight_bits: float = 16.0) -> float:
    """HBM traffic for one step (all devices). Activation traffic uses a
    per-layer tensor-count coefficient (≈12 activation r/w per layer)."""
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    L, D = cfg.n_layers, cfg.d_model
    tokens = B * S
    n_params = cfg.param_count()
    wbytes = weight_bits / 8.0
    if quantized:
        wbytes = (cfg_quant_bits(cfg) / 8.0)
    p_bytes = n_params * wbytes
    act_coeff = 12.0
    act_bytes = tokens * L * act_coeff * D * 2.0  # bf16 activations
    cache_bytes = 0.0
    kvb = getattr(cfg, "kv_cache_bits", 16) / 8.0
    if cfg.family in ("dense", "moe", "hymba"):
        cache_bytes = 2 * L * B * S * cfg.kv_dim * kvb
    elif cfg.family == "rwkv6":
        cache_bytes = L * B * D * cfg.rwkv_head_dim * 2.0

    if kind == "train":
        # fwd read + remat read + bwd read of params; grads + 2 moments rw in f32
        opt = n_params * 4.0 * 6.0
        return 3 * p_bytes + opt + 3 * act_bytes
    if kind == "prefill":
        return p_bytes + act_bytes + cache_bytes  # cache written once
    # decode: every step streams all weights + the whole cache + tiny acts
    return p_bytes + cache_bytes + B * L * act_coeff * D * 2.0


def cfg_quant_bits(cfg) -> float:
    """Effective bits/weight under FLRQ W4 defaults (4b codes + group scales
    + ~0.2 extra bits of low-rank factors, paper Tables 3/19)."""
    return 4.0 + 0.32 + 0.2


def min_hbm_bytes(cfg, shape, quantized: bool = False) -> float:
    """Irreducible per-step HBM traffic: every weight byte must be read once
    (at serving precision) and — for decode — the whole KV/SSM cache too."""
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    wbytes = (cfg_quant_bits(cfg) if quantized else 16.0) / 8.0
    p_bytes = cfg.param_count() * wbytes
    if kind == "train":
        return 3 * p_bytes + cfg.param_count() * 4.0 * 6.0
    if kind == "prefill":
        return p_bytes
    cache = 0.0
    kv_bytes = getattr(cfg, "kv_cache_bits", 16) / 8.0
    if cfg.family in ("dense", "moe", "hymba"):
        cache = 2 * cfg.n_layers * B * S * cfg.kv_dim * kv_bytes
        if kv_bytes < 2.0:
            cache *= 1.0 + 1.0 / cfg.head_dim  # per-entry scales
    elif cfg.family == "rwkv6":
        cache = cfg.n_layers * B * cfg.d_model * cfg.rwkv_head_dim * 2.0
    return p_bytes + cache


# ---------------------------------------------------------------------------
# Useful-FLOPs models (MODEL_FLOPS = 6·N·D for training; 2·N·D for one
# forward; decode: 2·N_active per token)
# ---------------------------------------------------------------------------

def model_flops_for(cfg, shape) -> float:
    """Useful FLOPs: structure-exact forward(+backward for train) including
    attention score/value work, EXCLUDING remat recompute. For dense LMs
    this reduces to ~6·N·D (train) / 2·N·D (prefill) + attention."""
    return analytic_flops(cfg, shape, include_remat=False)
