"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape)`` returns the kwargs for the lowered function of
that cell:
    train   -> {"batch": {...}}                  for train_step(state, batch)
    prefill -> {"tokens": (B, S) int32}
    decode  -> {"tokens": (B,), "cache": {...}, "length": ()}
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import ShapeSpec, get_config
from ..models import LM
from ..models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict[str, Any]:
    b, s = spec.global_batch, spec.seq_len
    if cfg.family == "encoder":
        return {
            "frames": SDS((b, s, cfg.d_model), cfg.dtype),
            "labels": SDS((b, s), jnp.int32),
            "mask": SDS((b, s), jnp.bool_),
        }
    return {"tokens": SDS((b, s), jnp.int32)}


def prefill_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict[str, Any]:
    b, s = spec.global_batch, spec.seq_len
    if cfg.family == "encoder":
        # encoder "prefill" = full forward over precomputed frame embeddings
        return {"frames": SDS((b, s, cfg.d_model), cfg.dtype)}
    return {"tokens": SDS((b, s), jnp.int32)}


def decode_specs(cfg: ModelConfig, spec: ShapeSpec) -> Dict[str, Any]:
    b, s = spec.global_batch, spec.seq_len
    model = LM(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "tokens": SDS((b,), jnp.int32),
        "cache": cache,
        "length": SDS((), jnp.int32),
    }


def input_specs(arch: str, shape: ShapeSpec) -> Dict[str, Any]:
    cfg = get_config(arch)
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_specs(cfg, shape)}
    return decode_specs(cfg, shape)
