"""Production mesh construction.

Single pod : (data=16, model=16)              — 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)       — 512 chips

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D (data,) mesh — used by the
    CPU training example and tests."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_quant_mesh(n_shards: int = 0):
    """1-D ("stack",) mesh for the offline quantizer: the batched engine
    shard_maps the stacked-layer dim over it. ``n_shards=0`` takes every
    local device; quantization is embarrassingly parallel over layers, so
    there is no reason to leave chips idle."""
    avail = len(jax.devices())
    n = n_shards or avail
    if n > avail:
        raise ValueError(f"asked for {n} quant shards, only {avail} devices")
    return jax.make_mesh((n,), ("stack",))


def mesh_context(mesh):
    """Activate ``mesh`` as the ambient mesh, across jax API generations:
    jax.set_mesh (new) → jax.sharding.use_mesh → Mesh-as-context-manager
    (0.4.x: ``with mesh:`` sets the thread-local physical mesh)."""
    setter = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None)
    return setter(mesh) if setter is not None else mesh


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh):
    """Axes batch shards over: ('pod','data') when pod exists, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
