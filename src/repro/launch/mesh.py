"""Production mesh construction.

Single pod : (data=16, model=16)              — 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)       — 512 chips

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D (data,) mesh — used by the
    CPU training example and tests."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh):
    """Axes batch shards over: ('pod','data') when pod exists, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
