"""Offline quantization CLI: checkpoint in → FLRQ-quantized checkpoint out.

    PYTHONPATH=src python -m repro.launch.quantize \
        --ckpt-dir /tmp/run1 --out-dir /tmp/run1-w4 \
        --arch opt-proxy-25m --bits 4 [--smoke] [--calib-segments 64]

Loads the latest training checkpoint, runs the paper's pipeline (scaling →
R1-FLR → BLC → pack) per stacked matrix with calibration activations from
the synthetic corpus, writes a serving checkpoint of QuantizedLinear
leaves, and prints the per-layer rank/error report (paper Tables 3/9).

Scale-out: ``--mesh-shards N`` shard_maps every stacked tensor's layer dim
over an N-device ("stack",) mesh (bit-identical results, pod-speed wall
time); same-shape stacks fuse into single launches unless ``--no-fuse``;
``--layer-chunk K`` bounds the engine's transient f32 residuals at
(K, m, n) for production widths; ``--clip-backend pallas|auto`` runs the
BLC clip-grid sweep as one fused Pallas pass over each weight stack.
The jitted while_loop programs compile slowly cold (~19s for the vmapped
engine on the tiny proxy) — a persistent compilation cache is on by
default at ``~/.cache/repro-flrq-xla`` (``--compile-cache DIR`` /
``--no-compile-cache``), cutting repeat runs to cache-hit latency.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs import get_config, get_smoke_config
from ..core.flrq import FLRQConfig
from ..data.pipeline import DataConfig, SyntheticCorpus, collect_layer_activations
from ..models import LM
from ..quant.stacked import quantize_model_stacked
from ..train.step import init_train_state
from .mesh import make_quant_mesh

DEFAULT_COMPILE_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro-flrq-xla")


def enable_compilation_cache(cache_dir: str) -> bool:
    """Point jax's persistent compilation cache at ``cache_dir``. Returns
    False (instead of raising) on jax builds without the config knobs —
    the quantizer must run, just colder."""
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # The offline quantizer's big programs are exactly the ones worth
        # caching; don't let the min-compile-time heuristic skip them.
        for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.5),
                          ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(knob, val)
            except AttributeError:
                pass
        return True
    except (AttributeError, OSError) as e:
        print(f"compilation cache unavailable ({e}); continuing without")
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="training checkpoint dir (default: random init)")
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--x-budget", type=float, default=0.2)
    ap.add_argument("--max-rank", type=int, default=48)
    ap.add_argument("--blc-epochs", type=int, default=0,
                    help="0 = paper defaults (1 at 3/4-bit, 20 at 2-bit)")
    ap.add_argument("--calib-segments", type=int, default=32)
    ap.add_argument("--no-scaling", action="store_true")
    ap.add_argument("--engine", choices=("batched", "sequential"),
                    default="batched",
                    help="batched = one jitted program per stacked tensor "
                         "(default); sequential = per-layer reference "
                         "oracle (same algorithm, per-peel host syncs)")
    ap.add_argument("--backend", choices=("xla", "pallas", "auto"),
                    default="xla",
                    help="sketch backend (default xla; the Pallas kernels "
                         "are interpret-verified on CPU but not yet "
                         "validated on real TPU — opt in with auto/pallas)")
    ap.add_argument("--clip-backend", choices=("xla", "pallas", "auto"),
                    default="xla",
                    help="BLC clip-grid sweep backend: xla = hoisted "
                         "group-stats path; pallas = one-pass fused sweep "
                         "kernel (whole grid from one HBM read of W; "
                         "interpret mode off-TPU); auto = pallas on TPU "
                         "when the config tiles, else xla")
    ap.add_argument("--layer-chunk", type=int, default=0,
                    help="quantize each stacked tensor in lane chunks of "
                         "this size (0 = whole stack per launch) — bounds "
                         "the engine's transient f32 residuals at "
                         "(chunk, m, n) with bit-identical results; the "
                         "production-shape memory lever")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard the stacked-layer dim over this many devices "
                         "(0 = single-device; results are bit-identical)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable same-shape stack fusion (one launch per "
                         "stacked tensor instead of per shape group)")
    ap.add_argument("--compile-cache", default=DEFAULT_COMPILE_CACHE,
                    help="persistent XLA compilation cache dir "
                         f"(default {DEFAULT_COMPILE_CACHE})")
    ap.add_argument("--no-compile-cache", action="store_true")
    args = ap.parse_args(argv)

    if not args.no_compile_cache:
        enable_compilation_cache(args.compile_cache)

    mesh = None
    if args.mesh_shards:
        mesh = make_quant_mesh(args.mesh_shards)
        print(f"sharding stacks over {args.mesh_shards} devices")

    def place_params(params):
        """Lane-shard the weight stacks over the quant mesh up front so no
        device holds a full-model tensor before quantization starts."""
        if mesh is None:
            return params
        from ..distributed.sharding import stack_lane_shardings
        return jax.device_put(params, stack_lane_shardings(mesh, "stack",
                                                           params))

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    key = jax.random.PRNGKey(0)

    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        state_like = jax.eval_shape(lambda k: init_train_state(model, k), key)
        state, step = ck.restore(state_like)
        params = place_params(state.params)
        print(f"loaded checkpoint step {step} from {args.ckpt_dir}")
    else:
        params = place_params(model.init(key))
        print("no checkpoint given — quantizing a fresh init (demo mode)")

    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=256,
                                      global_batch=4))
    calib = data.calibration_batch(n_segments=args.calib_segments,
                                   seq_len=256)
    acts = collect_layer_activations(model, params, calib)

    qcfg = FLRQConfig(
        bits=args.bits, x=args.x_budget, max_rank=args.max_rank,
        blc_epochs=args.blc_epochs or (1 if args.bits > 2 else 20),
        use_scaling=not args.no_scaling, backend=args.backend,
        clip_backend=args.clip_backend,
    )
    t0 = time.time()
    qparams, stats = quantize_model_stacked(
        params, acts, qcfg, engine=args.engine,
        mesh=mesh, fuse_stacks=not args.no_fuse,
        layer_chunk=args.layer_chunk or None,
        progress=lambda name, st: print(
            f"  {name}: rank={st.rank} err {st.err_before:.4f}->"
            f"{st.err_after:.4f} ({st.seconds:.1f}s)"))
    dt = time.time() - t0

    ranks = [s.rank for v in stats.values() for s in v]
    errs_b = [s.err_before for v in stats.values() for s in v]
    errs_a = [s.err_after for v in stats.values() for s in v]
    nbytes = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    print(f"\nquantized {len(ranks)} matrices in {dt:.1f}s | "
          f"avg rank {np.mean(ranks):.1f} | "
          f"mean err {np.mean(errs_b):.4f} -> {np.mean(errs_a):.4f} | "
          f"{nbytes(params)/1e6:.1f}MB -> {nbytes(qparams)/1e6:.1f}MB")

    out = Checkpointer(args.out_dir, keep=1)
    out.save(0, {"params": qparams}, blocking=True)
    print(f"wrote quantized serving checkpoint to {args.out_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
