"""Offline quantization CLI: checkpoint in → FLRQ-quantized checkpoint out.

    PYTHONPATH=src python -m repro.launch.quantize \
        --ckpt-dir /tmp/run1 --out-dir /tmp/run1-w4 \
        --arch opt-proxy-25m --bits 4 [--smoke] [--calib-segments 64]

Loads the latest training checkpoint, runs the paper's pipeline (scaling →
R1-FLR → BLC → pack) per stacked matrix with calibration activations from
the synthetic corpus, writes a serving checkpoint of QuantizedLinear
leaves, and prints the per-layer rank/error report (paper Tables 3/9).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs import get_config, get_smoke_config
from ..core.flrq import FLRQConfig
from ..data.pipeline import DataConfig, SyntheticCorpus, collect_layer_activations
from ..models import LM
from ..quant.stacked import quantize_model_stacked
from ..train.step import init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="training checkpoint dir (default: random init)")
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--x-budget", type=float, default=0.2)
    ap.add_argument("--max-rank", type=int, default=48)
    ap.add_argument("--blc-epochs", type=int, default=0,
                    help="0 = paper defaults (1 at 3/4-bit, 20 at 2-bit)")
    ap.add_argument("--calib-segments", type=int, default=32)
    ap.add_argument("--no-scaling", action="store_true")
    ap.add_argument("--engine", choices=("batched", "sequential"),
                    default="batched",
                    help="batched = one jitted program per stacked tensor "
                         "(default); sequential = per-layer reference "
                         "oracle (same algorithm, per-peel host syncs)")
    ap.add_argument("--backend", choices=("xla", "pallas", "auto"),
                    default="xla",
                    help="sketch backend (default xla; the Pallas kernels "
                         "are interpret-verified on CPU but not yet "
                         "validated on real TPU — opt in with auto/pallas)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    key = jax.random.PRNGKey(0)

    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        state_like = jax.eval_shape(lambda k: init_train_state(model, k), key)
        state, step = ck.restore(state_like)
        params = state.params
        print(f"loaded checkpoint step {step} from {args.ckpt_dir}")
    else:
        params = model.init(key)
        print("no checkpoint given — quantizing a fresh init (demo mode)")

    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=256,
                                      global_batch=4))
    calib = data.calibration_batch(n_segments=args.calib_segments,
                                   seq_len=256)
    acts = collect_layer_activations(model, params, calib)

    qcfg = FLRQConfig(
        bits=args.bits, x=args.x_budget, max_rank=args.max_rank,
        blc_epochs=args.blc_epochs or (1 if args.bits > 2 else 20),
        use_scaling=not args.no_scaling, backend=args.backend,
    )
    t0 = time.time()
    qparams, stats = quantize_model_stacked(
        params, acts, qcfg, engine=args.engine,
        progress=lambda name, st: print(
            f"  {name}: rank={st.rank} err {st.err_before:.4f}->"
            f"{st.err_after:.4f} ({st.seconds:.1f}s)"))
    dt = time.time() - t0

    ranks = [s.rank for v in stats.values() for s in v]
    errs_b = [s.err_before for v in stats.values() for s in v]
    errs_a = [s.err_after for v in stats.values() for s in v]
    nbytes = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    print(f"\nquantized {len(ranks)} matrices in {dt:.1f}s | "
          f"avg rank {np.mean(ranks):.1f} | "
          f"mean err {np.mean(errs_b):.4f} -> {np.mean(errs_a):.4f} | "
          f"{nbytes(params)/1e6:.1f}MB -> {nbytes(qparams)/1e6:.1f}MB")

    out = Checkpointer(args.out_dir, keep=1)
    out.save(0, {"params": qparams}, blocking=True)
    print(f"wrote quantized serving checkpoint to {args.out_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
