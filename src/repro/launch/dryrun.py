"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, print memory/cost analysis, and dump the
roofline terms to JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun.json

Every failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system, not in the harness.
"""
import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# (no `from __future__ import annotations` here — it would have to precede
# the os.environ lines, which must stay first.)

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, cell_status, get_config
from ..distributed import roofline as rl
from ..distributed import sharding
from ..models import LM
from ..train.optimizer import AdamWConfig
from ..core.flrq import FLRQConfig
from ..quant.stacked import abstract_quantized_params
from ..train.step import TrainState, make_train_step, train_state_shapes
from .mesh import make_production_mesh, make_quant_mesh, mesh_context
from .specs import decode_specs, prefill_specs, train_batch_specs

SDS = jax.ShapeDtypeStruct

# Back-compat alias: the mesh-activation shim now lives in launch.mesh so
# the quantizer CLI shares it.
_mesh_context = mesh_context


def _state_shardings(model, mesh, state_shapes):
    p_sh = sharding.param_shardings(model.cfg, state_shapes.params, mesh)
    rep = sharding.replicated(mesh)
    return TrainState(
        params=p_sh,
        opt=type(state_shapes.opt)(
            step=rep,
            mu=sharding.param_shardings(model.cfg, state_shapes.opt.mu, mesh),
            nu=sharding.param_shardings(model.cfg, state_shapes.opt.nu, mesh),
        ),
    )


def apply_opts(cfg, opts: tuple):
    """Apply beyond-paper perf levers to an arch config."""
    if "grouped_decode" in opts:
        cfg = dataclasses.replace(cfg, grouped_decode_attn=True)
    if "grouped_moe" in opts and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_impl="grouped")
    if "expert_parallel" in opts and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, expert_parallel=True)
    if "remat_dots" in opts:
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    if "kv_int8" in opts:
        cfg = dataclasses.replace(cfg, kv_cache_bits=8)
    return cfg


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               model_override: Optional[LM] = None, mesh=None,
               microbatches: int = 1, quantized: bool = False,
               opts: tuple = ()):
    """Build + lower one cell. Returns (lowered, n_devices, model_flops).

    ``opts`` — beyond-paper perf levers (see EXPERIMENTS.md §Perf):
      grouped_decode — GQA decode without repeat_kv
      tp_serving     — TP-only weight layout for serving cells
      bf16_grads     — bf16 gradient accumulation/communication
    """
    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    ok, why = cell_status(cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped: {why}")
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = model_override or LM(cfg)
    sharding.install(mesh)
    key = jax.random.PRNGKey(0)
    tp_serving = "tp_serving" in opts

    with _mesh_context(mesh):
        if shape.kind == "train":
            state_shapes = train_state_shapes(model, key)
            st_sh = _state_shardings(model, mesh, state_shapes)
            batch = train_batch_specs(cfg, shape)
            b_sh = sharding.batch_spec(batch, mesh)
            step = make_train_step(model, AdamWConfig(),
                                   microbatches=microbatches,
                                   grad_shardings=st_sh.params,
                                   compress="bf16" if "bf16_grads" in opts
                                   else "none")
            lowered = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, sharding.replicated(mesh)),
                donate_argnums=(0,),  # state buffers update in place
            ).lower(state_shapes, batch)
        elif shape.kind == "prefill":
            p_shapes = jax.eval_shape(model.init, key)
            if quantized:
                p_shapes = abstract_quantized_params(p_shapes, FLRQConfig(bits=4))
            p_sh = sharding.param_shardings(cfg, p_shapes, mesh,
                                            serving_tp_only=tp_serving)
            batch = prefill_specs(cfg, shape)
            b_sh = sharding.batch_spec(batch, mesh)
            if cfg.family == "encoder":
                def fwd(params, frames):
                    x = frames.astype(cfg.dtype)
                    h = model.stack.apply_train(
                        params["layers"], x,
                        model._positions(frames.shape[0], frames.shape[1]))
                    return model._logits_last(params, h[:, -1:])

                lowered = jax.jit(
                    fwd, in_shardings=(p_sh, b_sh["frames"]),
                ).lower(p_shapes, batch["frames"])
            else:
                lowered = jax.jit(
                    model.prefill, in_shardings=(p_sh, b_sh["tokens"]),
                ).lower(p_shapes, batch["tokens"])
        else:  # decode
            p_shapes = jax.eval_shape(model.init, key)
            if quantized:
                p_shapes = abstract_quantized_params(p_shapes, FLRQConfig(bits=4))
            p_sh = sharding.param_shardings(cfg, p_shapes, mesh,
                                            serving_tp_only=tp_serving)
            specs = decode_specs(cfg, shape)
            c_sh = sharding.cache_shardings(specs["cache"], mesh)
            t_sh = sharding.batch_spec({"t": specs["tokens"]}, mesh)["t"]
            rep = sharding.replicated(mesh)
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, t_sh, c_sh, rep),
                out_shardings=(rep, c_sh),
                donate_argnums=(2,),  # KV cache updates in place
            ).lower(p_shapes, specs["tokens"], specs["cache"], specs["length"])

    mflops = rl.model_flops_for(cfg, shape)
    return lowered, n_dev, mflops


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, microbatches: int = 1,
             quantized: bool = False, opts: tuple = (),
             mesh_override: str = None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    ok, why = cell_status(cfg, shape)
    row: Dict[str, Any] = dict(
        arch=arch, shape=shape_name, multi_pod=multi_pod, status="SKIP",
        reason=why, quantized=quantized, opts=list(opts),
    )
    if quantized and shape.kind == "train":
        row["reason"] = "quantized cells are serving-only (PTQ)"
        return row
    if not ok:
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} "
                  f"({'2x16x16' if multi_pod else '16x16'}): SKIP — {why}")
        return row
    try:
        mesh = None
        if mesh_override:
            from .mesh import make_mesh
            d, m = (int(x) for x in mesh_override.split("x"))
            mesh = make_mesh((d, m), ("data", "model"))
        lowered, n_dev, mflops = lower_cell(arch, shape_name, multi_pod,
                                            microbatches=microbatches,
                                            quantized=quantized, opts=opts,
                                            mesh=mesh)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        roof = rl.analyze(compiled, n_dev, mflops, cfg=cfg, shape=shape,
                          quantized=quantized)
        row.update(
            status="OK",
            microbatches=microbatches,
            seconds=round(time.time() - t0, 1),
            memory=dict(
                argument=getattr(mem, "argument_size_in_bytes", 0),
                output=getattr(mem, "output_size_in_bytes", 0),
                temp=getattr(mem, "temp_size_in_bytes", 0),
                generated_code=getattr(mem, "generated_code_size_in_bytes", 0),
            ),
            roofline=roof.to_dict(),
        )
        if verbose:
            m = row["memory"]
            print(f"[dryrun] {arch} × {shape_name} "
                  f"({'2x16x16' if multi_pod else '16x16'}): OK "
                  f"{row['seconds']}s  "
                  f"args={m['argument']/1e9:.2f}GB temp={m['temp']/1e9:.2f}GB  "
                  f"t_comp={roof.t_compute*1e3:.1f}ms "
                  f"t_mem={roof.t_memory*1e3:.1f}ms "
                  f"t_coll={roof.t_collective*1e3:.1f}ms "
                  f"bound={roof.bottleneck} "
                  f"roofline_frac={roof.roofline_fraction:.3f}")
    except Exception as e:  # failures are bugs — surface them loudly
        row.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   seconds=round(time.time() - t0, 1))
        if verbose:
            print(f"[dryrun] {arch} × {shape_name}: FAIL — {e}")
    return row


def run_quant_engine_cell(shards: int = 8, layers: int = 16, m: int = 512,
                          n: int = 512, bits: int = 4,
                          verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile the mesh-sharded stack quantizer on ``shards`` forced
    host devices and report its memory analysis — the offline-quantizer
    analogue of the serving/training cells: any sharding mismatch in the
    shard_map program is a bug surfaced here before it costs pod time."""
    import jax.numpy as jnp
    from ..core.flrq import _quantize_stack_sharded

    t0 = time.time()
    row: Dict[str, Any] = dict(kind="quant_engine", shards=shards,
                               layers=layers, shape=[m, n], bits=bits)
    try:
        mesh = make_quant_mesh(shards)
        cfg = FLRQConfig(bits=bits, max_rank=32, blc_epochs=1)
        l_pad = -(-layers // shards) * shards
        w = SDS((l_pad, m, n), jnp.float32)
        xt = SDS((64, n), jnp.float32)
        keys = SDS((l_pad, 2), jnp.uint32)
        mask = SDS((l_pad,), jnp.bool_)
        lowered = _quantize_stack_sharded.lower(
            w, xt, keys, mask, cfg, True, True, mesh, "stack")
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        row.update(
            status="OK", seconds=round(time.time() - t0, 1),
            memory=dict(
                argument=getattr(mem, "argument_size_in_bytes", 0),
                output=getattr(mem, "output_size_in_bytes", 0),
                temp=getattr(mem, "temp_size_in_bytes", 0),
            ))
        if verbose:
            mm = row["memory"]
            print(f"[dryrun] quant_engine ({shards} shards × "
                  f"{l_pad // shards} layers of {m}x{n}): OK "
                  f"{row['seconds']}s  args={mm['argument']/1e6:.1f}MB "
                  f"temp={mm['temp']/1e6:.1f}MB")
    except Exception as e:
        row.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   seconds=round(time.time() - t0, 1))
        if verbose:
            print(f"[dryrun] quant_engine: FAIL — {e}")
    return row


def _row_key(r: Dict[str, Any]):
    """Merge key for a results row — tolerant of both cell rows and
    quant-engine rows (missing fields → None; list-valued shape → tuple)
    so the two kinds can share one --out file."""
    shape = r.get("shape")
    if isinstance(shape, list):
        shape = tuple(shape)
    return (r.get("kind", "cell"), r.get("arch"), shape,
            r.get("multi_pod"), r.get("quantized", False),
            tuple(r.get("opts", [])), r.get("shards"), r.get("layers"))


def _merge_out(out_path: str, rows) -> None:
    """Merge rows into the JSON results file keyed by _row_key (re-runs of
    the same cell replace; everything else — including rows of the other
    kind — is preserved)."""
    import pathlib
    p = pathlib.Path(out_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    existing = []
    if p.exists():
        existing = json.loads(p.read_text())
    merged = {_row_key(r): r for r in existing}
    merged.update({_row_key(r): r for r in rows})
    p.write_text(json.dumps(list(merged.values()), indent=1))
    print(f"[dryrun] wrote {p}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quantized", action="store_true",
                    help="FLRQ-W4 weights for serving cells (the paper's "
                         "technique at production scale)")
    ap.add_argument("--mesh", default=None,
                    help="override mesh as DATAxMODEL, e.g. 4x4 (right-"
                         "sizing experiments; default: production mesh)")
    ap.add_argument("--opt", action="append", default=[],
                    choices=["grouped_decode", "tp_serving", "bf16_grads",
                             "grouped_moe", "expert_parallel", "remat_dots",
                             "kv_int8"],
                    help="beyond-paper perf levers (repeatable)")
    ap.add_argument("--quant-engine", action="store_true",
                    help="lower the mesh-sharded offline quantizer instead "
                         "of model cells")
    ap.add_argument("--quant-shards", type=int, default=8)
    ap.add_argument("--quant-layers", type=int, default=16)
    ap.add_argument("--quant-dim", type=int, default=512)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.quant_engine:
        row = run_quant_engine_cell(args.quant_shards, args.quant_layers,
                                    args.quant_dim, args.quant_dim)
        if args.out:
            _merge_out(args.out, [row])
        return 1 if row["status"] != "OK" else 0

    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                cells.append((a, s))

    rows = []
    for mp in pods:
        for a, s in cells:
            rows.append(run_cell(a, s, mp, microbatches=args.microbatches,
                                 quantized=args.quantized,
                                 opts=tuple(args.opt),
                                 mesh_override=args.mesh))

    n_fail = sum(r["status"] == "FAIL" for r in rows)
    n_ok = sum(r["status"] == "OK" for r in rows)
    n_skip = sum(r["status"] == "SKIP" for r in rows)
    print(f"[dryrun] done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    if args.out:
        _merge_out(args.out, rows)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
