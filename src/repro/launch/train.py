"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama-proxy-100m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1 [--resume] \
        [--microbatches 2] [--compress bf16] [--smoke]

On this CPU container it trains the proxy/smoke configs for real; on a TPU
pod the same entry point runs the full configs under
``make_production_mesh()`` (pass --production-mesh; requires real devices).
Fault tolerance: checkpoints every --ckpt-every steps, resumes from the
latest complete checkpoint automatically, SIGTERM-safe.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..checkpoint.checkpointer import Checkpointer
from ..configs import ARCHS, PAPER_PROXIES, get_config, get_smoke_config
from ..data.pipeline import DataConfig, SyntheticCorpus
from ..distributed import sharding
from ..models import LM
from ..train.loop import LoopConfig, train_loop
from ..train.optimizer import AdamWConfig
from ..train.step import init_train_state, make_train_step
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    sharding.install(mesh)

    data = SyntheticCorpus(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    step = jax.jit(make_train_step(
        model, opt, microbatches=args.microbatches, compress=args.compress,
        dp_size=mesh.devices.size))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    res = train_loop(
        step, state,
        lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()},
        ckpt,
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   log_every=max(args.steps // 20, 1)),
        on_metrics=lambda s, m: print(
            f"step {s}: loss={m['loss']:.4f} "
            f"gnorm={m['grad_norm']:.2f} {m['step_time_s']*1e3:.0f}ms"),
    )
    print(f"done at step {res.final_step} "
          f"(resumed_from={res.resumed_from}, preempted={res.preempted})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
