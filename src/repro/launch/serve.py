"""Serving launcher: batched generation with optional FLRQ quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --quantize 4 --requests 8 --new-tokens 16 --backend auto \
        --scheduler continuous --prefill-chunk 32 --poisson-rate 50

``--backend`` selects the quantized-matmul execution path (see
``quant.apply``): "ref" (pure jnp), "fused" (Pallas kernel; interpret mode
off-TPU), or "auto" (kernel on TPU when supported, ref elsewhere). The
dispatch report shows which path each tensor config actually took —
bits=3 and other kernel-unsupported configs fall back to ref *visibly*;
under the continuous scheduler it is flushed at every queue drain, so a
long-running serve surfaces fused→ref fallbacks without waiting for the
end. ``--no-scan`` unrolls the layer stack (L per-layer dispatches per
step) instead of the default single scanned layer body.

``--scheduler continuous`` serves through the continuous-batching
scheduler (per-slot admission, chunked prefill of ``--prefill-chunk``
tokens per step, immediate slot retirement); ``--poisson-rate R`` replays
a Poisson arrival process at R requests/s (0 = all requests at t=0) and
``--mixed-lengths`` draws prompt lengths uniformly from
[prompt_len/4, prompt_len] — the mixed-length workload where continuous
batching beats the chunked engine.

``--cache-backend paged`` swaps the scheduler's KV cache for the paged
block-table backend (``serve.kv_cache``): fixed ``--page-size`` pages in
one pooled buffer, per-slot page tables, free-list recycling, and (on by
default) radix prefix sharing — a fleet of same-system-prompt requests
prefills the shared prefix once; ``--no-prefix-cache`` disables sharing.
The drain report prints backend, page utilization and prefix hit rate.
The paged cache routes through the scheduler/supervisor paths; the
chunked engine keeps its own dense cache.

``--speculative`` turns each scheduler decode step into a
self-speculative window (see ``serve.scheduler``): the FLRQ model's own
rank-truncated view drafts ``--spec-k`` greedy tokens, one batched
verify pass checks the whole window, and each slot emits its longest
agreeing prefix plus the target's correction token — tokens stay
bitwise-identical to plain greedy decode, only the step count shrinks.
``--draft-rank`` sets how many low-rank terms the draft keeps (0 =
codes-only backbone); per-slot adaptive k is on by default
(``--no-spec-adaptive`` pins the window). The drain report adds
acceptance rate, accepted tokens/step and wasted-draft fraction.
``--decode-kernel paged`` routes the paged backend's plain decode step
through the ``flash_decode_gqa_paged`` kernel (auto = TPU only).

Fault-tolerant serving (see ``serve.supervisor``): ``--replicas N`` puts
N scheduler-backed replicas behind one shared admission queue with
supervised restart; ``--fault-plan`` injects deterministic faults in the
CLI format ``kind@step[:site[:replica[:arg]]]`` (e.g.
``exception@4:decode:0``, plus ``random@seed:rate:n``); ``--deadline-s``
stamps a per-request deadline, ``--queue-cap`` bounds the admission queue
with explicit load-shedding, ``--max-restarts`` caps replica rebuilds.
The drain-time report then includes per-request terminal status counts
(``ok | timeout | rejected | failed``), per-replica restart counts, and
the wasted-token fraction of the recovery work.

``--fleet procs`` moves each replica into its own worker subprocess
(``serve.worker``) behind the framed RPC transport — the fleet that
survives SIGKILL and worker OOM. ``--journal PATH`` adds the durable
request journal (``serve.journal``): every admit/emit/terminal is
CRC-logged and fsynced per tick, and if the supervisor itself dies
(``supervisor_crash@N`` in the fault plan) the launcher automatically
builds a fresh supervisor and ``resume()``s from the journal —
exactly-once token streams across worker AND supervisor death.
``--heartbeat-s`` sets the idle-worker ping cadence and
``--partition-tolerance-s`` the per-call retry budget before a
partitioned worker is declared dead. The drain report grows a fleet
section: per-worker restarts, journal records/bytes/replays, RPC frames
sent/retried, and the wasted split (lost compute vs replayed-emitted).

Observability (``repro.obs``): ``--trace out.json`` exports the full
request lifecycle (queued → admit → prefill chunks → decode/spec windows
→ retire, plus dispatch, journal flushes, checkpoints and respawns) as
Chrome trace-event JSON — worker-subprocess spans stitch into the
supervisor timeline via the trace id carried on RPC frames.
``--metrics-json out.json`` snapshots the metrics registry behind every
number the drain reports print; ``--flight-dir DIR`` arms the flight
recorder, which dumps its ring there on supervisor crash, worker EOF or
cache corruption. All three compose with crash+resume: one Obs bundle
spans every supervisor the launcher builds.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.flrq import FLRQConfig
from ..data.pipeline import DataConfig, SyntheticCorpus
from ..models import LM
from ..obs import Obs
from ..obs.metrics import default_registry
from ..quant.apply import BACKENDS, dispatch_report
from ..quant.stacked import quantize_model_stacked
from ..serve.engine import Engine, Request, ServeConfig
from ..serve.faults import FaultPlan
from ..serve.journal import Journal
from ..serve.kv_cache import CacheConfig
from ..serve.scheduler import ContinuousScheduler, nearest_percentile
from ..serve.supervisor import Supervisor, SupervisorConfig, SupervisorCrash
from ..serve.worker import WorkerSpec, model_config_to_dict


def make_requests(rng, n, vocab, prompt_len, new_tokens, mixed: bool,
                  deadline_s=None):
    """Synthetic workload; ``mixed`` spans a 4x prompt-length range."""
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(1, prompt_len // 4), prompt_len + 1)) \
            if mixed else prompt_len
        reqs.append(Request(rng.integers(2, vocab, plen).astype(np.int32),
                            max_new_tokens=new_tokens, id=i,
                            deadline_s=deadline_s))
    return reqs


def poisson_arrivals(rng, n, rate: float):
    """Run-relative arrival offsets: Poisson process at ``rate`` req/s
    (0 = everything arrives at t=0)."""
    if rate <= 0:
        return [0.0] * n
    return list(np.cumsum(rng.exponential(1.0 / rate, size=n)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", type=int, default=0,
                    help="FLRQ bit-width (0 = serve fp weights)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", default="auto", choices=list(BACKENDS),
                    help="quantized-matmul backend (default auto: fused "
                         "kernel on TPU, jnp reference elsewhere)")
    ap.add_argument("--interpret", action="store_true",
                    help="run the fused kernel in Pallas interpret mode "
                         "(CPU validation of the kernel path)")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the layer stack instead of scanning one "
                         "compiled layer body (A/B reference)")
    ap.add_argument("--scheduler", default="chunked",
                    choices=("chunked", "continuous"),
                    help="chunked: slot-chunks prefill together and drain "
                         "together (the A/B oracle); continuous: per-slot "
                         "admission + chunked prefill + immediate "
                         "retirement")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefilled per scheduler step "
                         "(continuous scheduler; length-bucketed)")
    ap.add_argument("--poisson-rate", type=float, default=0.0,
                    help="replay a Poisson arrival process at this many "
                         "requests/s (0 = all requests at t=0)")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw prompt lengths uniformly from "
                         "[prompt_len/4, prompt_len]")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through the fault-tolerant supervisor "
                         "with this many replicas (0 = single scheduler, "
                         "no supervisor)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault injection: comma-separated "
                         "kind@step[:site[:replica[:arg]]] entries and/or "
                         "random@seed:rate:n (implies the supervisor)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds; expired "
                         "requests end with status timeout (0 = none)")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="bound the admission queue; overflow is shed "
                         "with status rejected (0 = unbounded)")
    ap.add_argument("--cache-backend", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache backend: dense per-slot envelope (the "
                         "reference) or the paged block-table cache with "
                         "radix prefix sharing")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged backend)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share full prompt-prefix pages across requests "
                         "via the radix trie (paged backend; "
                         "--no-prefix-cache disables sharing)")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decode: draft --spec-k tokens "
                         "per step with the rank-truncated FLRQ model, "
                         "verify in one batched pass (greedy only; tokens "
                         "stay bitwise-identical to plain decode)")
    ap.add_argument("--draft-rank", type=int, default=0,
                    help="low-rank terms the draft model keeps (0 = "
                         "codes-only backbone)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft window size (upper bound; per-slot "
                         "adaptive k shrinks/grows within it)")
    ap.add_argument("--spec-adaptive", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="adapt each slot's draft window to its measured "
                         "acceptance (--no-spec-adaptive pins k)")
    ap.add_argument("--decode-kernel", default="auto",
                    choices=("auto", "gather", "paged"),
                    help="paged-backend decode route: gather-to-dense "
                         "view (reference) or the flash_decode_gqa_paged "
                         "kernel over page tables (auto = kernel on TPU)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="supervisor restart cap per replica; past it the "
                         "replica is retired and its requests fail "
                         "terminally")
    ap.add_argument("--fleet", default="inproc", choices=("inproc", "procs"),
                    help="replica placement: in-process engines (the "
                         "deterministic reference) or worker subprocesses "
                         "over framed RPC (survives SIGKILL/OOM)")
    ap.add_argument("--journal", default="",
                    help="durable request journal path; with a "
                         "supervisor_crash fault the launcher auto-resumes "
                         "a fresh supervisor from it (exactly-once)")
    ap.add_argument("--heartbeat-s", type=float, default=1.0,
                    help="idle worker ping cadence (process fleet)")
    ap.add_argument("--partition-tolerance-s", type=float, default=5.0,
                    help="per-RPC retry budget before a partitioned "
                         "worker is declared dead (process fleet)")
    ap.add_argument("--trace", default="",
                    help="export a Chrome trace-event JSON of the run "
                         "(load in chrome://tracing or Perfetto); worker "
                         "subprocess spans stitch into one timeline")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics-registry snapshot (the same "
                         "instruments behind every drain report) to this "
                         "path at exit")
    ap.add_argument("--flight-dir", default="",
                    help="directory for flight-recorder crash dumps "
                         "(supervisor crash, worker EOF, cache "
                         "corruption); nothing is written without one")
    args = ap.parse_args(argv)
    if args.fleet == "procs" and not (args.replicas > 0 or args.fault_plan):
        ap.error("--fleet procs requires the supervisor (--replicas N)")
    if args.speculative and args.scheduler != "continuous" \
            and not (args.replicas > 0 or args.fault_plan):
        ap.error("--speculative requires --scheduler continuous (or the "
                 "supervisor via --replicas); the chunked engine has no "
                 "speculative path")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    if args.no_scan:
        model = model.with_scan(False)
    key = jax.random.PRNGKey(0)
    params = None
    if args.fleet == "inproc":
        # a process fleet never touches launcher-side params: each worker
        # rebuilds (and re-quantizes) deterministically from its spec seed
        params = model.init(key)
        if args.quantize:
            t0 = time.time()
            data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=128,
                                              global_batch=4))
            params, stats = quantize_model_stacked(
                params, None,
                FLRQConfig(bits=args.quantize,
                           blc_epochs=2 if args.quantize > 2 else 8))
            ranks = [s.rank for v in stats.values() for s in v]
            print(f"FLRQ-W{args.quantize}: {len(ranks)} matrices, "
                  f"avg rank {np.mean(ranks):.1f}, {time.time()-t0:.1f}s")

    rng = np.random.default_rng(0)
    reqs = make_requests(rng, args.requests, cfg.vocab, args.prompt_len,
                         args.new_tokens, args.mixed_lengths,
                         deadline_s=args.deadline_s or None)
    supervised = args.replicas > 0 or bool(args.fault_plan)
    # one observability bundle for the whole run: every engine, scheduler
    # and supervisor below shares this registry and tracer, so the drain
    # reports, --metrics-json snapshot and --trace timeline are three
    # views over the same instruments — including across a supervisor
    # crash + journal resume, which reuses the same Obs.
    obs = Obs(trace=bool(args.trace),
              flight_dir=args.flight_dir or None,
              process_name="supervisor" if supervised else "serve")

    def export_obs(code: int = 0) -> int:
        if args.trace:
            obs.tracer.export(args.trace)
            print(f"  trace: {args.trace} "
                  f"({len(obs.tracer.events)} events)")
        if args.metrics_json:
            snap = obs.registry.snapshot()
            quant = default_registry().snapshot()
            if snap.get("enabled") and quant.get("enabled"):
                # quant.dispatch counters live in the process-wide default
                # registry (module-level dispatch log); fold them into the
                # run snapshot so one file carries every instrument
                snap["counters"].update(quant["counters"])
            import json
            with open(args.metrics_json, "w") as f:
                f.write(json.dumps(snap, sort_keys=True, indent=1))
            print(f"  metrics: {args.metrics_json}")
        return code
    scfg = ServeConfig(
        cache=CacheConfig(backend=args.cache_backend,
                          max_slots=args.slots,
                          max_seq=args.prompt_len + args.new_tokens + 8,
                          page_size=args.page_size,
                          prefix_cache=args.prefix_cache,
                          decode_kernel=args.decode_kernel),
        backend=args.backend, interpret=args.interpret or None,
        speculative=args.speculative, draft_rank=args.draft_rank,
        spec_k=args.spec_k, spec_adaptive=args.spec_adaptive)
    eng = Engine(model, params, scfg, obs=None if supervised else obs) \
        if args.fleet == "inproc" else None

    def cache_report(engine):
        s = engine.cache_backend.stats()
        line = (f"  cache: backend={s['backend']} "
                f"page-utilization {s['page_utilization']:.1%}")
        if s["backend"] == "paged":
            line += (f" prefix-hit-rate {s['prefix_hit_rate']:.1%} "
                     f"(hit {s['hit_tokens']}/{s['prompt_tokens']} prompt "
                     f"tokens, {s['cow_copies']} CoW, "
                     f"{s['evictions']} evictions) "
                     f"decode-route={s['decode_route']}")
        print(line)

    def spec_report(*scheds):
        """Aggregate speculative stats across schedulers (one, or a
        supervisor fleet's replicas) into a single drain-report line."""
        if not args.speculative:
            return
        drafted = sum(s.spec_draft_tokens for s in scheds)
        accepted = sum(s.spec_accepted_tokens for s in scheds)
        emitted = sum(s.spec_emitted_tokens for s in scheds)
        steps = sum(s.spec_slot_steps for s in scheds)
        windows = sum(s.spec_windows for s in scheds)
        print(f"  speculative: k={args.spec_k} draft-rank={args.draft_rank} "
              f"windows={windows} "
              f"acceptance {accepted / max(drafted, 1):.1%} "
              f"accepted/step {emitted / max(steps, 1):.2f} "
              f"wasted-draft {(drafted - accepted) / max(drafted, 1):.1%}")

    t0 = time.time()
    if supervised:
        # fault-tolerant fleet: N replicas behind one shared admission
        # queue, supervised restart, zero dropped requests
        plan = FaultPlan.parse(args.fault_plan) if args.fault_plan else None
        sup_cfg = SupervisorConfig(
            replicas=max(1, args.replicas),
            prefill_chunk=args.prefill_chunk,
            max_restarts=args.max_restarts,
            queue_cap=args.queue_cap or None,
            heartbeat_s=args.heartbeat_s,
            partition_tolerance_s=args.partition_tolerance_s)
        fleet = []
        factory, worker_spec = None, None
        if args.fleet == "procs":
            worker_spec = WorkerSpec(
                model=model_config_to_dict(cfg), serve=scfg.to_dict(),
                seed=0, scan=not args.no_scan,
                quantize_bits=args.quantize,
                prefill_chunk=args.prefill_chunk,
                fault_plan=args.fault_plan)
        else:
            def factory():
                fleet.append(Engine(model, params, scfg))
                return fleet[-1]
        arrivals = poisson_arrivals(rng, len(reqs), args.poisson_rate)
        resumed = 0
        sup = Supervisor(factory, sup_cfg, fault_plan=plan,
                         journal=Journal(args.journal) if args.journal
                         else None,
                         fleet=args.fleet, worker_spec=worker_spec,
                         obs=obs)
        try:
            with sup:
                report = sup.serve(reqs, arrivals)
        except SupervisorCrash as e:
            # the supervisor died; without a journal that is terminal,
            # with one a fresh supervisor replays and drains the rest
            if not args.journal:
                raise
            while True:
                resumed += 1
                print(f"  supervisor crashed ({e}); resuming from "
                      f"{args.journal} (attempt {resumed})")
                # same Obs across resume: one trace timeline and one
                # registry span the crash and the replayed drain
                sup = Supervisor(factory, sup_cfg,
                                 journal=Journal(args.journal),
                                 fleet=args.fleet, worker_spec=worker_spec,
                                 obs=obs)
                try:
                    with sup:
                        report = sup.resume()
                    break
                except SupervisorCrash as e2:  # crash during replay
                    e = e2
        dt = time.time() - t0
        ok = [o for o in report.outcomes if o.status == "ok"]
        toks = sum(len(o.tokens) for o in report.outcomes)
        counts = report.status_counts()
        p = lambda q: nearest_percentile([o.ttft_s for o in ok], q)
        print(f"{len(report.outcomes)}/{report.submitted} requests "
              f"terminal, {toks} tokens in {dt:.2f}s "
              f"({max(1, args.replicas)} {args.fleet} replicas, "
              f"supervised)")
        print("  statuses: " + " ".join(
            f"{s}={counts.get(s, 0)}"
            for s in ("ok", "timeout", "rejected", "failed")))
        print(f"  restarts: {dict(report.restarts)}; "
              f"failures={len(report.failures)}; "
              f"stragglers={report.straggler_events}; "
              f"wasted: compute {report.wasted_compute_fraction:.1%} + "
              f"replayed-emitted {report.replayed_emitted_fraction:.1%} "
              f"= {report.wasted_token_fraction:.1%}")
        if args.fleet == "procs" or args.journal:
            print(f"  fleet: mode={args.fleet} resumes={resumed}; "
                  f"frames sent={report.frames_sent} "
                  f"retried={report.frames_retried}; "
                  f"journal records={report.journal_records} "
                  f"bytes={report.journal_bytes} "
                  f"replayed={report.journal_replayed} "
                  f"fsyncs={report.journal_fsyncs}")
        print(f"  TTFT p50 {p(0.5)*1e3:.1f}ms p95 {p(0.95)*1e3:.1f}ms "
              f"(ok requests)")
        for engine in fleet[-max(1, args.replicas):]:
            cache_report(engine)
        if args.fleet == "inproc":
            spec_report(*(r.scheduler for r in sup.replicas))
        if not report.zero_drops:
            print("  WARNING: request reconciliation failed "
                  f"({len(report.outcomes)} != {report.submitted})")
            return export_obs(1)
        if args.quantize and args.fleet == "inproc":
            print(dispatch_report())
        return export_obs(0)
    if args.scheduler == "continuous":
        # surface fused→ref fallbacks at queue drains without waiting for
        # the end — but only when the routing registry actually changed,
        # not a bare print per drain (steady-state serving re-drains
        # constantly and decisions are static under jit)
        on_drain = None
        if args.quantize:
            last_report = [""]

            def on_drain():
                rep = dispatch_report()
                if rep != last_report[0]:
                    last_report[0] = rep
                    print(rep)
        sched = ContinuousScheduler(eng, prefill_chunk=args.prefill_chunk,
                                    on_drain=on_drain, obs=obs)
        arrivals = poisson_arrivals(rng, len(reqs), args.poisson_rate)
        sres = sched.run(reqs, arrivals)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in sres)
        ttfts = [r.ttft_s for r in sres]
        p = lambda q: nearest_percentile(ttfts, q)
        print(f"{len(sres)} requests, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s incl. compile, continuous scheduler, "
              f"chunk={args.prefill_chunk}, "
              f"utilization {sched.utilization():.0%})")
        print(f"  TTFT p50 {p(0.5)*1e3:.1f}ms p95 {p(0.95)*1e3:.1f}ms; "
              f"queue mean {np.mean([r.queue_s for r in sres])*1e3:.1f}ms")
        counts = sched.status_counts()
        print("  statuses: " + " ".join(
            f"{s}={counts.get(s, 0)}"
            for s in ("ok", "timeout", "rejected", "failed")))
        cache_report(eng)
        spec_report(sched)
        for r in sres[:3]:
            print(f"  req {r.id}: {r.tokens}")
        return export_obs(0)

    results = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile, "
          f"{'unrolled' if args.no_scan else 'scanned'} layers)")
    for r in results[:3]:
        print(f"  req {r.id}: {r.tokens}")
    if args.quantize:
        print(dispatch_report())
    return export_obs(0)


if __name__ == "__main__":
    raise SystemExit(main())
