"""Serving launcher: batched generation with optional FLRQ quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --quantize 4 --requests 8 --new-tokens 16 --backend auto \
        --scheduler continuous --prefill-chunk 32 --poisson-rate 50

``--backend`` selects the quantized-matmul execution path (see
``quant.apply``): "ref" (pure jnp), "fused" (Pallas kernel; interpret mode
off-TPU), or "auto" (kernel on TPU when supported, ref elsewhere). The
dispatch report shows which path each tensor config actually took —
bits=3 and other kernel-unsupported configs fall back to ref *visibly*;
under the continuous scheduler it is flushed at every queue drain, so a
long-running serve surfaces fused→ref fallbacks without waiting for the
end. ``--no-scan`` unrolls the layer stack (L per-layer dispatches per
step) instead of the default single scanned layer body.

``--scheduler continuous`` serves through the continuous-batching
scheduler (per-slot admission, chunked prefill of ``--prefill-chunk``
tokens per step, immediate slot retirement); ``--poisson-rate R`` replays
a Poisson arrival process at R requests/s (0 = all requests at t=0) and
``--mixed-lengths`` draws prompt lengths uniformly from
[prompt_len/4, prompt_len] — the mixed-length workload where continuous
batching beats the chunked engine.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.flrq import FLRQConfig
from ..data.pipeline import DataConfig, SyntheticCorpus
from ..models import LM
from ..quant.apply import BACKENDS, dispatch_report
from ..quant.stacked import quantize_model_stacked
from ..serve.engine import Engine, Request, ServeConfig
from ..serve.scheduler import ContinuousScheduler, nearest_percentile


def make_requests(rng, n, vocab, prompt_len, new_tokens, mixed: bool):
    """Synthetic workload; ``mixed`` spans a 4x prompt-length range."""
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(1, prompt_len // 4), prompt_len + 1)) \
            if mixed else prompt_len
        reqs.append(Request(rng.integers(2, vocab, plen).astype(np.int32),
                            max_new_tokens=new_tokens, id=i))
    return reqs


def poisson_arrivals(rng, n, rate: float):
    """Run-relative arrival offsets: Poisson process at ``rate`` req/s
    (0 = everything arrives at t=0)."""
    if rate <= 0:
        return [0.0] * n
    return list(np.cumsum(rng.exponential(1.0 / rate, size=n)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", type=int, default=0,
                    help="FLRQ bit-width (0 = serve fp weights)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", default="auto", choices=list(BACKENDS),
                    help="quantized-matmul backend (default auto: fused "
                         "kernel on TPU, jnp reference elsewhere)")
    ap.add_argument("--interpret", action="store_true",
                    help="run the fused kernel in Pallas interpret mode "
                         "(CPU validation of the kernel path)")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the layer stack instead of scanning one "
                         "compiled layer body (A/B reference)")
    ap.add_argument("--scheduler", default="chunked",
                    choices=("chunked", "continuous"),
                    help="chunked: slot-chunks prefill together and drain "
                         "together (the A/B oracle); continuous: per-slot "
                         "admission + chunked prefill + immediate "
                         "retirement")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefilled per scheduler step "
                         "(continuous scheduler; length-bucketed)")
    ap.add_argument("--poisson-rate", type=float, default=0.0,
                    help="replay a Poisson arrival process at this many "
                         "requests/s (0 = all requests at t=0)")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw prompt lengths uniformly from "
                         "[prompt_len/4, prompt_len]")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    if args.no_scan:
        model = model.with_scan(False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    if args.quantize:
        t0 = time.time()
        data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=128,
                                          global_batch=4))
        params, stats = quantize_model_stacked(
            params, None,
            FLRQConfig(bits=args.quantize,
                       blc_epochs=2 if args.quantize > 2 else 8))
        ranks = [s.rank for v in stats.values() for s in v]
        print(f"FLRQ-W{args.quantize}: {len(ranks)} matrices, "
              f"avg rank {np.mean(ranks):.1f}, {time.time()-t0:.1f}s")

    rng = np.random.default_rng(0)
    reqs = make_requests(rng, args.requests, cfg.vocab, args.prompt_len,
                         args.new_tokens, args.mixed_lengths)
    eng = Engine(model, params, ServeConfig(
        max_slots=args.slots, max_seq=args.prompt_len + args.new_tokens + 8,
        backend=args.backend, interpret=args.interpret or None))

    t0 = time.time()
    if args.scheduler == "continuous":
        # flush the dispatch report at every queue drain — a long-running
        # serve surfaces fused→ref fallbacks without waiting for the end
        on_drain = (lambda: print(dispatch_report())) if args.quantize \
            else None
        sched = ContinuousScheduler(eng, prefill_chunk=args.prefill_chunk,
                                    on_drain=on_drain)
        arrivals = poisson_arrivals(rng, len(reqs), args.poisson_rate)
        sres = sched.run(reqs, arrivals)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in sres)
        ttfts = [r.ttft_s for r in sres]
        p = lambda q: nearest_percentile(ttfts, q)
        print(f"{len(sres)} requests, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s incl. compile, continuous scheduler, "
              f"chunk={args.prefill_chunk}, "
              f"utilization {sched.utilization():.0%})")
        print(f"  TTFT p50 {p(0.5)*1e3:.1f}ms p95 {p(0.95)*1e3:.1f}ms; "
              f"queue mean {np.mean([r.queue_s for r in sres])*1e3:.1f}ms")
        for r in sres[:3]:
            print(f"  req {r.id}: {r.tokens}")
        return 0

    results = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile, "
          f"{'unrolled' if args.no_scan else 'scanned'} layers)")
    for r in results[:3]:
        print(f"  req {r.id}: {r.tokens}")
    if args.quantize:
        print(dispatch_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
