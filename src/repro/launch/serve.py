"""Serving launcher: batched generation with optional FLRQ quantization.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --quantize 4 --requests 8 --new-tokens 16 --backend auto

``--backend`` selects the quantized-matmul execution path (see
``quant.apply``): "ref" (pure jnp), "fused" (Pallas kernel; interpret mode
off-TPU), or "auto" (kernel on TPU when supported, ref elsewhere). The
dispatch report printed after generation shows which path each tensor
config actually took — bits=3 and other kernel-unsupported configs fall
back to ref *visibly*. ``--no-scan`` unrolls the layer stack (L per-layer
dispatches per step) instead of the default single scanned layer body.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.flrq import FLRQConfig
from ..data.pipeline import DataConfig, SyntheticCorpus
from ..models import LM
from ..quant.apply import BACKENDS, dispatch_report
from ..quant.stacked import quantize_model_stacked
from ..serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-proxy-25m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", type=int, default=0,
                    help="FLRQ bit-width (0 = serve fp weights)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--backend", default="auto", choices=list(BACKENDS),
                    help="quantized-matmul backend (default auto: fused "
                         "kernel on TPU, jnp reference elsewhere)")
    ap.add_argument("--interpret", action="store_true",
                    help="run the fused kernel in Pallas interpret mode "
                         "(CPU validation of the kernel path)")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the layer stack instead of scanning one "
                         "compiled layer body (A/B reference)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    if args.no_scan:
        model = model.with_scan(False)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    if args.quantize:
        t0 = time.time()
        data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=128,
                                          global_batch=4))
        params, stats = quantize_model_stacked(
            params, None,
            FLRQConfig(bits=args.quantize,
                       blc_epochs=2 if args.quantize > 2 else 8))
        ranks = [s.rank for v in stats.values() for s in v]
        print(f"FLRQ-W{args.quantize}: {len(ranks)} matrices, "
              f"avg rank {np.mean(ranks):.1f}, {time.time()-t0:.1f}s")

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(2, cfg.vocab, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens, id=i)
            for i in range(args.requests)]
    eng = Engine(model, params, ServeConfig(
        max_slots=args.slots, max_seq=args.prompt_len + args.new_tokens + 8,
        backend=args.backend, interpret=args.interpret or None))
    t0 = time.time()
    results = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile, "
          f"{'unrolled' if args.no_scan else 'scanned'} layers)")
    for r in results[:3]:
        print(f"  req {r.id}: {r.tokens}")
    if args.quantize:
        print(dispatch_report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
