"""Sharded, async, atomic checkpointing — from scratch (no orbax offline).

Layout on disk:

    <dir>/step_000123/
        manifest.json          {step, tree structure, leaf shapes/dtypes,
                                mesh axes at save time, wall time}
        shard_00000.npz        host-local leaf shards (addressable data only)
        COMMIT                 written last — a checkpoint without COMMIT is
                               incomplete and ignored on restore (atomicity)

Fault-tolerance properties:
  * async: ``save`` snapshots to host RAM synchronously (cheap device→host
    copy of local shards) and writes in a background thread — training
    continues; ``wait()`` joins before the next save or exit. A background
    write that fails is NEVER swallowed: the exception is captured and
    re-raised at the next ``wait()``/``save()`` (an unreported checkpoint
    failure is a restore-time data loss discovered months later).
  * atomic: tmp-dir + rename + COMMIT marker; a process killed mid-save
    never corrupts the latest-complete link. The injectable ``fault_hook``
    (serve.faults) fires between shard write and COMMIT — the exact window
    a kill-during-checkpoint test must hit.
  * verified: the manifest records a sha256 + byte count per shard;
    ``restore`` refuses corrupt or truncated shards with
    ``CheckpointCorruptionError`` instead of loading garbage weights.
  * elastic: restore reshards to *any* mesh via jax.make_array_from_callback
    on the target sharding (512→256 survivors works; tested).
  * retention: keep-last-k garbage collection.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A shard failed checksum/size verification at restore time."""


def digest_bytes(payload: bytes) -> Dict[str, Any]:
    """Manifest entry for a byte blob: sha256 + byte count. One shared
    verification discipline: checkpoint shards and the serve journal's
    sealed prefix (``serve.journal``) both record and re-check exactly
    this pair before trusting bytes from disk."""
    return dict(sha256=hashlib.sha256(payload).hexdigest(),
                bytes=len(payload))


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in flat}, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # fault-injection hook (serve.faults.FaultInjector.check): called
        # with site "checkpoint" between shard write and COMMIT. None in
        # production.
        self.fault_hook = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()  # joins AND re-raises a prior background failure
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        host_data = {}
        meta = {}
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                host_data[key] = arr.view(np.uint16)
                meta[key] = dict(shape=list(arr.shape), dtype="bfloat16")
            else:
                host_data[key] = arr
                meta[key] = dict(shape=list(arr.shape), dtype=str(arr.dtype))

        def write():
            # a raise anywhere in here (disk full, injected kill) leaves
            # the tmp dir without COMMIT — invisible to restore — and is
            # captured for re-raise at the next wait()/save()
            try:
                tmp = self.dir / f".tmp_step_{step:09d}"
                final = self.dir / f"step_{step:09d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "shard_00000.npz",
                         **{k.replace("/", "\\"): v
                            for k, v in host_data.items()})
                shards = {}
                for f in sorted(tmp.glob("shard_*.npz")):
                    shards[f.name] = digest_bytes(f.read_bytes())
                (tmp / "manifest.json").write_text(json.dumps(
                    dict(step=step, leaves=meta, shards=shards,
                         time=time.time()), indent=1))
                if self.fault_hook is not None:
                    self.fault_hook("checkpoint")
                (tmp / "COMMIT").write_text("ok")
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — captured, not lost
                self._error = e

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _complete_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``tree_like`` (shapes/dtypes
        authoritative from the manifest). ``shardings``: optional pytree of
        NamedSharding — enables restore onto a different mesh (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        # shard verification (manifests predating checksums skip it):
        # corrupt weights must fail HERE, not as garbage activations later
        for name, info in manifest.get("shards", {}).items():
            f = d / name
            if not f.exists():
                raise CheckpointCorruptionError(
                    f"checkpoint step {step}: shard {name} missing")
            payload = f.read_bytes()
            if len(payload) != info["bytes"]:
                raise CheckpointCorruptionError(
                    f"checkpoint step {step}: shard {name} truncated "
                    f"({len(payload)} bytes, manifest says {info['bytes']})")
            if hashlib.sha256(payload).hexdigest() != info["sha256"]:
                raise CheckpointCorruptionError(
                    f"checkpoint step {step}: shard {name} failed sha256 "
                    "verification — refusing to load corrupt weights")
        data = np.load(d / "shard_00000.npz")

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        sh_flat = None
        if shardings is not None:
            sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        out = []
        for i, (path, leaf) in enumerate(flat):
            key = jax.tree_util.keystr(path)
            meta = manifest["leaves"][key]
            arr = data[key.replace("/", "\\")]
            if meta["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            if sh_flat is not None:
                sh = sh_flat[i]
                out.append(jax.make_array_from_callback(
                    tuple(meta["shape"]), sh, lambda idx, a=arr: a[idx]))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
