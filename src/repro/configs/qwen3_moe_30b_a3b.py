"""Qwen3-30B-A3B MoE: 48L, d=2048, 32H (GQA kv=4), expert d_ff=768,
128 experts top-8, qk_norm. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    qk_norm=True,
    n_experts=128,
    topk=8,
)
