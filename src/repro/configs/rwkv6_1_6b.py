"""RWKV-6 "Finch" 1.6B (attention-free): 24L, d=2048, d_ff=7168,
vocab=65536, data-dependent decay, O(1)-state decode -> runs long_500k.
[arXiv:2404.05892; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # d_model / rwkv_head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    rwkv_head_dim=64,
    sub_quadratic=True,
)
