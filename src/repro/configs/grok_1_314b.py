"""grok-1 (314B MoE): 64L, d=6144, 48H (GQA kv=8), d_ff=32768, 8e top-2.
[hf:xai-org/grok-1; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    topk=2,
)
