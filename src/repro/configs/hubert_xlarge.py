"""HuBERT X-Large (audio encoder backbone): 48L, d=1280, 16H, d_ff=5120,
504 cluster units. Encoder-only — no decode shapes. Conv feature frontend
is a stub per the brief. [arXiv:2106.07447; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    frontend="audio_stub",
    is_encoder=True,
)
