"""Architecture registry: ``get_config(arch_id)`` + input-shape cells.

The 10 assigned architectures (each paired with the LM shape set) plus the
paper's own evaluation families (OPT / LLaMA-2 proxies used by quantization
benchmarks and the e2e training example).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig, small_variant

from . import (  # noqa: E402
    gemma2_9b,
    grok_1_314b,
    hubert_xlarge,
    hymba_1_5b,
    internlm2_20b,
    mistral_nemo_12b,
    qwen2_vl_72b,
    qwen3_4b,
    qwen3_moe_30b_a3b,
    rwkv6_1_6b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        grok_1_314b, qwen3_moe_30b_a3b, hubert_xlarge, gemma2_9b,
        internlm2_20b, qwen3_4b, mistral_nemo_12b, hymba_1_5b,
        rwkv6_1_6b, qwen2_vl_72b,
    )
}

# Paper-model proxies (OPT-125M-ish / LLaMA-ish) for in-repo training +
# quantization end-to-end runs on CPU.
PAPER_PROXIES: Dict[str, ModelConfig] = {
    "opt-proxy-25m": ModelConfig(
        name="opt-proxy-25m", family="dense", n_layers=6, d_model=384,
        n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536, vocab=8192,
        remat=False, loss_chunk=256,
    ),
    "llama-proxy-100m": ModelConfig(
        name="llama-proxy-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=16384,
        remat=False, loss_chunk=256,
    ),
}


def get_config(arch: str) -> ModelConfig:
    if arch in ARCHS:
        return ARCHS[arch]
    if arch in PAPER_PROXIES:
        return PAPER_PROXIES[arch]
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS) + sorted(PAPER_PROXIES)}")


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return small_variant(get_config(arch), **overrides)


# ---------------------------------------------------------------------------
# Input-shape cells (the assigned 4-shape LM set)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape, runnable, skip_reason) — 40 rows."""
    out = []
    for a, cfg in ARCHS.items():
        for s, spec in SHAPES.items():
            ok, why = cell_status(cfg, spec)
            out.append((a, s, ok, why))
    return out
