"""Gemma-2 9B: 42L, d=3584, 16H (GQA kv=8, hd=256), d_ff=14336,
vocab=256000, alternating local(4096)/global attention, logit softcaps,
tied embeddings. [arXiv:2408.00118; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    attn_softcap=50.0,
    final_softcap=30.0,
    local_window=4096,
    global_every=2,
    tie_embeddings=True,
)
