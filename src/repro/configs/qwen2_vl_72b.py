"""Qwen2-VL 72B (VLM backbone): 80L, d=8192, 64H (GQA kv=8, hd=128),
d_ff=29568, vocab=152064, M-RoPE. Vision frontend is a stub per the brief.
[arXiv:2409.12191; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    mrope=True,
    frontend="vision_stub",
)
