"""Hymba 1.5B (hybrid): 32L, d=1600, 25H (GQA kv=5, hd=64), d_ff=5504,
vocab=32001, parallel attn+mamba heads (ssm_state=16), sliding-window
attention with 3 global layers. Sub-quadratic -> runs long_500k.
[arXiv:2411.13676; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hymba",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    local_window=1024,
    global_layers=(0, 15, 31),
    sub_quadratic=True,
)
