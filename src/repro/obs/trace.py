"""Span tracing with Chrome trace-event export (Perfetto-loadable).

``tracer.span("prefill_chunk", request_id=3)`` is a context manager that
records one complete ("ph":"X") event on exit; ``tracer.instant(...)``
records a point event. Events carry the tracer's ``trace_id`` in their
args, which is how worker-side spans are matched to the supervisor
timeline: the supervisor ships its trace id in the ``start`` RPC, the
worker stamps every span with it, and each ``step`` reply returns the
worker's drained events for the supervisor to ``adopt`` under the
worker's logical pid (supervisor = pid 0, worker replica r = pid r+1)
with a clock offset measured at the start handshake.

Determinism: timestamps come from the injectable clock (a
``VirtualClock`` yields byte-identical exports across replayed chaos
runs — asserted in tests/test_obs.py), ids are never random, and
``to_json`` serializes with sorted keys. A disabled tracer hands back a
shared no-op span so instrumented code pays one attribute check and no
allocation.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .metrics import MonotonicClock


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: int,
                 args: dict) -> None:
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self._t0 = 0

    def __enter__(self):
        self._t0 = self._tracer._us()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            # a span that ends in a raise records it — chaos timelines
            # show WHERE the injected fault fired, not just that it did
            self.args["error"] = exc_type.__name__
        tr = self._tracer
        tr.events.append({
            "name": self.name, "ph": "X", "cat": self.cat,
            "ts": self._t0, "dur": tr._us() - self._t0,
            "pid": tr.pid, "tid": self.tid, "args": self.args,
        })
        return False


class Tracer:
    """Per-process span collector. ``pid`` is a LOGICAL process id in the
    exported timeline (deterministic: supervisor 0, worker r at r+1), not
    an OS pid."""

    def __init__(self, clock=None, enabled: bool = False, pid: int = 0,
                 process_name: str = "serve",
                 trace_id: str = "00000000") -> None:
        self.clock = clock if clock is not None else MonotonicClock()
        self.enabled = enabled
        self.pid = pid
        self.trace_id = trace_id
        self.events: List[dict] = []
        self._process_names: Dict[int, str] = {pid: process_name}

    def _us(self) -> int:
        return int(round(float(self.clock.now()) * 1e6))

    # ------------------------------------------------------------ record
    def span(self, name: str, cat: str = "serve", tid: int = 0, **args):
        if not self.enabled:
            return NULL_SPAN
        args["trace"] = self.trace_id
        return _Span(self, name, cat, tid, args)

    def instant(self, name: str, cat: str = "serve", tid: int = 0,
                **args) -> None:
        if not self.enabled:
            return
        args["trace"] = self.trace_id
        self.events.append({
            "name": name, "ph": "i", "s": "t", "cat": cat,
            "ts": self._us(), "pid": self.pid, "tid": tid, "args": args,
        })

    def set_process_name(self, pid: int, name: str) -> None:
        self._process_names[pid] = name

    # ------------------------------------------------------- stitching
    def adopt(self, events: Optional[List[dict]], pid: Optional[int] = None,
              offset_us: int = 0) -> None:
        """Merge events drained from another process's tracer into this
        timeline, re-homed under ``pid`` and shifted by ``offset_us``
        (the supervisor-vs-worker clock offset measured at the start
        handshake)."""
        if not self.enabled or not events:
            return
        for e in events:
            e = dict(e)
            if pid is not None:
                e["pid"] = pid
            e["ts"] = int(e.get("ts", 0)) + int(offset_us)
            self.events.append(e)

    def drain(self) -> List[dict]:
        """Take and clear the buffered events (what a worker ships in
        each step reply)."""
        ev, self.events = self.events, []
        return ev

    # ---------------------------------------------------------- export
    def to_obj(self) -> dict:
        meta = [{"name": "process_name", "ph": "M", "ts": 0,
                 "pid": pid, "tid": 0, "args": {"name": name}}
                for pid, name in sorted(self._process_names.items())]
        return {"displayTimeUnit": "ms", "traceEvents": meta + self.events}

    def to_json(self) -> str:
        return json.dumps(self.to_obj(), sort_keys=True)

    def export(self, path) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return str(path)


def validate_chrome_trace(obj) -> List[str]:
    """Schema check for a Chrome trace-event JSON object (the structure
    chrome://tracing and Perfetto load). Returns a list of problems —
    empty means valid. Used by the CI gate step and the obs tests."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, e in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if not isinstance(e.get("name"), str) or not e["name"]:
            errors.append(f"{where}: missing name")
        if ph not in ("X", "i", "I", "M", "B", "E", "b", "e", "C"):
            errors.append(f"{where}: bad ph {ph!r}")
        for field in ("pid", "tid"):
            if not isinstance(e.get(field), int):
                errors.append(f"{where}: {field} is not an int")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: complete event with bad dur "
                              f"{dur!r}")
    return errors
