"""Unified observability for the serving stack: one metrics registry,
span tracing with Perfetto export, and a crash flight recorder.

``Obs`` bundles the three plus the clock they share, and is threaded
Supervisor → replicas → scheduler/engine/cache-backend so every report
surface (drain reports, ``dispatch_report()``, ``spec_stats()``, cache
``stats()``, fleet journal/frame counters) reads the SAME instruments
the registry snapshots — no independent counters. Defaults are chosen
for the hot path: metrics on (a registry counter costs what the int it
replaced cost), tracing off (spans allocate), flight recorder on but
writing nothing until a crash dump is requested with a directory
configured.

Usage::

    obs = Obs(trace=True, clock=clock, flight_dir="...")
    with obs.tracer.span("prefill_chunk", request_id=req.id):
        ...
    obs.registry.counter("serve.decode.tokens").inc()
    obs.tracer.export("trace.json")         # chrome://tracing / Perfetto
    json.dump(obs.registry.snapshot(), f)   # --metrics-json
"""
from __future__ import annotations

from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MonotonicClock, Registry, default_registry,
                      metric_key)
from .recorder import FlightRecorder
from .stats import latency_summary, nearest_percentile
from .trace import NULL_SPAN, Tracer, validate_chrome_trace


class Obs:
    """Registry + tracer + flight recorder sharing one injectable clock."""

    def __init__(self, metrics: bool = True, trace: bool = False,
                 clock=None, flight_dir=None, capacity: int = 256,
                 process_name: str = "serve",
                 trace_id: str = "00000000") -> None:
        self.clock = clock if clock is not None else MonotonicClock()
        self.registry = Registry(enabled=metrics, clock=self.clock)
        self.tracer = Tracer(clock=self.clock, enabled=trace,
                             process_name=process_name, trace_id=trace_id)
        self.recorder = FlightRecorder(capacity=capacity, clock=self.clock,
                                       dir=flight_dir,
                                       enabled=metrics or trace)
        self.flight_dir = flight_dir

    @classmethod
    def disabled(cls) -> "Obs":
        return cls(metrics=False, trace=False)


# Fully-off bundle for "no observability" paths; every consumer treats
# ``obs=None`` as "make me a default Obs()" (metrics on, tracing off),
# NOT as NULL_OBS — reports must keep working out of the box.
NULL_OBS = Obs.disabled()

__all__ = [
    "Obs", "NULL_OBS",
    "Registry", "Counter", "Gauge", "Histogram", "default_registry",
    "metric_key", "DEFAULT_BUCKETS", "MonotonicClock",
    "Tracer", "NULL_SPAN", "validate_chrome_trace",
    "FlightRecorder",
    "nearest_percentile", "latency_summary",
]
