"""Latency statistics shared by every reporting surface.

One nearest-rank percentile definition for the whole repo: the serve CLI,
the continuous scheduler, the chunked engine and the serving benchmark all
import THIS function (``serve.scheduler`` re-exports it for backward
compatibility), so reported TTFT/ITL percentiles cannot silently diverge
between surfaces. The semantics match ``benchmarks/gate.py``'s reference
statistic: nearest-rank over the sorted sample, 0.0 for an empty one.
"""
from __future__ import annotations

from typing import Dict, List, Sequence


def nearest_percentile(values: Sequence[float], q: float) -> float:
    """Nearest-index percentile over unsorted values (0.0 for an empty
    sequence)."""
    if not values:
        return 0.0
    vs = sorted(values)
    return float(vs[min(len(vs) - 1, int(q * len(vs)))])


def latency_summary(values: Sequence[float]) -> Dict[str, float]:
    """The standard latency digest every report prints: count, mean,
    nearest-rank p50/p95, min/max. Zeroes for an empty sample (a drained
    serve with no ok requests must not crash its own report)."""
    vals: List[float] = [float(v) for v in values]
    if not vals:
        return dict(n=0, mean=0.0, p50=0.0, p95=0.0, min=0.0, max=0.0)
    return dict(
        n=len(vals),
        mean=sum(vals) / len(vals),
        p50=nearest_percentile(vals, 0.50),
        p95=nearest_percentile(vals, 0.95),
        min=min(vals),
        max=max(vals),
    )
