"""Process-wide metrics registry: counters, gauges, fixed-bucket
histograms.

Design constraints (see tests/test_obs.py):

  * **Handles, not lookups, on the hot path** — ``registry.counter(name,
    **labels)`` returns a cached ``Counter`` object; callers hold the
    handle and ``.inc()`` is one attribute add. The registry dict is only
    touched at instrument-creation time.
  * **Near-zero overhead when disabled** — a disabled registry hands out
    shared no-op singletons (one per instrument kind, ever), so
    instrumented code pays a method call on a slotted do-nothing object
    and allocates nothing.
  * **Injectable clock** — snapshots stamp ``ts`` from the same
    ``Clock``/``VirtualClock`` the serving stack runs on, so chaos tests
    under a virtual clock produce deterministic timestamps.
  * **Adoptable instruments** — components that predate the shared
    registry (a ``Journal`` opened by the launcher before the supervisor
    exists) create counters standalone and the supervisor re-registers
    the SAME objects under fleet labels via ``register_counter``; counts
    are never copied, so report and snapshot read one storage location.

Snapshot keys are ``name{label=value,...}`` with labels sorted — stable
across runs, greppable, and JSON-safe.
"""
from __future__ import annotations

import bisect
import json
import time
from typing import Dict, Optional, Sequence, Tuple


class MonotonicClock:
    """Minimal stand-in for ``serve.faults.Clock`` (kept local so ``obs``
    never imports the serving stack — the dependency points the other
    way). Anything with a ``now() -> float`` works as a clock here."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(max(0.0, dt))


# default histogram buckets: latencies in seconds, µs..10s
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Counter:
    """Monotonically-increasing count (with an explicit ``reset`` for
    per-serve accounting like the scheduler's spec counters)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written value (utilizations, hit rates, report fields)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` observations <= buckets[i],
    plus an overflow bucket, running sum and count."""
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("histogram buckets must be sorted and unique")
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def to_dict(self) -> dict:
        return dict(buckets=list(self.buckets), counts=list(self.counts),
                    sum=self.sum, count=self.count)


class _NoopCounter:
    __slots__ = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def reset(self) -> None:
        pass


class _NoopGauge:
    __slots__ = ()
    value = 0.0

    def set(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass


class _NoopHistogram:
    __slots__ = ()
    buckets: Tuple[float, ...] = ()
    sum = 0.0
    count = 0

    def observe(self, v: float) -> None:
        pass

    def reset(self) -> None:
        pass

    def to_dict(self) -> dict:
        return dict(buckets=[], counts=[], sum=0.0, count=0)


NOOP_COUNTER = _NoopCounter()
NOOP_GAUGE = _NoopGauge()
NOOP_HISTOGRAM = _NoopHistogram()


def metric_key(name: str, labels: Dict[str, object]) -> str:
    """Stable snapshot key: ``name{k=v,...}`` with labels sorted."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """One metrics registry per process (or per supervisor — the fleet
    shares the supervisor's). Disabled registries hand out shared no-op
    instruments and snapshot to an explicitly-empty dict."""

    def __init__(self, enabled: bool = True, clock=None) -> None:
        self.enabled = enabled
        self.clock = clock if clock is not None else MonotonicClock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------- instruments
    def counter(self, name: str, **labels) -> Counter:
        if not self.enabled:
            return NOOP_COUNTER
        k = metric_key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        if not self.enabled:
            return NOOP_GAUGE
        k = metric_key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge()
        return g

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        if not self.enabled:
            return NOOP_HISTOGRAM
        k = metric_key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(buckets)
        return h

    def register_counter(self, name: str, counter: Counter,
                         **labels) -> Counter:
        """Adopt an EXISTING counter object under this registry's key —
        the component keeps its handle, the snapshot sees its live value,
        and no count is ever copied between two storage locations."""
        if self.enabled and not isinstance(counter, _NoopCounter):
            self._counters[metric_key(name, labels)] = counter
        return counter

    # --------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """JSON-serializable view of every instrument. Disabled
        registries report themselves as such rather than pretending an
        empty system."""
        if not self.enabled:
            return dict(enabled=False)
        return dict(
            enabled=True,
            ts=round(float(self.clock.now()), 6),
            counters={k: self._counters[k].value
                      for k in sorted(self._counters)},
            gauges={k: self._gauges[k].value
                    for k in sorted(self._gauges)},
            histograms={k: self._histograms[k].to_dict()
                        for k in sorted(self._histograms)},
        )

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=1)

    def reset(self) -> None:
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for h in self._histograms.values():
            h.reset()


# Process-global default registry for process-global state: the quant
# dispatch log (``quant.apply``) is a module-level accumulator shared by
# every engine in the process, so its counters live here rather than in
# any one supervisor's registry.
_DEFAULT_REGISTRY = Registry()


def default_registry() -> Registry:
    return _DEFAULT_REGISTRY
