"""Schema check for emitted observability artifacts (the CI gate step).

    PYTHONPATH=src python -m repro.obs.check --trace trace.json \
        --metrics metrics.json

Validates a Chrome trace-event JSON against the structural schema
(``obs.trace.validate_chrome_trace``) and a ``--metrics-json`` snapshot
against the registry shape (counters/gauges numeric, histogram dicts
well-formed). Exit 0 = valid, 1 = problems (listed), 2 = unreadable.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .trace import validate_chrome_trace


def validate_metrics_snapshot(obj) -> List[str]:
    """Structural check for ``Registry.snapshot()`` JSON."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    if obj.get("enabled") is False:
        return errors  # a disabled registry snapshots to {"enabled": false}
    if obj.get("enabled") is not True:
        errors.append("missing enabled flag")
    for section in ("counters", "gauges"):
        vals = obj.get(section)
        if not isinstance(vals, dict):
            errors.append(f"{section} is not an object")
            continue
        for k, v in vals.items():
            if not isinstance(v, (int, float)):
                errors.append(f"{section}[{k}]: non-numeric value {v!r}")
    hists = obj.get("histograms")
    if not isinstance(hists, dict):
        errors.append("histograms is not an object")
    else:
        for k, h in hists.items():
            if not isinstance(h, dict) or not isinstance(
                    h.get("counts"), list):
                errors.append(f"histograms[{k}]: malformed")
                continue
            if sum(h["counts"]) != h.get("count"):
                errors.append(f"histograms[{k}]: bucket counts do not sum "
                              f"to count")
    return errors


def _check(path: str, validator, what: str) -> List[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SystemExit(f"[obs.check] cannot read {what} {path}: {e}")
    return [f"{what} {path}: {e}" for e in validator(obj)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default=None,
                    help="registry snapshot JSON to validate")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")
    errors: List[str] = []
    if args.trace:
        errors += _check(args.trace, validate_chrome_trace, "trace")
    if args.metrics:
        errors += _check(args.metrics, validate_metrics_snapshot, "metrics")
    for e in errors:
        print(f"[obs.check] INVALID: {e}")
    if not errors:
        print("[obs.check] OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
