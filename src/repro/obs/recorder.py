"""Flight recorder: a bounded ring of recent events per process, dumped
to disk when something dies.

Recording is always cheap (append to a ``deque(maxlen=...)``); nothing
is written to disk until ``dump(reason)`` — which the supervisor calls on
``SupervisorCrash``, ``CacheCorruptionError``, worker EOF and
reconciliation failure — so a crashed chaos run leaves a post-mortem
artifact (``flight-<reason>-<seq>.json`` in ``dir``) while healthy runs
write nothing. With ``dir=None`` the ring still records (it is the
in-memory black box) but dumps are skipped, keeping test suites and
default CLI runs from littering the working directory.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import List, Optional

from .metrics import MonotonicClock


class FlightRecorder:
    def __init__(self, capacity: int = 256, clock=None,
                 dir: Optional[str] = None, enabled: bool = True) -> None:
        self.capacity = int(capacity)
        self.clock = clock if clock is not None else MonotonicClock()
        self.dir = dir
        self.enabled = enabled
        self.events = deque(maxlen=self.capacity)
        self.dumps: List[str] = []
        self._seq = 0

    def record(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        fields["t"] = round(float(self.clock.now()), 6)
        fields["kind"] = kind
        self.events.append(fields)

    def dump(self, reason: str, dir: Optional[str] = None) -> Optional[str]:
        """Write the ring to ``<dir>/flight-<reason>-<seq>.json`` and
        return the path (None when disabled or no directory is
        configured)."""
        d = dir if dir is not None else self.dir
        if not self.enabled or d is None:
            return None
        self._seq += 1
        path = os.path.join(str(d), f"flight-{reason}-{self._seq}.json")
        payload = dict(reason=reason,
                       dumped_at=round(float(self.clock.now()), 6),
                       n_events=len(self.events),
                       events=list(self.events))
        with open(path, "w") as f:
            json.dump(payload, f, sort_keys=True, indent=1)
        self.dumps.append(path)
        return path
