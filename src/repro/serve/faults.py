"""Deterministic fault injection for the serving runtime.

Production fault tolerance is untestable without a way to *cause* faults
on demand: a supervisor that has never seen a replica die in CI will die
with it in deployment. This module makes every failure mode the serving
stack claims to survive schedulable at exact coordinates:

  * **FaultPlan** — a list of named ``FaultSpec``s, each pinned to a
    (step, site, replica) coordinate: ``exception`` (an engine step
    raises), ``corrupt_cache`` (NaN-poison one slot's KV region — caught
    by the scheduler's NaN guard, never sampled into tokens),
    ``straggler`` (an injected delay, advancing the injected clock so
    straggler detection is deterministic), and checkpoint-write kills
    (an ``exception`` at site ``checkpoint``, fired inside the
    Checkpointer's background write between shard write and COMMIT).
    Plus a seeded **random mode**: with ``seed``/``rate``/``n_random``
    set, each hook-point query draws from a per-replica PRNG — chaos
    testing that is still bitwise-reproducible per seed.
  * **FaultInjector** — the per-replica view of a plan. The scheduler
    calls ``begin_step()`` once per step and threads ``check(site,
    cache)`` through its hook points (the Engine's public
    ``prefill_slot_chunk``/``decode_slots`` wrappers call the same hook),
    so a fault fires exactly where a real one would: inside the step.
    Specs are one-shot — a restarted replica does not re-trip the same
    coordinate forever — and the step counter is replica-lifetime
    monotonic across restarts.
  * **Clock / VirtualClock** — every time source in the fault-tolerant
    serving path (arrival replay, deadlines, heartbeats, backoff) is an
    injectable clock. ``VirtualClock`` only advances when slept, so
    deadline-at-chunk-boundary and straggler-detection tests are exact,
    not sleep-and-hope.

Faults injected here are indistinguishable from real ones to the
supervisor — it sees an exception / NaN / slow step, not a test flag.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class InjectedFault(RuntimeError):
    """Raised by the injector at an ``exception`` coordinate."""


class CacheCorruptionError(RuntimeError):
    """Raised by the scheduler's NaN guard when a slot's logits are
    non-finite — corrupted state must never be sampled into tokens."""


# --------------------------------------------------------------- clocks
class Clock:
    """Injectable time source; the default wraps the monotonic clock."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, s: float) -> None:
        if s > 0:
            time.sleep(s)


class VirtualClock(Clock):
    """Deterministic clock: time advances ONLY via sleep()/advance().
    Straggler delays and deadline expiries become exact coordinates
    instead of wall-clock races."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def sleep(self, s: float) -> None:
        self._t += max(0.0, float(s))

    def advance(self, s: float) -> None:
        self._t += float(s)


# ----------------------------------------------------------------- plan
KINDS = ("exception", "corrupt_cache", "straggler")
# process-level kinds (cross-process fleet; driven SUPERVISOR-side so a
# chaos replay is deterministic — the worker never rolls its own dice):
#   sigkill          — SIGKILL the worker process (inproc: hard failure)
#   sigterm          — SIGTERM: graceful drain (finish assigned work,
#                      reject new submits, exit 0)
#   partition        — drop the next ``arg`` RPC attempts in transport
#                      (alternating request-lost / reply-lost)
#   slowpipe         — stall the next RPC by ``arg`` seconds
#   supervisor_crash — the supervisor itself dies at tick ``step``
#                      (journal flushed first: a SIGKILL mid-fsync is the
#                      torn-tail test's job, not this coordinate's)
PROC_KINDS = ("sigkill", "sigterm", "partition", "slowpipe",
              "supervisor_crash")
SITES = ("step", "prefill", "decode", "verify", "checkpoint", "transport")
# random mode never draws corrupt_cache: a corruption landing on a free
# slot is unobservable, and a silent fault would make the chaos suite
# vacuous for that draw.
RANDOM_KINDS = ("exception", "straggler")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault at an exact (step, site, replica) coordinate.

    ``step`` counts a replica's lifetime hook steps (monotonic across
    restarts); ``site`` is the hook point; ``replica`` selects which
    injector fires (the supervisor's own hooks — checkpoint writes — use
    replica=-1). ``delay_s`` is the straggler stall; ``slot`` the
    corruption target."""
    kind: str
    step: int
    site: str = "decode"
    replica: int = 0
    delay_s: float = 0.0
    slot: int = 0
    arg: float = 0.0            # partition: RPC attempts to drop;
                                # slowpipe: stall seconds

    def __post_init__(self):
        if self.kind not in KINDS + PROC_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS + PROC_KINDS})")
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(one of {SITES})")


class FaultPlan:
    """A schedule of faults, plus an optional seeded random mode.

    ``parse`` accepts the CLI format: comma-separated
    ``kind@step[:site[:replica[:arg]]]`` entries, where ``arg`` is the
    straggler/slowpipe delay (seconds), the corruption slot, or the
    partition's dropped-attempt count — e.g.
    ``exception@3:decode:0,straggler@5:step:1:2.0``,
    ``sigkill@8:step:0,partition@4:transport:1:4,supervisor_crash@12``.
    Process-level kinds (``PROC_KINDS``) pin to the same grammar:
    ``step`` counts the replica's lifetime step *attempts* for worker
    kinds and the supervisor's tick for ``supervisor_crash`` (whose
    replica defaults to -1 — the supervisor's own coordinate space).
    Random mode rides as ``random@seed:rate:n`` (rate in [0,1], n = max
    faults drawn)."""

    def __init__(self, faults: Sequence[FaultSpec] = (),
                 seed: Optional[int] = None, rate: float = 0.0,
                 n_random: int = 0):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self.seed = seed
        self.rate = float(rate)
        self.n_random = int(n_random)

    def __bool__(self) -> bool:
        return bool(self.faults) or self.n_random > 0

    def injector(self, replica: int, clock: Optional[Clock] = None
                 ) -> "FaultInjector":
        return FaultInjector(self, replica, clock or Clock())

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        faults: List[FaultSpec] = []
        seed, rate, n_random = None, 0.0, 0
        for part in filter(None, (p.strip() for p in spec.split(","))):
            head, _, rest = part.partition("@")
            if not rest:
                raise ValueError(f"fault entry {part!r}: expected "
                                 "kind@step[:site[:replica[:arg]]]")
            fields = rest.split(":")
            if head == "random":
                seed = int(fields[0])
                rate = float(fields[1]) if len(fields) > 1 else 0.5
                n_random = int(fields[2]) if len(fields) > 2 else 1
                continue
            kw = dict(kind=head, step=int(fields[0]))
            if head in ("partition", "slowpipe"):
                kw["site"] = "transport"
            elif head in PROC_KINDS:
                kw["site"] = "step"
            if head == "supervisor_crash":
                kw["replica"] = -1
            if len(fields) > 1:
                kw["site"] = fields[1]
            if len(fields) > 2:
                kw["replica"] = int(fields[2])
            if len(fields) > 3:
                if head in ("straggler", "slowpipe"):
                    kw["delay_s"] = float(fields[3])
                elif head == "partition":
                    kw["arg"] = float(fields[3])
                else:
                    kw["slot"] = int(fields[3])
            faults.append(FaultSpec(**kw))
        return cls(faults, seed=seed, rate=rate, n_random=n_random)

    def proc_faults(self, replica: int) -> List[FaultSpec]:
        """Worker-process-level specs for one replica — driven by the
        supervisor before the replica's step, never by the worker."""
        return [f for f in self.faults
                if f.kind in PROC_KINDS and f.kind != "supervisor_crash"
                and f.replica == replica]

    def supervisor_crashes(self) -> List[FaultSpec]:
        """``supervisor_crash`` specs (tick-coordinate, replica -1)."""
        return [f for f in self.faults if f.kind == "supervisor_crash"]


class FaultInjector:
    """Per-replica view of a FaultPlan, threaded through the scheduler's
    and Engine's hook points. ``check(site, cache)`` either returns the
    cache untouched, returns a NaN-poisoned copy (``corrupt_cache``),
    stalls the injected clock (``straggler``), or raises
    ``InjectedFault`` (``exception``)."""

    def __init__(self, plan: FaultPlan, replica: int, clock: Clock):
        self.plan = plan
        self.replica = replica
        self.clock = clock
        self.step = -1             # advanced by begin_step()
        self.fired: List[FaultSpec] = []
        # engine-level kinds only: PROC_KINDS are driven supervisor-side
        # (a worker rebuilt after a sigkill gets a fresh injector whose
        # step offset the supervisor sets — see serve.worker)
        self._pending = [f for f in plan.faults
                         if f.replica == replica and f.kind in KINDS]
        self._rng = (np.random.default_rng(
            np.random.SeedSequence([plan.seed, replica + 1]))
            if plan.seed is not None else None)
        self._random_left = plan.n_random if self._rng is not None else 0

    def begin_step(self) -> None:
        """Called once per scheduler step; replica-lifetime monotonic
        (NOT reset on restart, so a one-shot coordinate cannot re-trip
        the rebuilt replica forever)."""
        self.step += 1

    def _draw(self, site: str) -> Optional[FaultSpec]:
        if self._random_left <= 0 or self._rng is None:
            return None
        if self._rng.random() >= self.plan.rate:
            return None
        self._random_left -= 1
        kind = RANDOM_KINDS[int(self._rng.integers(len(RANDOM_KINDS)))]
        return FaultSpec(kind=kind, step=self.step, site=site,
                         replica=self.replica,
                         delay_s=float(self._rng.uniform(0.5, 3.0)))

    def check(self, site: str, cache=None):
        """Hook point: fire any spec scheduled at (this step, site).
        Returns the (possibly corrupted) cache; may sleep or raise."""
        spec = next((f for f in self._pending
                     if f.step == self.step and f.site == site), None)
        if spec is not None:
            self._pending.remove(spec)
        else:
            spec = self._draw(site)
        if spec is None:
            return cache
        self.fired.append(spec)
        if spec.kind == "straggler":
            self.clock.sleep(spec.delay_s)
            return cache
        if spec.kind == "corrupt_cache":
            return cache if cache is None \
                else corrupt_slot_cache(cache, spec.slot)
        raise InjectedFault(
            f"injected {spec.kind} at step={spec.step} site={site} "
            f"replica={spec.replica}")


def corrupt_slot_cache(cache, slot: int):
    """NaN-poison one slot's region of the decode cache (leaves are
    (L, B, S, ...) — the slot axis is axis 1). Float leaves only: the
    int8 KV codes cannot hold NaN, but their scales can, and NaN scale
    poisons the dequant exactly like a poisoned fp cache."""
    def poison(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.at[:, slot].set(jnp.nan)
        return x
    return jax.tree.map(poison, cache)
