"""Framed RPC transport for the cross-process serving fleet.

The supervisor talks to each ``serve.worker`` subprocess over a pair of
pipes carrying length-prefixed, CRC32-checked JSON frames::

    <u32 payload_len><u32 crc32(payload)><payload bytes>

Framing survives exactly the failures a process fleet sees:

  * **Torn reads** — a recv deadline that fires mid-frame leaves the
    partial bytes buffered; the next recv resumes where it stopped, so a
    slow worker never desynchronizes the stream. Only EOF (peer died) or
    a CRC/oversize mismatch (stream corrupt) is fatal.
  * **Typed retryability** — every failure surfaces as
    ``TransportError(retryable=...)``: deadlines and injected partition
    drops are retryable; EOF, broken pipes and corrupt frames are not
    (the process behind the pipe is gone — respawn, don't retry).
  * **Idempotent retries** — ``RPCClient`` stamps every call with a
    monotonically increasing id and retries retryable failures under a
    seeded exponential backoff (``distributed.fault.backoff_delay``)
    bounded by ``tolerance_s``. The worker caches its last reply by call
    id and *retransmits instead of re-executing* on a duplicate id, so a
    reply lost to a partition never double-executes a step (which would
    duplicate streamed tokens). Stale replies from earlier attempts are
    discarded by id mismatch.
  * **Injected partitions** — ``arm_partition(n)`` drops the next ``n``
    call attempts supervisor-side, alternating request-lost / reply-lost
    so both halves of the idempotency contract are exercised;
    ``arm_slowpipe(s)`` stalls the next call (straggler-via-transport).
    Both are driven by the supervisor's fault plan, never by the worker,
    so chaos replays stay deterministic.

``WorkerError`` (a method raised *inside* the worker — an injected
engine fault, a NaN guard) is deliberately NOT a ``TransportError``:
the pipe is healthy, the replica failed; the supervisor routes it
through the same salvage-and-respawn path as a crash.
"""
from __future__ import annotations

import dataclasses
import json
import os
import select
import struct
import time
import zlib
from typing import Optional

import numpy as np

from ..distributed.fault import backoff_delay

_HEADER = struct.Struct("<II")
MAX_FRAME = 1 << 26             # 64 MB: anything larger is a desync


class TransportError(RuntimeError):
    """A transport-layer failure. ``retryable=True`` means the frame may
    simply be late (deadline, injected drop) — retry with the same call
    id; ``False`` means the peer or the stream is gone — respawn."""

    def __init__(self, msg: str, *, retryable: bool = False):
        super().__init__(msg)
        self.retryable = retryable


class WorkerError(RuntimeError):
    """The worker executed the call and raised: a replica failure
    (injected fault, NaN guard, real bug) reported over a healthy pipe."""


def encode_frame(obj) -> bytes:
    payload = json.dumps(obj, separators=(",", ":")).encode()
    return _HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def _write_all(fd: int, data: bytes) -> None:
    view = memoryview(data)
    while view:
        view = view[os.write(fd, view):]


class FramedConnection:
    """One duplex frame stream over raw file descriptors (pipe ends).

    Reads are buffered and deadline-aware via ``select``; a timeout
    mid-frame preserves the partial bytes (stream stays in sync).
    Writes are atomic-from-the-caller's-view via a full-write loop."""

    def __init__(self, read_fd: int, write_fd: int):
        self._rfd = read_fd
        self._wfd = write_fd
        self._buf = bytearray()
        self.frames_sent = 0
        self.frames_received = 0

    # ------------------------------------------------------------- sending
    def send(self, obj) -> None:
        try:
            _write_all(self._wfd, encode_frame(obj))
        except (BrokenPipeError, OSError, ValueError) as e:
            raise TransportError(f"send failed (peer pipe closed?): {e!r}",
                                 retryable=False) from e
        self.frames_sent += 1

    # ----------------------------------------------------------- receiving
    def _fill(self, n: int, deadline: Optional[float]) -> None:
        """Grow the buffer to >= n bytes or raise. Deadline -> retryable
        (bytes read so far stay buffered); EOF -> fatal."""
        while len(self._buf) < n:
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"recv deadline ({n - len(self._buf)} bytes still "
                        "outstanding)", retryable=True)
                ready, _, _ = select.select([self._rfd], [], [], remaining)
                if not ready:
                    raise TransportError(
                        f"recv deadline ({n - len(self._buf)} bytes still "
                        "outstanding)", retryable=True)
            try:
                chunk = os.read(self._rfd, 1 << 16)
            except OSError as e:
                raise TransportError(f"recv failed: {e!r}",
                                     retryable=False) from e
            if not chunk:
                raise TransportError("peer closed the pipe (EOF)",
                                     retryable=False)
            self._buf.extend(chunk)

    def recv(self, timeout: Optional[float] = None) -> dict:
        deadline = None if timeout is None else time.monotonic() + timeout
        self._fill(_HEADER.size, deadline)
        n, crc = _HEADER.unpack(bytes(self._buf[:_HEADER.size]))
        if n > MAX_FRAME:
            raise TransportError(
                f"oversized frame ({n} bytes): stream desynchronized",
                retryable=False)
        self._fill(_HEADER.size + n, deadline)
        payload = bytes(self._buf[_HEADER.size:_HEADER.size + n])
        del self._buf[:_HEADER.size + n]
        if zlib.crc32(payload) != crc:
            raise TransportError("frame CRC mismatch: stream corrupt",
                                 retryable=False)
        self.frames_received += 1
        try:
            return json.loads(payload)
        except json.JSONDecodeError as e:
            raise TransportError(f"frame payload not JSON: {e}",
                                 retryable=False) from e


@dataclasses.dataclass
class TransportConfig:
    call_timeout_s: float = 30.0    # per-attempt recv deadline
    tolerance_s: float = 5.0        # total retry budget (partition
                                    # tolerance): past it the call fails
                                    # non-retryably and the replica is
                                    # declared dead
    backoff_base_s: float = 0.02
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.25
    seed: int = 0


class RPCClient:
    """Supervisor-side call surface over one FramedConnection."""

    def __init__(self, conn: FramedConnection,
                 cfg: TransportConfig = TransportConfig()):
        self.conn = conn
        self.cfg = cfg
        self._next_id = 0
        self.retries = 0
        # set by a tracing supervisor: stamps every call frame with "tr"
        # so worker-side spans stitch into the supervisor's timeline
        self.trace_id: Optional[str] = None
        self._rng = np.random.default_rng(cfg.seed)
        self._partition_left = 0
        self._partition_phase = 0
        self._slow_s = 0.0
        self.slow_events = 0

    # ------------------------------------------------------ fault injection
    def arm_partition(self, n_calls: int) -> None:
        """Drop the next ``n_calls`` call attempts (alternating
        request-lost / reply-lost). The worker's reply cache makes the
        eventual retry idempotent."""
        self._partition_left += max(0, int(n_calls))

    def arm_slowpipe(self, delay_s: float) -> None:
        """Stall the next call attempt by ``delay_s`` (real sleep: the
        supervisor's health monitor sees a genuinely slow step)."""
        self._slow_s = max(self._slow_s, float(delay_s))

    # -------------------------------------------------------------- calling
    @property
    def frames_sent(self) -> int:
        return self.conn.frames_sent

    def call(self, method: str, params: Optional[dict] = None,
             timeout: Optional[float] = None):
        """One RPC with retryable-failure backoff bounded by
        ``tolerance_s``. Raises ``WorkerError`` if the worker's handler
        raised, ``TransportError(retryable=False)`` if the pipe/budget is
        gone."""
        cid = self._next_id
        self._next_id += 1
        frame = {"t": "call", "id": cid, "m": method, "p": params or {}}
        if self.trace_id is not None:
            frame["tr"] = self.trace_id
        per_attempt = self.cfg.call_timeout_s if timeout is None else timeout
        deadline = time.monotonic() + self.cfg.tolerance_s + per_attempt
        attempt = 0
        while True:
            try:
                return self._attempt(frame, cid, per_attempt)
            except TransportError as e:
                if not e.retryable:
                    raise
                self.retries += 1
                delay = backoff_delay(attempt, self.cfg.backoff_base_s,
                                      self.cfg.backoff_factor,
                                      self.cfg.backoff_jitter, self._rng)
                attempt += 1
                if time.monotonic() + delay > deadline:
                    raise TransportError(
                        f"call {method!r} exceeded partition tolerance "
                        f"({self.cfg.tolerance_s}s, {attempt} attempts): "
                        f"{e}", retryable=False) from e
                time.sleep(delay)

    def _attempt(self, frame: dict, cid: int, timeout: float):
        if self._slow_s > 0:
            s, self._slow_s = self._slow_s, 0.0
            self.slow_events += 1
            time.sleep(s)
        if self._partition_left > 0:
            self._partition_left -= 1
            self._partition_phase ^= 1
            if self._partition_phase == 1:
                # request frame lost: the worker never sees this attempt
                raise TransportError(
                    "partition: request frame dropped (injected)",
                    retryable=True)
            # reply frame lost: the worker EXECUTES the call, we never
            # read the answer — the retry must hit the reply cache
            self.conn.send(frame)
            raise TransportError(
                "partition: reply frame dropped (injected)", retryable=True)
        self.conn.send(frame)
        while True:
            reply = self.conn.recv(timeout=timeout)
            if reply.get("t") == "reply" and reply.get("id") == cid:
                break
            # stale reply from an earlier dropped attempt: discard by id
        if not reply.get("ok", False):
            raise WorkerError(reply.get("err", "unknown worker error"))
        return reply.get("r")
