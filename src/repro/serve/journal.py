"""Durable request journal: the supervisor's write-ahead log.

PR 6's exactly-once guarantee lived in the in-memory ``_Book`` — it died
with the supervisor. This journal makes that bookkeeping durable enough
to survive a supervisor SIGKILL:

  * **Append-only, CRC-per-record** — each record is a JSON object in
    the same ``<u32 len><u32 crc32>`` framing as ``serve.transport``;
    appends buffer in the file object and ``flush()`` (called once per
    supervisor tick) does one write + fsync, so durability costs one
    syscall batch per tick, not per token.
  * **Torn-tail truncation** — a crash mid-append leaves a partial or
    CRC-broken record at the tail. Opening the journal scans it, keeps
    the longest valid prefix, truncates the rest (counted, never
    silent), and raises ``JournalCorruptionError`` only for corruption
    *inside* the valid region (a bad CRC followed by good records means
    disk damage, not a torn write).
  * **Sealed manifest** — ``seal()`` writes ``<path>.manifest.json``
    with the sha256 + byte count of the log prefix (the
    ``checkpoint.checkpointer.digest_bytes`` discipline). Re-opening
    verifies the sealed prefix before trusting it; records past the seal
    are covered by their per-record CRCs.

Record types (all carry ``"t"``):

    {"t": "admit", "id", "prompt": [...], "new", "dl", "arr"}
    {"t": "emit",  "id", "i": first_index, "toks": [...]}
    {"t": "term",  "id", "st": "ok|timeout|rejected|failed"}

A tracing supervisor additionally stamps records with ``"tr"`` (its
trace id) so a journal can be matched to the Perfetto timeline of the
run that wrote it; ``replay_state`` ignores unknown fields, so journals
from traced and untraced runs replay identically.

``replay_state`` folds a record list into per-request recovery state:
prompt, emitted prefix, terminal status (or None). Emit records are
idempotent under replay — an overlap re-delivers the same tokens at the
same indices (verified; a mismatch or a gap is corruption). On recovery
the supervisor re-admits every non-terminal request as
``prompt + emitted`` — greedy decode then continues the token stream
bitwise-identically, and clients are re-synced with the journaled prefix
via ``on_replay`` (exactly-once across supervisor death).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import struct
import zlib
from typing import Dict, List, Optional, Tuple

from ..checkpoint.checkpointer import digest_bytes
from ..obs.metrics import Counter, Registry

_REC = struct.Struct("<II")


class JournalCorruptionError(RuntimeError):
    """The journal's valid region (sealed prefix, or records before the
    tail) failed verification — refusing to rebuild serving state from
    corrupt bookkeeping."""


def encode_record(rec: dict) -> bytes:
    payload = json.dumps(rec, separators=(",", ":")).encode()
    return _REC.pack(len(payload), zlib.crc32(payload)) + payload


def scan_records(data: bytes) -> Tuple[List[dict], int]:
    """Parse the longest valid record prefix of ``data``; returns
    (records, good_end). A partial/CRC-broken record at the very tail is
    a torn write (good_end stops before it); the same breakage followed
    by MORE parseable bytes would also stop there — the caller decides
    whether that region was sealed (corruption) or tail (truncate)."""
    records: List[dict] = []
    off = 0
    n = len(data)
    while off + _REC.size <= n:
        length, crc = _REC.unpack_from(data, off)
        end = off + _REC.size + length
        if length > (1 << 26) or end > n:
            break
        payload = data[off + _REC.size:end]
        if zlib.crc32(payload) != crc:
            break
        try:
            records.append(json.loads(payload))
        except json.JSONDecodeError:
            break
        off = end
    return records, off


class Journal:
    """Append handle + recovery scan over one journal file.

    Opening an existing journal IS the recovery: the constructor
    verifies the sealed manifest (if any), scans records, truncates a
    torn tail in place, and leaves the parsed records in ``recovered``
    for ``Supervisor.resume``."""

    def __init__(self, path, *, fsync: bool = True):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        # accounting lives in registry counters (the journal's own until a
        # supervisor adopts them via bind_registry) so every drain report
        # and --metrics-json snapshot reads the SAME storage
        self._c_records = Counter()
        self._c_bytes = Counter()
        self._c_fsyncs = Counter()
        self._c_truncated = Counter()
        self.bind_registry(Registry())
        self._dirty = False
        data = self.path.read_bytes() if self.path.exists() else b""
        sealed = self._verify_manifest(data)
        self.recovered, good_end = scan_records(data)
        if good_end < sealed:
            raise JournalCorruptionError(
                f"{self.path}: record breakage at byte {good_end} inside "
                f"the sealed prefix ({sealed} bytes) — manifest says those "
                "bytes were durable; this is corruption, not a torn tail")
        if good_end < len(data):
            self._c_truncated.inc(len(data) - good_end)
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
        self._c_records.inc(len(self.recovered))
        self._c_bytes.inc(good_end)
        self._fp = open(self.path, "ab")

    def bind_registry(self, registry: Registry, **labels) -> None:
        """Re-register this journal's live counters in ``registry`` (the
        supervisor calls this with its fleet registry): counts are never
        copied, the snapshot simply sees the same objects."""
        registry.register_counter("journal.records", self._c_records,
                                  **labels)
        registry.register_counter("journal.bytes", self._c_bytes, **labels)
        registry.register_counter("journal.fsyncs", self._c_fsyncs, **labels)
        registry.register_counter("journal.truncated_bytes",
                                  self._c_truncated, **labels)

    # registry-backed views (the old attribute API)
    @property
    def records(self) -> int:
        return self._c_records.value

    @property
    def bytes(self) -> int:
        return self._c_bytes.value

    @property
    def fsyncs(self) -> int:
        return self._c_fsyncs.value

    @property
    def truncated_bytes(self) -> int:
        return self._c_truncated.value

    @property
    def manifest_path(self) -> pathlib.Path:
        return self.path.with_name(self.path.name + ".manifest.json")

    def _verify_manifest(self, data: bytes) -> int:
        """Returns the sealed byte count (0 if never sealed). The sealed
        prefix must be present and hash-identical."""
        mp = self.manifest_path
        if not mp.exists():
            return 0
        try:
            manifest = json.loads(mp.read_text())
        except json.JSONDecodeError as e:
            raise JournalCorruptionError(
                f"{mp}: unreadable manifest: {e}") from e
        sealed = int(manifest.get("bytes", 0))
        if len(data) < sealed:
            raise JournalCorruptionError(
                f"{self.path}: journal shorter than its sealed manifest "
                f"({len(data)} < {sealed} bytes)")
        got = digest_bytes(data[:sealed])
        if got["sha256"] != manifest.get("sha256"):
            raise JournalCorruptionError(
                f"{self.path}: sealed prefix failed sha256 verification "
                "— refusing to rebuild state from corrupt bookkeeping")
        return sealed

    # -------------------------------------------------------------- writing
    def append(self, rec: dict) -> None:
        data = encode_record(rec)
        self._fp.write(data)
        self._c_records.inc()
        self._c_bytes.inc(len(data))
        self._dirty = True

    def flush(self) -> None:
        """One write + fsync for everything appended since the last
        flush — the supervisor calls this once per tick, so the fsync
        cost amortizes over the tick's token batch."""
        if not self._dirty:
            return
        self._fp.flush()
        if self.fsync:
            os.fsync(self._fp.fileno())
            self._c_fsyncs.inc()
        self._dirty = False

    def seal(self) -> None:
        """Flush, then record the durable prefix's digest in the
        manifest (tmp + rename: a crash mid-seal keeps the old one)."""
        self.flush()
        tmp = self.manifest_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            dict(records=self.records,
                 **digest_bytes(self.path.read_bytes()))))
        tmp.rename(self.manifest_path)

    def close(self, *, seal: bool = True) -> None:
        """``seal=False`` closes without writing a manifest — modelling a
        writer that died before its clean shutdown."""
        if not self._fp.closed:
            if seal:
                self.seal()
            else:
                self.flush()
            self._fp.close()


@dataclasses.dataclass
class ReplayEntry:
    """Recovered per-request state."""
    prompt: List[int]
    max_new_tokens: int
    deadline_s: Optional[float]
    arrival: float
    emitted: List[int] = dataclasses.field(default_factory=list)
    status: Optional[str] = None


def replay_state(records: List[dict]) -> Dict[int, ReplayEntry]:
    """Fold journal records into per-request recovery state. Emit
    overlaps (same tokens re-journaled at the same indices) are
    idempotent; a token mismatch or an index gap is corruption."""
    state: Dict[int, ReplayEntry] = {}
    for rec in records:
        t = rec.get("t")
        if t == "admit":
            state[rec["id"]] = ReplayEntry(
                prompt=list(rec["prompt"]),
                max_new_tokens=int(rec["new"]),
                deadline_s=rec.get("dl"),
                arrival=float(rec.get("arr", 0.0)))
        elif t == "emit":
            e = state.get(rec["id"])
            if e is None:
                raise JournalCorruptionError(
                    f"emit for unknown request {rec['id']}")
            i0, toks = int(rec["i"]), list(rec["toks"])
            if i0 > len(e.emitted):
                raise JournalCorruptionError(
                    f"request {rec['id']}: emit gap (have "
                    f"{len(e.emitted)} tokens, record starts at {i0})")
            overlap = len(e.emitted) - i0
            if e.emitted[i0:] != toks[:overlap]:
                raise JournalCorruptionError(
                    f"request {rec['id']}: emit overlap mismatch at {i0}")
            e.emitted.extend(toks[overlap:])
        elif t == "term":
            e = state.get(rec["id"])
            if e is None:
                raise JournalCorruptionError(
                    f"terminal status for unknown request {rec['id']}")
            e.status = rec["st"]
    return state
