"""Serving supervisor: N scheduler-backed replicas behind one shared
admission queue, with supervised restart and zero dropped requests.

The scheduler (``serve.scheduler``) made one engine continuous; this
module makes a fleet of them survivable. One deterministic thread drives
every replica's ``step()`` round-robin, so a chaos test with a virtual
clock replays bit-identically — there is no race to lose a request in.

Failure model and recovery:

  * A replica **fails** when its step raises — a real exception, an
    injected one (``serve.faults``), or the scheduler's NaN guard
    refusing to sample from a corrupted cache. The supervisor salvages
    exactly what the replica held: queued requests re-enter the shared
    queue unchanged; **in-flight requests are re-admitted as
    ``prompt + tokens_emitted_so_far``** — greedy decode makes the
    continuation bitwise-identical to an uninterrupted run, and because
    the already-emitted tokens ride in the resume *prompt*, replay can
    never re-stream them (exactly-once streaming by construction). A
    replica killed mid-speculative-window salvages at the last
    *accepted* token: draft tokens only enter ``tokens_emitted`` after
    the verify pass confirms them, so a kill at the verify step (fault
    site ``verify``) resumes from exactly the non-speculative state.
  * The replica is **rebuilt** after a seeded exponential backoff
    (``distributed.fault.backoff_delay``): a fresh cache via
    ``CacheBackend.start`` (inside ``scheduler.start`` — the paged
    backend rebuilds its page pool, page tables and prefix trie from
    scratch, and shared prefixes re-pin as the salvaged requests
    re-prefill), optionally reloading params from the checksum-verified
    latest checkpoint.
  * **Caps are terminal, never silent**: a replica exceeding
    ``max_restarts`` is retired from the fleet; a request re-admitted
    more than ``max_request_replays`` times (a poison pill that keeps
    killing replicas) ends with status ``failed`` — with whatever tokens
    it had; if every replica is dead, all remaining requests fail
    visibly. Every submitted request ends ``ok | timeout | rejected |
    failed`` — the report reconciles counts to zero drops.
  * **Health**: every replica step feeds
    ``distributed.fault.HealthMonitor.heartbeat``; its ``check`` flags
    stragglers from step-time quantiles (deterministic under the virtual
    clock via ``step_cost_s``), and ``restart_stragglers`` routes them
    through the same salvage-and-restart path as a crash.

Admission control lives at the shared queue: per-request ``deadline_s``
is enforced while queued (timeout before ever occupying a slot) and the
remaining budget rides into the replica for mid-flight expiry;
``queue_cap`` bounds arrived-but-unserved requests with explicit
``rejected`` load-shedding.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..distributed.fault import HealthMonitor, backoff_delay
from .engine import Engine, Request
from .faults import Clock, FaultPlan
from .scheduler import ContinuousScheduler


@dataclasses.dataclass
class SupervisorConfig:
    replicas: int = 2
    prefill_chunk: int = 32
    max_restarts: int = 3           # per replica; beyond -> replica retired
    max_request_replays: int = 3    # per request; beyond -> status "failed"
    backoff_base_s: float = 0.05    # exponential restart backoff
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.25
    seed: int = 0                   # backoff-jitter PRNG seed
    queue_cap: Optional[int] = None  # bound on arrived-but-unserved requests
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 4.0
    restart_stragglers: bool = False
    step_cost_s: float = 0.0        # synthetic per-step clock charge: makes
                                    # straggler/deadline tests deterministic
                                    # under a VirtualClock (0 = real timing)
    ckpt_every: int = 0             # checkpoint params every N ticks (0=off)


@dataclasses.dataclass
class Outcome:
    """Terminal per-request record, assembled across replays."""
    id: int
    tokens: List[int]
    status: str                 # ok | timeout | rejected | failed
    arrival_s: float            # supervisor-frame arrival
    ttft_s: float               # arrival -> first token (0.0 if none)
    finish_s: float             # arrival -> terminal
    replays: int = 0            # times re-admitted after a replica failure
    replica: int = -1           # replica that finished it (-1: never placed)


@dataclasses.dataclass
class SupervisorReport:
    outcomes: List[Outcome]
    submitted: int
    restarts: Dict[int, int]            # replica -> restart count
    failures: List[Tuple[int, str]]     # (replica, exception repr)
    straggler_events: int
    ckpt_failures: int
    wasted_tokens: int                  # positions recomputed after failures
    useful_tokens: int                  # prompt + generated across outcomes

    def status_counts(self) -> Counter:
        return Counter(o.status for o in self.outcomes)

    @property
    def zero_drops(self) -> bool:
        """Every submitted request reached exactly one terminal status."""
        return len(self.outcomes) == self.submitted and \
            len({o.id for o in self.outcomes}) == self.submitted

    @property
    def wasted_token_fraction(self) -> float:
        total = self.wasted_tokens + self.useful_tokens
        return self.wasted_tokens / total if total else 0.0


@dataclasses.dataclass
class _Book:
    """Supervisor-side truth for one request across replays."""
    req: Request
    arrival: float
    emitted: List[int] = dataclasses.field(default_factory=list)
    first_token_t: float = -1.0
    replays: int = 0
    done: bool = False


class _Replica:
    def __init__(self, rid: int, engine: Engine,
                 scheduler: ContinuousScheduler):
        self.id = rid
        self.engine = engine
        self.scheduler = scheduler
        self.alive = True
        self.dead = False           # restart cap exhausted
        self.restarts = 0
        self.restart_at = 0.0
        self.consumed = 0           # scheduler results already collected


class Supervisor:
    """Drives ``cfg.replicas`` engines from one shared admission queue.

    ``engine_factory()`` builds one Engine per replica (same model/params,
    its own trace cache). ``fault_plan`` threads a per-replica
    ``FaultInjector`` through each scheduler plus a host-side injector
    (replica=-1) into the checkpointer's write path. All timing reads the
    injectable ``clock``."""

    def __init__(self, engine_factory: Callable[[], Engine],
                 cfg: SupervisorConfig = SupervisorConfig(), *,
                 on_token: Optional[Callable[[int, int, bool], None]] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 clock: Optional[Clock] = None,
                 checkpointer=None,
                 monitor: Optional[HealthMonitor] = None):
        self.cfg = cfg
        self.clock = clock or Clock()
        self.on_token = on_token
        self.plan = fault_plan
        self.checkpointer = checkpointer
        self.monitor = monitor or HealthMonitor(
            n_hosts=cfg.replicas, timeout_s=cfg.heartbeat_timeout_s,
            straggler_factor=cfg.straggler_factor)
        self._rng = np.random.default_rng(cfg.seed)
        self._host_faults = fault_plan.injector(-1, self.clock) \
            if fault_plan else None
        if checkpointer is not None and self._host_faults is not None:
            checkpointer.fault_hook = self._host_faults.check
        self.replicas: List[_Replica] = []
        for rid in range(cfg.replicas):
            eng = engine_factory()
            inj = fault_plan.injector(rid, self.clock) if fault_plan else None
            sched = ContinuousScheduler(
                eng, prefill_chunk=cfg.prefill_chunk,
                on_token=lambda req_id, tok, done, rid=rid:
                    self._on_token(rid, req_id, tok, done),
                clock=self.clock, faults=inj, nan_guard=True)
            self.replicas.append(_Replica(rid, eng, sched))
        # per-serve state
        self._book: Dict[int, _Book] = {}
        self._future: List[Tuple[float, Request]] = []
        self._queue: Deque[Tuple[float, Request]] = deque()
        self._outcomes: List[Outcome] = []
        self._t0 = 0.0
        self._tick = 0
        self.failures: List[Tuple[int, str]] = []
        self.straggler_events = 0
        self.ckpt_failures = 0
        self.wasted_tokens = 0

    # ------------------------------------------------------------ callbacks
    def _on_token(self, rid: int, req_id: int, tok: int, done: bool) -> None:
        b = self._book[req_id]
        if b.first_token_t < 0:
            b.first_token_t = self._now()
        b.emitted.append(tok)
        if self.on_token is not None:
            # replayed tokens ride in the resume prompt, never re-emitted:
            # the stream the user sees is exactly-once by construction
            self.on_token(req_id, tok, done)

    def _now(self) -> float:
        return self.clock.now() - self._t0

    # -------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Request],
              arrivals: Optional[Sequence[float]] = None) -> SupervisorReport:
        cfg = self.cfg
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrivals must match requests 1:1")
        self._t0 = self.clock.now()
        self._tick = 0
        self._book = {}
        self._outcomes = []
        self._queue = deque()
        self._future = sorted(zip(map(float, arrivals), requests),
                              key=lambda t: t[0])
        submitted = len(requests)
        max_seq = self.replicas[0].engine.cfg.max_seq
        valid: List[Tuple[float, Request]] = []
        for arr, req in self._future:
            self._book[req.id] = _Book(req=req, arrival=arr)
            need = len(req.prompt) + req.max_new_tokens
            if len(req.prompt) < 1 or req.max_new_tokens < 1 or \
                    need > max_seq:
                # a fleet front-door cannot raise at a remote client:
                # invalid requests get an explicit rejected outcome
                self._finish(req.id, "rejected", replica=-1)
            else:
                valid.append((arr, req))
        self._future = valid
        for r in self.replicas:
            r.scheduler.start()
        if self.checkpointer is not None:
            self._checkpoint(blocking=True)

        while True:
            now = self._now()
            self._admit_arrivals(now)
            self._expire_queue(now)
            if all(r.dead for r in self.replicas):
                self._fail_everything()
            self._dispatch(now)
            progressed = self._step_replicas()
            self._tick += 1
            if self.checkpointer is not None and cfg.ckpt_every and \
                    self._tick % cfg.ckpt_every == 0:
                self._checkpoint(blocking=False)
            self._health_check()
            if self._done():
                break
            if not progressed:
                self._advance_to_next_event()
        if self.checkpointer is not None:
            try:
                self.checkpointer.wait()
            except Exception:
                self.ckpt_failures += 1
        return self.report(submitted)

    def report(self, submitted: Optional[int] = None) -> SupervisorReport:
        # useful = positions computed AND kept: a request that produced
        # tokens had its prompt prefilled; token-less terminals cost ~0
        useful = sum(len(self._book[o.id].req.prompt) + len(o.tokens)
                     for o in self._outcomes
                     if o.tokens and o.id in self._book)
        return SupervisorReport(
            outcomes=list(self._outcomes),
            submitted=len(self._book) if submitted is None else submitted,
            restarts={r.id: r.restarts for r in self.replicas},
            failures=list(self.failures),
            straggler_events=self.straggler_events,
            ckpt_failures=self.ckpt_failures,
            wasted_tokens=self.wasted_tokens,
            useful_tokens=useful)

    # ------------------------------------------------------ queue machinery
    def _admit_arrivals(self, now: float) -> None:
        """future -> shared queue once the clock passes the arrival;
        ``queue_cap`` bounds arrived-but-unserved occupancy with explicit
        load-shedding."""
        while self._future and self._future[0][0] <= now:
            arr, req = self._future.pop(0)
            cap = self.cfg.queue_cap
            if cap is not None and len(self._queue) >= cap:
                self._finish(req.id, "rejected", replica=-1)
                continue
            self._queue.append((arr, req))

    def _expire_queue(self, now: float) -> None:
        """Deadline enforcement while queued: an expired request times out
        before ever occupying a slot (keeping any tokens from a previous
        incarnation)."""
        kept: Deque[Tuple[float, Request]] = deque()
        for arr, req in self._queue:
            dl = getattr(req, "deadline_s", None)
            if dl is not None and now > arr + dl:
                self._finish(req.id, "timeout", replica=-1)
            else:
                kept.append((arr, req))
        self._queue = kept

    def _dispatch(self, now: float) -> None:
        """Shared queue -> free replica slots, FIFO by arrival, least
        loaded replica first. A replayed request resumes as
        ``prompt + emitted``; its deadline budget keeps draining across
        incarnations."""
        while self._queue:
            live = [r for r in self.replicas
                    if r.alive and r.scheduler.free_slots > 0]
            if not live:
                return
            arr, req = self._queue.popleft()
            b = self._book[req.id]
            r = max(live, key=lambda rep: rep.scheduler.free_slots)
            run = req
            if b.emitted:
                run = dataclasses.replace(
                    req, prompt=np.concatenate(
                        [np.asarray(req.prompt, np.int32),
                         np.asarray(b.emitted, np.int32)]),
                    max_new_tokens=req.max_new_tokens - len(b.emitted))
            if req.deadline_s is not None:
                run = dataclasses.replace(
                    run, deadline_s=req.deadline_s - (now - arr))
            r.scheduler.submit(run)

    # ---------------------------------------------------------- replica ops
    def _step_replicas(self) -> bool:
        progressed = False
        for r in self.replicas:
            if r.dead:
                continue
            if not r.alive:
                if self.clock.now() >= r.restart_at:
                    self._restart(r)
                else:
                    continue
            if not r.scheduler.has_arrived_work():
                continue
            t_a = self.clock.now()
            try:
                if r.scheduler.step():
                    progressed = True
                if self.cfg.step_cost_s:
                    self.clock.sleep(self.cfg.step_cost_s)
                self.monitor.heartbeat(
                    r.id, step_time_s=self.clock.now() - t_a,
                    now=self.clock.now())
                self._collect(r)
            except Exception as e:  # noqa: BLE001 — any step failure is a
                self._on_failure(r, e)  # replica failure, by design
                progressed = True
        return progressed

    def _collect(self, r: _Replica) -> None:
        results = r.scheduler.results
        while r.consumed < len(results):
            res = results[r.consumed]
            r.consumed += 1
            self._finish(res.id, res.status, replica=r.id)

    def _finish(self, req_id: int, status: str, replica: int) -> None:
        b = self._book[req_id]
        if b.done:
            return
        b.done = True
        now = self._now()
        self._outcomes.append(Outcome(
            id=req_id, tokens=list(b.emitted), status=status,
            arrival_s=b.arrival,
            ttft_s=(b.first_token_t - b.arrival)
            if b.first_token_t >= 0 else 0.0,
            finish_s=now - b.arrival, replays=b.replays, replica=replica))

    def _on_failure(self, r: _Replica, exc: BaseException) -> None:
        """Salvage everything the replica held, then schedule its rebuild
        (or retire it past the cap). No request is ever dropped here: each
        one either re-queues or gets a terminal ``failed`` outcome."""
        self.failures.append((r.id, repr(exc)))
        # requests retired DURING the failing step (before the raise) have
        # results sitting in the scheduler — collect them first, or the
        # restart's state reset would silently drop them
        self._collect(r)
        salvage: List[Tuple[float, Request, int]] = []
        for arr, req in r.scheduler.pending():
            salvage.append((arr, req, 0))
        for arr, req, toks, pos in r.scheduler.inflight():
            # positions computed on the dead replica that a resume must
            # recompute: prefilled prompt positions + emitted tokens
            self.wasted_tokens += pos + len(toks)
            salvage.append((arr, req, 1))
        for arr, req, replayed in salvage:
            # the replica-local request may be a resume (concatenated
            # prompt, shrunk budget, drained deadline) — always re-queue
            # the ORIGINAL from the book; emitted tokens ride separately
            b = self._book[req.id]
            b.replays += replayed
            if b.replays > self.cfg.max_request_replays:
                self._finish(req.id, "failed", replica=r.id)
                continue
            self._queue.append((b.arrival, b.req))
        self._queue = deque(sorted(self._queue, key=lambda t: t[0]))
        r.alive = False
        r.restarts += 1
        if r.restarts > self.cfg.max_restarts:
            r.dead = True
            return
        r.restart_at = self.clock.now() + backoff_delay(
            r.restarts - 1, self.cfg.backoff_base_s,
            self.cfg.backoff_factor, self.cfg.backoff_jitter, self._rng)

    def _restart(self, r: _Replica) -> None:
        """Rebuild: fresh cache via CacheBackend.start (inside
        scheduler.start), and — when a checkpointer is wired — params
        reloaded from the latest checksum-verified checkpoint (the
        restart-from-checkpoint path a real weight-holding replica
        takes)."""
        if self.checkpointer is not None:
            try:
                params, _ = self.checkpointer.restore(r.engine.params)
                r.engine.params = params
            except FileNotFoundError:
                pass  # no complete checkpoint yet: keep in-memory params
        r.scheduler.start()
        r.consumed = 0
        r.alive = True

    def _fail_everything(self) -> None:
        """Every replica is permanently dead: remaining requests cannot be
        served — terminal ``failed``, never a hang or a silent drop."""
        for arr, req in list(self._queue) + list(self._future):
            self._finish(req.id, "failed", replica=-1)
        self._queue.clear()
        self._future = []

    # ------------------------------------------------------- health + time
    def _health_check(self) -> None:
        plan = self.monitor.check(now=self.clock.now())
        if not plan.straggler_hosts:
            return
        self.straggler_events += 1
        if not self.cfg.restart_stragglers:
            return
        for rid in plan.straggler_hosts:
            r = self.replicas[rid]
            if r.alive and not r.dead:
                self._on_failure(r, TimeoutError(
                    f"replica {rid} straggling (health-monitor verdict)"))

    def _checkpoint(self, blocking: bool) -> None:
        try:
            if self._host_faults is not None:
                self._host_faults.begin_step()
            self.checkpointer.save(self._tick, self.replicas[0].engine.params,
                                   blocking=blocking)
        except Exception:  # capture-and-continue: checkpoint failure is
            self.ckpt_failures += 1  # not a serving failure; the previous
            # complete checkpoint remains authoritative

    def _done(self) -> bool:
        if self._future or self._queue:
            return False
        return all(r.dead or r.scheduler.done for r in self.replicas)

    def _advance_to_next_event(self) -> None:
        """Nothing progressed: jump the clock to the next arrival or
        pending restart (virtual clocks need this to move at all; a real
        clock just sleeps out the gap)."""
        events = [self._t0 + arr for arr, _ in self._future[:1]]
        events += [r.restart_at for r in self.replicas
                   if not r.alive and not r.dead]
        if not events:
            return
        self.clock.sleep(max(1e-4, min(events) - self.clock.now()))
