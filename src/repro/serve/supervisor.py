"""Serving supervisor: N scheduler-backed replicas behind one shared
admission queue, with supervised restart and zero dropped requests.

The scheduler (``serve.scheduler``) made one engine continuous; this
module makes a fleet of them survivable — and, since the cross-process
fleet, survivable *across address spaces*. One deterministic loop drives
every replica's ``step()`` round-robin behind one replica interface with
two implementations:

  * **InprocReplica** — the PR 6 fleet: engine + scheduler objects in
    the supervisor's process. A chaos test with a virtual clock replays
    bit-identically — there is no race to lose a request in.
  * **ProcessReplica** — a ``serve.worker`` subprocess driven over the
    framed RPC transport (``serve.transport``): spawn, heartbeat-over-
    transport health, EOF/exit crash detection, capped-backoff respawn.
    SIGKILL is survivable *by construction*: the worker holds no
    authoritative state — emitted tokens live in the supervisor's book
    (and journal), and a respawned worker rebuilds params
    deterministically from the spec seed.

Failure model and recovery:

  * A replica **fails** when its step raises — a real exception, an
    injected one (``serve.faults``), the scheduler's NaN guard, a
    ``WorkerError`` reported over a healthy pipe, or a
    ``TransportError`` (the pipe itself died). The supervisor salvages
    exactly what the replica held: queued requests re-enter the shared
    queue unchanged; **in-flight requests are re-admitted as
    ``prompt + tokens_emitted_so_far``** — greedy decode makes the
    continuation bitwise-identical to an uninterrupted run, and because
    the already-emitted tokens ride in the resume *prompt*, replay can
    never re-stream them (exactly-once streaming by construction). A
    SIGKILLed worker cannot be queried, so the process replica keeps a
    supervisor-side assignment table (admission + progress hints from
    every step reply) as its salvage source.
  * The replica is **rebuilt** after a seeded exponential backoff
    (``distributed.fault.backoff_delay``): in-process, a fresh cache via
    ``scheduler.start`` (optionally reloading params from the
    checksum-verified latest checkpoint); cross-process, a fresh worker
    spawn — the ``start`` RPC carries the replica's lifetime step count
    so one-shot fault coordinates never re-trip after a respawn.
  * **Caps are terminal, never silent**: a replica exceeding
    ``max_restarts`` is retired from the fleet; a request re-admitted
    more than ``max_request_replays`` times (a poison pill that keeps
    killing replicas) ends with status ``failed`` — with whatever tokens
    it had; if every replica is dead, all remaining requests fail
    visibly. Every submitted request ends ``ok | timeout | rejected |
    failed`` — the report reconciles counts to zero drops.
  * **Durability** (``serve.journal``): with a journal wired, every
    admit, emitted-token batch and terminal status is CRC-logged and
    fsynced once per tick. If the *supervisor* dies (simulated by the
    ``supervisor_crash`` fault kind, which flushes then raises
    ``SupervisorCrash``), a fresh supervisor's ``resume()`` replays the
    journal: terminal requests keep their outcomes, non-terminal ones
    re-admit as ``prompt + journaled emitted`` (bitwise-identical
    continuation), and clients re-sync via ``on_replay(id, prefix)`` —
    token streams stay exactly-once across worker AND supervisor death.
  * **Health**: every replica step feeds
    ``distributed.fault.HealthMonitor.heartbeat`` (idle process workers
    are pinged every ``heartbeat_s``); ``check`` flags stragglers from
    step-time quantiles, and ``restart_stragglers`` routes them through
    the same salvage-and-restart path as a crash.

Wasted-work accounting is split honestly: ``wasted_compute_tokens``
(prompt positions prefilled on a dead replica — genuinely lost forward
passes) vs ``replayed_emitted_tokens`` (tokens already journaled/
streamed that merely ride the resume prompt — recovery cost, not lost
output). ``wasted_tokens`` keeps the legacy sum.

Admission control lives at the shared queue: per-request ``deadline_s``
is enforced while queued (timeout before ever occupying a slot) and the
remaining budget rides into the replica for mid-flight expiry;
``queue_cap`` bounds arrived-but-unserved requests with explicit
``rejected`` load-shedding. A worker draining after SIGTERM refuses new
submits — the supervisor re-routes them and retires the worker once its
assigned work completes (exit 0, no failure counted).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..distributed.fault import HealthMonitor, backoff_delay
from ..obs import Obs
from .engine import Engine, Request
from .faults import (CacheCorruptionError, Clock, FaultPlan, FaultSpec,
                     InjectedFault, VirtualClock)
from .journal import Journal, replay_state
from .scheduler import ContinuousScheduler
from .transport import (FramedConnection, RPCClient, TransportConfig,
                        TransportError)


class SupervisorCrash(RuntimeError):
    """Injected supervisor death (the ``supervisor_crash`` fault kind):
    the journal is flushed, every worker process is killed (a real
    supervisor SIGKILL takes its process group down), and this
    propagates out of ``serve()``. Recovery is a NEW supervisor calling
    ``resume()`` on the same journal."""


@dataclasses.dataclass
class SupervisorConfig:
    replicas: int = 2
    prefill_chunk: int = 32
    max_restarts: int = 3           # per replica; beyond -> replica retired
    max_request_replays: int = 3    # per request; beyond -> status "failed"
    backoff_base_s: float = 0.05    # exponential restart backoff
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.25
    seed: int = 0                   # backoff-jitter PRNG seed
    queue_cap: Optional[int] = None  # bound on arrived-but-unserved requests
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 4.0
    restart_stragglers: bool = False
    step_cost_s: float = 0.0        # synthetic per-step clock charge: makes
                                    # straggler/deadline tests deterministic
                                    # under a VirtualClock (0 = real timing)
    ckpt_every: int = 0             # checkpoint params every N ticks (0=off)
    # --- cross-process fleet -----------------------------------------------
    call_timeout_s: float = 30.0    # per-RPC-attempt recv deadline
    partition_tolerance_s: float = 5.0  # retryable-failure budget per call;
                                    # past it the worker is declared dead
    heartbeat_s: float = 1.0        # idle-worker ping cadence
    spawn_timeout_s: float = 300.0  # worker build+compile budget (the
                                    # ``start`` RPC's recv deadline)


@dataclasses.dataclass
class Outcome:
    """Terminal per-request record, assembled across replays."""
    id: int
    tokens: List[int]
    status: str                 # ok | timeout | rejected | failed
    arrival_s: float            # supervisor-frame arrival
    ttft_s: float               # arrival -> first token (0.0 if none)
    finish_s: float             # arrival -> terminal
    replays: int = 0            # times re-admitted after a replica failure
    replica: int = -1           # replica that finished it (-1: never placed)


@dataclasses.dataclass
class SupervisorReport:
    outcomes: List[Outcome]
    submitted: int
    restarts: Dict[int, int]            # replica -> restart count
    failures: List[Tuple[int, str]]     # (replica, exception repr)
    straggler_events: int
    ckpt_failures: int
    wasted_compute_tokens: int          # positions genuinely lost to failures
    replayed_emitted_tokens: int        # journaled/streamed tokens that rode
                                        # a resume prompt (recovery cost, not
                                        # lost output)
    useful_tokens: int                  # prompt + generated across outcomes
    journal_records: int = 0
    journal_bytes: int = 0
    journal_replayed: int = 0           # records replayed by resume()
    journal_fsyncs: int = 0
    frames_sent: int = 0                # RPC frames (process fleet)
    frames_retried: int = 0             # retried call attempts

    def status_counts(self) -> Counter:
        return Counter(o.status for o in self.outcomes)

    @property
    def zero_drops(self) -> bool:
        """Every submitted request reached exactly one terminal status."""
        return len(self.outcomes) == self.submitted and \
            len({o.id for o in self.outcomes}) == self.submitted

    @property
    def wasted_tokens(self) -> int:
        """Legacy aggregate: every position recomputed after failures."""
        return self.wasted_compute_tokens + self.replayed_emitted_tokens

    @property
    def wasted_token_fraction(self) -> float:
        total = self.wasted_tokens + self.useful_tokens
        return self.wasted_tokens / total if total else 0.0

    @property
    def wasted_compute_fraction(self) -> float:
        """Genuinely lost forward passes as a fraction of all computed
        positions — the honest recovery-cost gate."""
        total = self.wasted_tokens + self.useful_tokens
        return self.wasted_compute_tokens / total if total else 0.0

    @property
    def replayed_emitted_fraction(self) -> float:
        total = self.wasted_tokens + self.useful_tokens
        return self.replayed_emitted_tokens / total if total else 0.0


@dataclasses.dataclass
class _Book:
    """Supervisor-side truth for one request across replays."""
    req: Request
    arrival: float
    emitted: List[int] = dataclasses.field(default_factory=list)
    first_token_t: float = -1.0
    replays: int = 0
    done: bool = False
    base_emitted: int = 0       # len(emitted) at the last dispatch — the
                                # split between replayed-emitted and
                                # this-incarnation tokens


@dataclasses.dataclass
class StepEvents:
    """One replica step's observable output, fleet-agnostic."""
    progressed: bool = False
    events: List[Tuple[int, int, bool]] = \
        dataclasses.field(default_factory=list)    # (req_id, tok, done)
    results: List[Tuple[int, str]] = \
        dataclasses.field(default_factory=list)    # (req_id, status)
    draining: bool = False
    exiting: bool = False


class InprocReplica:
    """PR 6 replica: engine + scheduler in the supervisor's process.
    Token events buffer replica-side and drain through ``step()`` /
    ``take_pending()`` — the same ingestion surface a process worker's
    step reply provides, so the supervisor's book/journal/streaming
    logic is fleet-agnostic."""

    kind = "inproc"

    def __init__(self, rid: int, engine: Engine, cfg: SupervisorConfig,
                 clock: Clock, plan: Optional[FaultPlan],
                 obs: Optional[Obs] = None):
        self.id = rid
        self.engine = engine
        inj = plan.injector(rid, clock) if plan else None
        self.scheduler = ContinuousScheduler(
            engine, prefill_chunk=cfg.prefill_chunk,
            on_token=self._buffer, clock=clock, faults=inj, nan_guard=True,
            obs=obs, obs_labels={"replica": rid})
        # in-process replicas share the supervisor timeline (pid 0) and
        # get their own lane: tid 0 is the supervisor loop, tid rid+1 the
        # replica's scheduler spans
        self.scheduler.trace_tid = rid + 1
        self.alive = True
        self.dead = False           # restart cap exhausted (or retired)
        self.accepting = True
        self.restarts = 0
        self.restart_at = 0.0
        self.steps_taken = 0        # lifetime step attempts (never reset)
        self.frames_sent = 0
        self.frames_retried = 0
        self._events: List[Tuple[int, int, bool]] = []
        self._consumed = 0
        self._draining = False

    def _buffer(self, req_id: int, tok: int, done: bool) -> None:
        self._events.append((req_id, tok, done))

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        self.scheduler.start()
        self._events = []
        self._consumed = 0
        self.accepting = not self._draining

    @property
    def max_seq(self) -> int:
        return self.engine.cfg.max_seq

    @property
    def eos_token(self) -> int:
        return self.engine.cfg.eos_token

    @property
    def free_slots(self) -> int:
        return self.scheduler.free_slots

    @property
    def done(self) -> bool:
        return self.scheduler.done

    def has_arrived_work(self) -> bool:
        return self.scheduler.has_arrived_work()

    def submit(self, req: Request) -> bool:
        if not self.accepting:
            return False
        return self.scheduler.submit(req)

    def step(self) -> StepEvents:
        self.steps_taken += 1
        progressed = self.scheduler.step()
        ev = self.take_pending()
        ev.progressed = progressed
        ev.draining = self._draining
        ev.exiting = self._draining and self.scheduler.done
        return ev

    def take_pending(self) -> StepEvents:
        """Buffered events + uncollected results — everything observable
        that survived a mid-step raise (tokens emitted and requests
        retired before the exception)."""
        events, self._events = self._events, []
        results = self.scheduler.results[self._consumed:]
        self._consumed = len(self.scheduler.results)
        return StepEvents(events=events,
                          results=[(r.id, r.status) for r in results])

    def idle_beat(self, now: float, monitor: HealthMonitor) -> None:
        pass                        # same process: liveness is trivial

    def salvage(self) -> List[Tuple[int, bool, int]]:
        """(req_id, was_inflight, prompt_pos) for everything held."""
        out = [(req.id, False, 0) for _, req in self.scheduler.pending()]
        out += [(req.id, True, pos)
                for _, req, _toks, pos in self.scheduler.inflight()]
        return out

    # ------------------------------------------------------- fault driving
    def inject_kill(self) -> None:
        pass                        # the supervisor raises the failure

    def inject_sigterm(self) -> None:
        self._draining = True
        self.accepting = False

    def arm_partition(self, n_calls: int) -> None:
        raise ValueError("partition faults need a process fleet "
                         "(fleet='procs'): there is no transport to drop "
                         "frames on in-process")

    def arm_slowpipe(self, delay_s: float) -> None:
        raise ValueError("slowpipe faults need a process fleet "
                         "(fleet='procs')")

    def retire(self) -> None:
        self.alive = False
        self.dead = True

    def hard_kill(self) -> None:
        pass

    def close(self) -> None:
        pass


class ProcessReplica:
    """A ``serve.worker`` subprocess behind the framed RPC transport.

    The worker holds no authoritative request state: this handle keeps
    an assignment table (request id -> admitted?, prompt-progress hint)
    updated from every step reply, which is the salvage source when the
    process dies unqueryably (SIGKILL, OOM). Respawn = spawn a fresh
    process (params rebuild deterministically from the spec seed) and
    ``start`` it with the lifetime step offset."""

    kind = "procs"

    def __init__(self, rid: int, spec, cfg: SupervisorConfig,
                 obs: Optional[Obs] = None):
        self.id = rid
        self.obs = obs
        tracing = obs is not None and obs.tracer.enabled
        self.spec = dataclasses.replace(spec, replica=rid, trace=tracing)
        self.cfg = cfg
        self._clock_offset_us = 0
        self.proc: Optional[subprocess.Popen] = None
        self.client: Optional[RPCClient] = None
        self.alive = True
        self.dead = False
        self.accepting = True
        self.restarts = 0
        self.restart_at = 0.0
        self.steps_taken = 0
        self.assigned: Dict[int, List] = {}     # id -> [admitted, pos]
        self._last_beat = 0.0
        self._frames_base = 0
        self._retries_base = 0
        self._armed_partition = 0
        self._armed_slowpipe = 0.0
        serve = self.spec.serve
        self.max_seq = int(serve["cache"]["max_seq"]
                           if serve.get("cache") else serve["max_seq"])
        self.eos_token = int(serve["eos_token"])

    # ------------------------------------------------------------ lifecycle
    def _reap(self) -> None:
        if self.proc is not None:
            if self.client is not None:
                self._frames_base += self.client.frames_sent
                self._retries_base += self.client.retries
            if self.proc.poll() is None:
                self.proc.kill()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
            self.proc = None
            self.client = None

    def start(self) -> None:
        from .worker import SPEC_ENV
        if self.proc is not None and self.proc.poll() is not None:
            self._reap()            # crashed incarnation: reap the zombie
        if self.proc is None:
            env = dict(os.environ)
            env[SPEC_ENV] = self.spec.to_json()
            # the worker must import `repro` no matter the caller's cwd
            src = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            # -c (not -m): the package already imports .worker, and
            # runpy would warn about re-executing an imported module
            self.proc = subprocess.Popen(
                [sys.executable, "-c",
                 "from repro.serve.worker import main; "
                 "raise SystemExit(main())"],
                stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                bufsize=0, env=env)
            self.client = RPCClient(
                FramedConnection(self.proc.stdout.fileno(),
                                 self.proc.stdin.fileno()),
                TransportConfig(call_timeout_s=self.cfg.call_timeout_s,
                                tolerance_s=self.cfg.partition_tolerance_s,
                                backoff_base_s=min(
                                    0.05, self.cfg.backoff_base_s or 0.05),
                                backoff_factor=self.cfg.backoff_factor,
                                backoff_jitter=self.cfg.backoff_jitter,
                                seed=self.cfg.seed * 1000 + self.id))
        tracing = self.obs is not None and self.obs.tracer.enabled
        if tracing:
            # every call frame carries the trace id; worker-side spans
            # come back in step replies and stitch under pid rid+1
            self.client.trace_id = self.obs.tracer.trace_id
        try:
            rep = self.client.call(
                "start",
                {"fault_step_offset": self.steps_taken,
                 "trace_id": self.obs.tracer.trace_id if tracing else None},
                timeout=self.cfg.spawn_timeout_s)
        except TransportError as e:
            code = self.proc.poll()
            raise TransportError(
                f"worker {self.id} failed to start "
                f"(exit={code}): {e}", retryable=False) from e
        if tracing and isinstance(rep, dict) and rep.get("t0_us") is not None:
            # clock stitching: worker timestamps are worker-monotonic;
            # the offset measured at the start handshake maps them into
            # the supervisor timeline (skewed by at most the handshake)
            sup_us = int(round(self.obs.clock.now() * 1e6))
            self._clock_offset_us = sup_us - int(rep["t0_us"])
            self.obs.tracer.set_process_name(self.id + 1,
                                             f"worker-{self.id}")
        self.assigned = {}
        self.accepting = True

    @property
    def free_slots(self) -> int:
        slots = int(self.spec.serve["cache"]["max_slots"]
                    if self.spec.serve.get("cache")
                    else self.spec.serve["max_slots"])
        # the worker admits from its own queue; the supervisor bounds
        # assigned-but-unfinished work to the slot count so no worker
        # hoards the shared queue
        return max(0, slots - len(self.assigned))

    @property
    def done(self) -> bool:
        return not self.assigned

    def has_arrived_work(self) -> bool:
        return bool(self.assigned)

    def submit(self, req: Request) -> bool:
        if not self.accepting or self.client is None:
            return False
        rep = self.client.call("submit", {
            "prompt": np.asarray(req.prompt, np.int32).tolist(),
            "new": int(req.max_new_tokens), "id": int(req.id),
            "dl": req.deadline_s})
        if rep.get("draining"):
            self.accepting = False
        if rep.get("accepted"):
            self.assigned[req.id] = [False, 0]
            return True
        return False

    def step(self) -> StepEvents:
        self.steps_taken += 1
        if self._armed_slowpipe > 0:
            s, self._armed_slowpipe = self._armed_slowpipe, 0.0
            self.client.arm_slowpipe(s)
        if self._armed_partition > 0:
            n, self._armed_partition = self._armed_partition, 0
            self.client.arm_partition(n)
        rep = self.client.call("step", {})
        self._last_beat = 0.0       # forces no extra ping while stepping
        ev_tr = rep.get("ev")
        if ev_tr and self.obs is not None:
            self.obs.tracer.adopt(ev_tr, pid=self.id + 1,
                                  offset_us=self._clock_offset_us)
        for rid in rep.get("admitted", ()):
            if int(rid) in self.assigned:
                self.assigned[int(rid)][0] = True
        for rid, pos in (rep.get("progress") or {}).items():
            if int(rid) in self.assigned:
                self.assigned[int(rid)][1] = int(pos)
        results = [(int(r), str(st)) for r, st in rep.get("results", ())]
        for rid, _st in results:
            self.assigned.pop(rid, None)
        if rep.get("draining"):
            self.accepting = False
        return StepEvents(
            progressed=bool(rep.get("progressed")),
            events=[(int(r), int(t), bool(d))
                    for r, t, d in rep.get("events", ())],
            results=results,
            draining=bool(rep.get("draining")),
            exiting=bool(rep.get("exiting")))

    def take_pending(self) -> StepEvents:
        # a dead process takes its un-replied step output with it; the
        # journal/book already hold every token previously ingested
        return StepEvents()

    def idle_beat(self, now: float, monitor: HealthMonitor) -> None:
        """Liveness for workers with nothing assigned: ping every
        ``heartbeat_s``. A dead pipe raises out to the failure path."""
        if self.client is None or now - self._last_beat < \
                self.cfg.heartbeat_s:
            return
        self._last_beat = now
        self.client.call("ping", {})
        monitor.heartbeat(self.id, now=now)

    def salvage(self) -> List[Tuple[int, bool, int]]:
        out = [(rid, bool(adm), int(pos))
               for rid, (adm, pos) in self.assigned.items()]
        self.assigned = {}
        return out

    # ------------------------------------------------------- fault driving
    def inject_kill(self) -> None:
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()        # SIGKILL: no cleanup, no goodbye

    def inject_sigterm(self) -> None:
        if self.proc is not None and self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)

    def arm_partition(self, n_calls: int) -> None:
        self._armed_partition += max(0, int(n_calls))

    def arm_slowpipe(self, delay_s: float) -> None:
        self._armed_slowpipe = max(self._armed_slowpipe, float(delay_s))

    def retire(self) -> None:
        """Graceful drain completed: the worker exited 0 on its own."""
        self.alive = False
        self.dead = True
        self._reap()

    def hard_kill(self) -> None:
        self.inject_kill()
        self._reap()

    def close(self) -> None:
        if self.proc is not None and self.proc.poll() is None \
                and self.client is not None:
            try:
                self.client.call("shutdown", {}, timeout=2.0)
            except Exception:  # noqa: BLE001 — best-effort goodbye
                pass
        self._reap()

    @property
    def frames_sent(self) -> int:
        live = self.client.frames_sent if self.client is not None else 0
        return self._frames_base + live

    @property
    def frames_retried(self) -> int:
        live = self.client.retries if self.client is not None else 0
        return self._retries_base + live


class Supervisor:
    """Drives ``cfg.replicas`` replicas from one shared admission queue.

    In-process fleet (``fleet="inproc"``): ``engine_factory()`` builds
    one Engine per replica (same model/params, its own trace cache).
    Process fleet (``fleet="procs"``): ``worker_spec``
    (``serve.worker.WorkerSpec``) describes how each worker subprocess
    rebuilds its replica; engines live in the workers.

    ``fault_plan`` threads engine-level faults through each replica's
    injector and process-level kinds (``faults.PROC_KINDS``) through the
    supervisor's own driving loop — chaos replays stay deterministic
    because the worker never rolls its own dice. ``journal`` makes the
    bookkeeping durable (see ``resume``); ``on_replay(id, tokens)``
    re-syncs client streams with the journaled prefix after a recovery.
    All timing reads the injectable ``clock`` (in-process only: worker
    subprocesses live in real time)."""

    def __init__(self, engine_factory: Optional[Callable[[], Engine]] = None,
                 cfg: SupervisorConfig = SupervisorConfig(), *,
                 on_token: Optional[Callable[[int, int, bool], None]] = None,
                 on_replay: Optional[Callable[[int, List[int]], None]] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 clock: Optional[Clock] = None,
                 checkpointer=None,
                 monitor: Optional[HealthMonitor] = None,
                 journal: Optional[Journal] = None,
                 fleet: str = "inproc",
                 worker_spec=None,
                 obs: Optional[Obs] = None):
        if fleet not in ("inproc", "procs"):
            raise ValueError(f"fleet {fleet!r} (one of inproc|procs)")
        self.cfg = cfg
        self.fleet = fleet
        self.clock = clock or Clock()
        # one obs bundle for the whole fleet: replicas label their
        # instruments, worker spans adopt into this tracer, the journal
        # binds its counters here, report() publishes fleet gauges here
        self.obs = obs if obs is not None else Obs(clock=self.clock)
        if journal is not None:
            journal.bind_registry(self.obs.registry)
        self.on_token = on_token
        self.on_replay = on_replay
        self.plan = fault_plan
        self.checkpointer = checkpointer
        self.journal = journal
        self.monitor = monitor or HealthMonitor(
            n_hosts=cfg.replicas, timeout_s=cfg.heartbeat_timeout_s,
            straggler_factor=cfg.straggler_factor)
        self._rng = np.random.default_rng(cfg.seed)
        self._host_faults = fault_plan.injector(-1, self.clock) \
            if fault_plan else None
        if checkpointer is not None and self._host_faults is not None:
            checkpointer.fault_hook = self._host_faults.check
        if fleet == "procs":
            if worker_spec is None:
                raise ValueError("fleet='procs' needs a worker_spec "
                                 "(serve.worker.WorkerSpec)")
            if checkpointer is not None:
                raise ValueError(
                    "checkpointer is in-process only: process workers "
                    "rebuild params deterministically from the spec seed")
            if isinstance(self.clock, VirtualClock):
                raise ValueError(
                    "a VirtualClock cannot drive worker subprocesses "
                    "(they live in real time)")
            self.replicas = [ProcessReplica(rid, worker_spec, cfg,
                                            obs=self.obs)
                             for rid in range(cfg.replicas)]
        else:
            if engine_factory is None:
                raise ValueError("engine_factory is required for the "
                                 "in-process fleet")
            self.replicas = [
                InprocReplica(rid, engine_factory(), cfg, self.clock,
                              fault_plan, obs=self.obs)
                for rid in range(cfg.replicas)]
        # process-level fault schedule, driven supervisor-side
        self._proc_pending: Dict[int, List[FaultSpec]] = {
            r.id: (fault_plan.proc_faults(r.id) if fault_plan else [])
            for r in self.replicas}
        self._sup_pending: List[FaultSpec] = \
            fault_plan.supervisor_crashes() if fault_plan else []
        # per-serve state
        self._book: Dict[int, _Book] = {}
        self._future: List[Tuple[float, Request]] = []
        self._queue: Deque[Tuple[float, Request]] = deque()
        self._outcomes: List[Outcome] = []
        self._t0 = 0.0
        self._tick = 0
        self.failures: List[Tuple[int, str]] = []
        self.straggler_events = 0
        self.ckpt_failures = 0
        self.wasted_compute_tokens = 0
        self.replayed_emitted_tokens = 0
        self.journal_replayed = 0

    # ------------------------------------------------------------- plumbing
    def __enter__(self) -> "Supervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        """Release worker processes (graceful shutdown RPC, then reap).
        In-process replicas have nothing to release."""
        for r in self.replicas:
            r.close()

    def _now(self) -> float:
        return self.clock.now() - self._t0

    @property
    def _eos(self) -> int:
        return self.replicas[0].eos_token

    def _journal_add(self, rec: dict) -> None:
        if self.journal is not None:
            if self.obs.tracer.enabled and rec.get("t") == "admit":
                # stamp admits with the trace id so the journal can be
                # matched to the Perfetto timeline of the run that wrote
                # it (replay_state ignores unknown fields)
                rec["tr"] = self.obs.tracer.trace_id
            self.journal.append(rec)

    # -------------------------------------------------------------- serving
    def serve(self, requests: Sequence[Request],
              arrivals: Optional[Sequence[float]] = None) -> SupervisorReport:
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrivals must match requests 1:1")
        self._t0 = self.clock.now()
        self._tick = 0
        self._book = {}
        self._outcomes = []
        self._queue = deque()
        self._future = sorted(zip(map(float, arrivals), requests),
                              key=lambda t: t[0])
        submitted = len(requests)
        max_seq = self.replicas[0].max_seq
        valid: List[Tuple[float, Request]] = []
        for arr, req in self._future:
            self._book[req.id] = _Book(req=req, arrival=arr)
            self._journal_add({
                "t": "admit", "id": int(req.id),
                "prompt": np.asarray(req.prompt).tolist(),
                "new": int(req.max_new_tokens),
                "dl": req.deadline_s, "arr": arr})
            need = len(req.prompt) + req.max_new_tokens
            if len(req.prompt) < 1 or req.max_new_tokens < 1 or \
                    need > max_seq:
                # a fleet front-door cannot raise at a remote client:
                # invalid requests get an explicit rejected outcome
                self._finish(req.id, "rejected", replica=-1)
            else:
                valid.append((arr, req))
        self._future = valid
        if self.journal is not None:
            self.journal.flush()    # admits are durable before any step
        return self._run(submitted)

    def resume(self) -> SupervisorReport:
        """Rebuild serving state from the journal after a supervisor
        death and drain the unfinished work. Terminal requests keep their
        journaled outcomes; non-terminal ones re-admit as
        ``prompt + emitted`` (clients re-sync via ``on_replay``) so their
        continuations — and the final streams — are bitwise-identical to
        an undisturbed run, exactly-once."""
        if self.journal is None:
            raise ValueError("resume() requires a journal")
        state = replay_state(self.journal.recovered)
        self.journal_replayed = len(self.journal.recovered)
        self.obs.tracer.instant("resume", tid=0,
                                replayed=self.journal_replayed,
                                requests=len(state))
        self.obs.recorder.record("resume", replayed=self.journal_replayed,
                                 requests=len(state))
        self._t0 = self.clock.now()
        self._tick = 0
        self._book = {}
        self._outcomes = []
        self._future = []
        pending: List[Tuple[float, Request]] = []
        for rid, e in state.items():
            req = Request(prompt=np.asarray(e.prompt, np.int32),
                          max_new_tokens=e.max_new_tokens, id=rid,
                          deadline_s=e.deadline_s)
            b = _Book(req=req, arrival=0.0, emitted=list(e.emitted))
            self._book[rid] = b
            if e.status is not None:
                b.done = True
                self._outcomes.append(Outcome(
                    id=rid, tokens=list(e.emitted), status=e.status,
                    arrival_s=e.arrival, ttft_s=0.0, finish_s=0.0,
                    replica=-1))
                continue
            if self.on_replay is not None:
                self.on_replay(rid, list(b.emitted))
            if self._emission_complete(b):
                # everything was emitted and journaled; only the terminal
                # record died with the old supervisor
                self._finish(rid, "ok", replica=-1)
                continue
            # deadline budget restarts at recovery: the original arrival
            # belongs to a dead supervisor's clock frame
            pending.append((0.0, req))
        self._queue = deque(pending)
        return self._run(len(state))

    def _run(self, submitted: int) -> SupervisorReport:
        cfg = self.cfg
        for r in self.replicas:
            if not r.dead:
                r.start()
                r.alive = True
        if self.checkpointer is not None:
            self._checkpoint(blocking=True)
        try:
            while True:
                now = self._now()
                self._admit_arrivals(now)
                self._expire_queue(now)
                if all(r.dead for r in self.replicas):
                    self._fail_everything()
                self._dispatch(now)
                progressed = self._step_replicas()
                self._tick += 1
                if self.checkpointer is not None and cfg.ckpt_every and \
                        self._tick % cfg.ckpt_every == 0:
                    self._checkpoint(blocking=False)
                self._health_check()
                if self.journal is not None:
                    with self.obs.tracer.span("journal_flush", tid=0,
                                              tick=self._tick):
                        self.journal.flush()
                self._maybe_supervisor_crash()
                if self._done():
                    break
                if not progressed:
                    self._advance_to_next_event()
        except SupervisorCrash:
            if self.journal is not None:
                self.journal.flush()
            self.obs.tracer.instant("supervisor_crash", tid=0,
                                    tick=self._tick)
            self.obs.recorder.record("supervisor_crash", tick=self._tick)
            self.obs.recorder.dump("supervisor_crash")
            for r in self.replicas:
                r.hard_kill()       # the process tree dies with its leader
            raise
        if self.checkpointer is not None:
            try:
                self.checkpointer.wait()
            except Exception:
                self.ckpt_failures += 1
        if self.journal is not None:
            self.journal.seal()
        return self.report(submitted)

    def report(self, submitted: Optional[int] = None) -> SupervisorReport:
        # useful = positions computed AND kept: a request that produced
        # tokens had its prompt prefilled; token-less terminals cost ~0
        useful = sum(len(self._book[o.id].req.prompt) + len(o.tokens)
                     for o in self._outcomes
                     if o.tokens and o.id in self._book)
        # publish the fleet-derived numbers as gauges so the registry
        # snapshot carries EXACTLY what this report returns (journal
        # counters are already registry-backed via bind_registry; replica
        # token/status counters via the schedulers' labeled instruments)
        reg = self.obs.registry
        reg.gauge("fleet.wasted_compute_tokens").set(
            self.wasted_compute_tokens)
        reg.gauge("fleet.replayed_emitted_tokens").set(
            self.replayed_emitted_tokens)
        reg.gauge("fleet.useful_tokens").set(useful)
        reg.gauge("fleet.restarts").set(
            sum(r.restarts for r in self.replicas))
        reg.gauge("fleet.straggler_events").set(self.straggler_events)
        reg.gauge("fleet.frames_sent").set(
            sum(r.frames_sent for r in self.replicas))
        reg.gauge("fleet.frames_retried").set(
            sum(r.frames_retried for r in self.replicas))
        reg.gauge("fleet.journal_replayed").set(self.journal_replayed)
        for status, n in Counter(o.status for o in self._outcomes).items():
            reg.gauge("fleet.requests", status=status).set(n)
        return SupervisorReport(
            outcomes=list(self._outcomes),
            submitted=len(self._book) if submitted is None else submitted,
            restarts={r.id: r.restarts for r in self.replicas},
            failures=list(self.failures),
            straggler_events=self.straggler_events,
            ckpt_failures=self.ckpt_failures,
            wasted_compute_tokens=self.wasted_compute_tokens,
            replayed_emitted_tokens=self.replayed_emitted_tokens,
            useful_tokens=useful,
            journal_records=self.journal.records if self.journal else 0,
            journal_bytes=self.journal.bytes if self.journal else 0,
            journal_replayed=self.journal_replayed,
            journal_fsyncs=self.journal.fsyncs if self.journal else 0,
            frames_sent=sum(r.frames_sent for r in self.replicas),
            frames_retried=sum(r.frames_retried for r in self.replicas))

    # ------------------------------------------------------ queue machinery
    def _admit_arrivals(self, now: float) -> None:
        """future -> shared queue once the clock passes the arrival;
        ``queue_cap`` bounds arrived-but-unserved occupancy with explicit
        load-shedding."""
        while self._future and self._future[0][0] <= now:
            arr, req = self._future.pop(0)
            cap = self.cfg.queue_cap
            if cap is not None and len(self._queue) >= cap:
                self._finish(req.id, "rejected", replica=-1)
                continue
            self._queue.append((arr, req))

    def _expire_queue(self, now: float) -> None:
        """Deadline enforcement while queued: an expired request times out
        before ever occupying a slot (keeping any tokens from a previous
        incarnation)."""
        kept: Deque[Tuple[float, Request]] = deque()
        for arr, req in self._queue:
            dl = getattr(req, "deadline_s", None)
            if dl is not None and now > arr + dl:
                self._finish(req.id, "timeout", replica=-1)
            else:
                kept.append((arr, req))
        self._queue = kept

    def _dispatch(self, now: float) -> None:
        """Shared queue -> free replica slots, FIFO by arrival, least
        loaded replica first. A replayed request resumes as
        ``prompt + emitted``; its deadline budget keeps draining across
        incarnations. A replica refusing a submit (draining worker) is
        skipped; a submit whose transport dies routes through the normal
        failure path (the killed incarnation never gets stepped again, so
        a possibly-delivered request cannot double-serve)."""
        if not self._queue:
            return
        with self.obs.tracer.span("dispatch", tid=0,
                                  queued=len(self._queue)):
            self._dispatch_queue(now)

    def _dispatch_queue(self, now: float) -> None:
        while self._queue:
            live = [r for r in self.replicas
                    if r.alive and not r.dead and r.accepting
                    and r.free_slots > 0]
            if not live:
                return
            arr, req = self._queue.popleft()
            b = self._book[req.id]
            run = req
            if b.emitted:
                run = dataclasses.replace(
                    req, prompt=np.concatenate(
                        [np.asarray(req.prompt, np.int32),
                         np.asarray(b.emitted, np.int32)]),
                    max_new_tokens=req.max_new_tokens - len(b.emitted))
            if req.deadline_s is not None:
                run = dataclasses.replace(
                    run, deadline_s=req.deadline_s - (now - arr))
            placed = False
            for r in sorted(live, key=lambda rep: (-rep.free_slots,
                                                   rep.id)):
                try:
                    accepted = r.submit(run)
                except Exception as e:  # noqa: BLE001 — transport death
                    self._ingest(r, r.take_pending())
                    self._on_failure(r, e)
                    continue
                if accepted:
                    b.base_emitted = len(b.emitted)
                    placed = True
                    break
            if not placed:
                self._queue.appendleft((arr, req))
                return

    # ---------------------------------------------------------- replica ops
    def _step_replicas(self) -> bool:
        progressed = False
        for r in self.replicas:
            if r.dead:
                continue
            if not r.alive:
                if self.clock.now() >= r.restart_at:
                    try:
                        self._restart(r)
                    except Exception as e:  # noqa: BLE001 — spawn failed
                        self._on_failure(r, e)
                        progressed = True
                        continue
                else:
                    continue
            if self._drive_proc_faults(r):
                progressed = True
                continue
            if not r.has_arrived_work():
                try:
                    r.idle_beat(self.clock.now(), self.monitor)
                except Exception as e:  # noqa: BLE001 — dead pipe
                    self._ingest(r, r.take_pending())
                    self._on_failure(r, e)
                    progressed = True
                continue
            t_a = self.clock.now()
            try:
                with self.obs.tracer.span("replica_step", tid=0,
                                          replica=r.id):
                    ev = r.step()
                if ev.progressed:
                    progressed = True
                self._ingest(r, ev)
                if self.cfg.step_cost_s:
                    self.clock.sleep(self.cfg.step_cost_s)
                self.monitor.heartbeat(
                    r.id, step_time_s=self.clock.now() - t_a,
                    now=self.clock.now())
                if ev.exiting:
                    r.retire()      # graceful drain done: exit 0, not a
                                    # failure — no restart, no salvage
            except Exception as e:  # noqa: BLE001 — any step failure is a
                self._ingest(r, r.take_pending())  # replica failure,
                self._on_failure(r, e)             # by design
                progressed = True
        return progressed

    def _drive_proc_faults(self, r) -> bool:
        """Fire due process-level fault coordinates. Returns True when
        the replica was killed here (skip its step this tick)."""
        due = [f for f in self._proc_pending[r.id]
               if f.step <= r.steps_taken]
        killed = False
        for f in due:
            self._proc_pending[r.id].remove(f)
            if f.kind == "sigkill":
                r.inject_kill()
                if r.kind == "inproc":
                    # no process to kill: the failure IS the injection
                    self._ingest(r, r.take_pending())
                    self._on_failure(r, InjectedFault(
                        f"injected sigkill at step={f.step} "
                        f"replica={r.id}"))
                    killed = True
                # process fleet: the next RPC hits EOF/EPIPE and routes
                # through the same failure path with a real dead process
            elif f.kind == "sigterm":
                r.inject_sigterm()
            elif f.kind == "partition":
                r.arm_partition(int(f.arg) or 4)
            elif f.kind == "slowpipe":
                r.arm_slowpipe(f.delay_s or 0.05)
        return killed

    def _maybe_supervisor_crash(self) -> None:
        due = [f for f in self._sup_pending if f.step <= self._tick]
        if not due:
            return
        for f in due:
            self._sup_pending.remove(f)
        if self.journal is not None:
            self.journal.flush()
        raise SupervisorCrash(
            f"injected supervisor crash at tick {self._tick}")

    def _ingest(self, r, ev: Optional[StepEvents]) -> None:
        """Fold one step's observable output into the book, the journal
        and the client stream — in that order, per batch, so a token is
        journal-buffered before it is streamed."""
        if ev is None:
            return
        starts: Dict[int, int] = {}
        for req_id, tok, _done in ev.events:
            b = self._book[req_id]
            starts.setdefault(req_id, len(b.emitted))
            if b.first_token_t < 0:
                b.first_token_t = self._now()
            b.emitted.append(tok)
        for req_id, i0 in starts.items():
            self._journal_add({"t": "emit", "id": int(req_id), "i": i0,
                               "toks": self._book[req_id].emitted[i0:]})
        if self.on_token is not None:
            for req_id, tok, done in ev.events:
                # replayed tokens ride in the resume prompt, never
                # re-emitted: the stream the user sees is exactly-once
                # by construction
                self.on_token(req_id, tok, done)
        for req_id, status in ev.results:
            self._finish(req_id, status, replica=r.id)

    def _finish(self, req_id: int, status: str, replica: int) -> None:
        b = self._book[req_id]
        if b.done:
            return
        b.done = True
        now = self._now()
        self._journal_add({"t": "term", "id": int(req_id), "st": status})
        self._outcomes.append(Outcome(
            id=req_id, tokens=list(b.emitted), status=status,
            arrival_s=b.arrival,
            ttft_s=(b.first_token_t - b.arrival)
            if b.first_token_t >= 0 else 0.0,
            finish_s=now - b.arrival, replays=b.replays, replica=replica))

    def _emission_complete(self, b: _Book) -> bool:
        """The request's token budget is fully emitted (or EOS landed)
        but its terminal record is missing — a result that died with a
        replica/supervisor. Finishing it ``ok`` beats re-admitting a
        zero-budget resume."""
        return len(b.emitted) >= b.req.max_new_tokens or \
            (bool(b.emitted) and b.emitted[-1] == self._eos)

    def _on_failure(self, r, exc: BaseException) -> None:
        """Salvage everything the replica held, then schedule its rebuild
        (or retire it past the cap). No request is ever dropped here: each
        one either re-queues, finishes from its complete emission, or
        gets a terminal ``failed`` outcome."""
        if r.dead:
            return
        self.failures.append((r.id, repr(exc)))
        self.obs.tracer.instant("replica_failure", tid=0, replica=r.id,
                                error=type(exc).__name__)
        self.obs.recorder.record("replica_failure", replica=r.id,
                                 error=repr(exc), tick=self._tick)
        if isinstance(exc, TransportError) and not exc.retryable:
            # the worker process is gone (EOF, broken pipe, corrupt
            # stream): leave a post-mortem of the supervisor's last view
            self.obs.recorder.dump("worker_eof")
        elif isinstance(exc, CacheCorruptionError):
            self.obs.recorder.dump("cache_corruption")
        for req_id, was_inflight, pos in r.salvage():
            b = self._book[req_id]
            if b.done:
                continue
            if was_inflight:
                # positions computed on the dead replica: the prefilled
                # prompt span is genuinely lost compute; tokens emitted
                # this incarnation were already journaled/streamed and
                # merely ride the next resume prompt
                self.wasted_compute_tokens += pos
                self.replayed_emitted_tokens += \
                    len(b.emitted) - b.base_emitted
            if self._emission_complete(b):
                self._finish(req_id, "ok", replica=r.id)
                continue
            b.replays += 1 if was_inflight else 0
            if b.replays > self.cfg.max_request_replays:
                self._finish(req_id, "failed", replica=r.id)
                continue
            # the replica-local request may be a resume (concatenated
            # prompt, shrunk budget, drained deadline) — always re-queue
            # the ORIGINAL from the book; emitted tokens ride separately
            self.obs.tracer.instant("salvage", tid=0, request_id=req_id,
                                    replica=r.id,
                                    inflight=int(was_inflight))
            self._queue.append((b.arrival, b.req))
        self._queue = deque(sorted(self._queue, key=lambda t: t[0]))
        r.alive = False
        r.restarts += 1
        if r.restarts > self.cfg.max_restarts:
            r.dead = True
            r.hard_kill()
            return
        r.restart_at = self.clock.now() + backoff_delay(
            r.restarts - 1, self.cfg.backoff_base_s,
            self.cfg.backoff_factor, self.cfg.backoff_jitter, self._rng)

    def _restart(self, r) -> None:
        """Rebuild: in-process, a fresh cache via ``scheduler.start``
        (params optionally reloaded from the latest checksum-verified
        checkpoint); cross-process, a fresh worker spawn."""
        if r.kind == "inproc" and self.checkpointer is not None:
            try:
                params, _ = self.checkpointer.restore(r.engine.params)
                r.engine.params = params
            except FileNotFoundError:
                pass  # no complete checkpoint yet: keep in-memory params
        with self.obs.tracer.span("worker_respawn", tid=0, replica=r.id,
                                  restarts=r.restarts):
            r.start()
        self.obs.recorder.record("restart", replica=r.id,
                                 restarts=r.restarts)
        r.alive = True

    def _fail_everything(self) -> None:
        """Every replica is permanently dead: remaining requests cannot be
        served — terminal ``failed``, never a hang or a silent drop."""
        self.obs.recorder.record("fleet_dead", tick=self._tick,
                                 queued=len(self._queue))
        self.obs.recorder.dump("fleet_dead")
        for arr, req in list(self._queue) + list(self._future):
            self._finish(req.id, "failed", replica=-1)
        self._queue.clear()
        self._future = []

    # ------------------------------------------------------- health + time
    def _health_check(self) -> None:
        plan = self.monitor.check(now=self.clock.now())
        if not plan.straggler_hosts:
            return
        self.straggler_events += 1
        if not self.cfg.restart_stragglers:
            return
        for rid in plan.straggler_hosts:
            r = self.replicas[rid]
            if r.alive and not r.dead:
                self._ingest(r, r.take_pending())
                self._on_failure(r, TimeoutError(
                    f"replica {rid} straggling (health-monitor verdict)"))

    def _checkpoint(self, blocking: bool) -> None:
        try:
            if self._host_faults is not None:
                self._host_faults.begin_step()
            with self.obs.tracer.span("checkpoint", tid=0, tick=self._tick):
                self.checkpointer.save(self._tick,
                                       self.replicas[0].engine.params,
                                       blocking=blocking)
        except Exception:  # capture-and-continue: checkpoint failure is
            self.ckpt_failures += 1  # not a serving failure; the previous
            # complete checkpoint remains authoritative

    def _done(self) -> bool:
        if self._future or self._queue:
            return False
        return all(r.dead or r.done for r in self.replicas)

    def _advance_to_next_event(self) -> None:
        """Nothing progressed: jump the clock to the next arrival or
        pending restart (virtual clocks need this to move at all; a real
        clock just sleeps out the gap)."""
        events = [self._t0 + arr for arr, _ in self._future[:1]]
        events += [r.restart_at for r in self.replicas
                   if not r.alive and not r.dead]
        if not events:
            return
        self.clock.sleep(max(1e-4, min(events) - self.clock.now()))
