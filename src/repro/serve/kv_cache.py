"""Pluggable KV-cache backends for the serving engine.

The Engine's cache surface is a ``CacheBackend``: the scheduler never
touches a raw cache pytree again — it asks the backend to ``alloc`` a
slot for a prompt (learning how many leading tokens are already cached),
runs ``prefill_chunk``/``prefill_chunks``/``decode`` steps, and ``free``s
the slot at retirement. Two backends ship:

  * **DenseCacheBackend** — the reference oracle: one ``(L, B, max_seq,
    KV, hd)`` envelope per serve, exactly the cache the engine always
    owned, now threaded privately through the backend (donation-safe:
    callers can no longer hand a consumed cache back).
  * **PagedCacheBackend** — a block-table cache: all KV lives in one
    pooled ``(L, num_pages, page, KV, hd)`` buffer; each slot maps a row
    of physical pages through an int32 page table; retired pages return
    to a free list the moment the slot frees. On top rides radix-style
    prefix sharing: completed prompt pages register in a trie keyed by
    their token content, a newly admitted request walks the trie and maps
    every matching full page read-only (refcounted), and the first
    divergent page is copy-on-written — so a fleet of same-system-prompt
    requests prefills the shared prefix once.

Bitwise parity by construction: the paged backend *gathers* its pages
into exactly the dense ``(L, B, S, KV, hd)`` view and runs the very same
compiled prefill/decode executables the dense backend runs, then
scatters touched pages back. K/V entries are position-local (same token
at the same absolute position quantizes/ropes to the same bytes), so
shared pages, copy-on-write copies and the scheduler's near-``max_seq``
overlap re-prefills are all bitwise-identical to an unshared run — the
scheduler's oracle tests hold verbatim with ``backend="paged"``. Note
the cost: every paged step materializes that dense-footprint temporary,
so on the gather route the paged backend buys slot density and prefix
reuse, not peak memory. ``CacheConfig(decode_kernel=...)`` now routes
the per-token decode step around that detour: ``"paged"`` (or
``"auto"`` on TPU) runs ``kernels.decode_attention.flash_decode_gqa_
paged`` directly against the pools — K/V written at page-table
positions, no dense temporary — at allclose (not bitwise) parity with
the gather route, since the kernel's online softmax normalizes
divide-after where the decode formula divides before. Prefill and the
speculative window keep the gather route (the bitwise-oracle paths).

Speculative decode support: ``spec_window`` drafts k tokens from the
rank-truncated model and verifies the window in ONE pass. The draft's
cache updates are internal to its executable and discarded; verify
inserts all k+1 window tokens' K/V at positions ``length..length+k``.
Because ``alloc`` reserves every page a request can ever touch
(prompt + max_new) up front, those writes land in the slot's own
exclusive pages (shared read-only prefix pages cover only positions
< plen, and writes beyond the reservation hit the scratch sink), so
``rollback`` after partial acceptance is pure length bookkeeping —
page tables and refcounts are bitwise what a never-drafted run holds,
which the rollback tests assert directly.

Admission control: ``alloc`` raises ``PageExhaustionError`` when the
pool cannot hold a request — ``permanent=True`` when the request could
never fit even an empty pool (the scheduler retires it ``rejected``),
``permanent=False`` when pages are merely busy right now (the request
stays queued). Trie-held pages with no live readers are LRU-evicted
before either verdict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import Counter


def _bind_backend_obs(backend) -> None:
    """Register the backend's live counters (and utilization gauges) in
    the engine's obs registry. Called from ``start()`` — by then the
    scheduler has propagated its Obs (and any replica labels) onto the
    engine, so fleet replicas land under distinct label sets. Counts are
    never copied: the snapshot sees the same Counter objects ``stats()``
    reads."""
    from ..obs import Obs  # deferred: obs never imports serve, this is safe
    engine = backend.engine
    if getattr(engine, "obs", None) is None:
        engine.obs = Obs()
    labels = dict(getattr(engine, "obs_labels", None) or {})
    labels["backend"] = backend.name
    reg = engine.obs.registry
    for name, c in backend._obs_counters().items():
        reg.register_counter(f"cache.{name}", c, **labels)
    backend._g_util = reg.gauge("cache.page_utilization", **labels)
    backend._g_hit = reg.gauge("cache.prefix_hit_rate", **labels)


class PageExhaustionError(RuntimeError):
    """The page pool cannot serve an ``alloc``. ``permanent`` says the
    request could never fit (reject it) vs pages being busy right now
    (keep it queued)."""

    def __init__(self, msg: str, permanent: bool):
        super().__init__(msg)
        self.permanent = permanent


@dataclasses.dataclass
class CacheConfig:
    """Every cache knob in one place, consumed by both backends (the
    serve CLI maps ``--cache-backend/--page-size/--prefix-cache`` here).

    ``kv_cache_bits=None`` defers to the model config; 8 forces the int8
    per-(token, head) quantized cache regardless of what the model was
    built with. ``num_pages=None`` sizes the paged pool to the dense
    footprint (``max_slots * ceil(max_seq / page_size)``) — prefix
    sharing then strictly *adds* capacity headroom."""
    backend: str = "dense"              # dense | paged
    max_slots: int = 8
    max_seq: int = 1024
    page_size: int = 16
    num_pages: Optional[int] = None
    prefix_cache: bool = True
    kv_cache_bits: Optional[int] = None
    donate_cache: Optional[bool] = None
    decode_kernel: str = "auto"         # paged backend's decode route:
                                        # "gather" = dense-view detour (the
                                        # bitwise oracle), "paged" = the
                                        # flash_decode_gqa_paged kernel
                                        # (interpret mode off-TPU; allclose
                                        # parity), "auto" = kernel on TPU
                                        # only (interpret mode is a
                                        # validation tool, not a fast path)

    def __post_init__(self):
        if self.backend not in ("dense", "paged"):
            raise ValueError(f"cache backend {self.backend!r} "
                             "(one of dense|paged)")
        if self.backend == "paged" and self.page_size < 1:
            raise ValueError(f"page_size={self.page_size} must be >= 1")
        if self.decode_kernel not in ("auto", "gather", "paged"):
            raise ValueError(f"decode_kernel {self.decode_kernel!r} "
                             "(one of auto|gather|paged)")

    def resolve_donate(self) -> bool:
        """Single resolution of cache donation for every cache-threading
        executable (see ``ServeConfig.resolve_donate`` for why they must
        agree). XLA:CPU ignores donation but JAX still invalidates the
        buffer, so default off there."""
        if self.donate_cache is None:
            return jax.default_backend() != "cpu"
        return bool(self.donate_cache)

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_seq // self.page_size)

    @property
    def total_pages(self) -> int:
        if self.num_pages is not None:
            return int(self.num_pages)
        return self.max_slots * self.pages_per_slot


class CacheBackend:
    """Protocol both backends implement. The backend OWNS the device
    cache state — donation-safe by construction: every compute call
    rebinds the internal state to the executable's return, so no caller
    can ever hand a consumed cache back."""

    name = "abstract"

    def start(self) -> None:
        raise NotImplementedError

    def alloc(self, slot: int, prompt: np.ndarray, max_new: int) -> int:
        """Reserve capacity for ``prompt`` + ``max_new`` in ``slot``;
        returns how many leading prompt tokens are ALREADY cached (a
        prefix-cache hit; always <= len(prompt) - 1 so the final prompt
        position is re-computed for its logits). Raises
        ``PageExhaustionError`` when the pool cannot serve it."""
        raise NotImplementedError

    def free(self, slot: int) -> None:
        raise NotImplementedError

    def register_prompt(self, slot: int, prompt: np.ndarray) -> None:
        """Called once the slot's prompt is fully prefilled — the paged
        backend registers completed prompt pages in the prefix trie."""
        raise NotImplementedError

    def prefill_chunk(self, slot: int, tokens, start: int, last: int):
        """Single-slot chunked prefill; returns logits (1, 1, V)."""
        raise NotImplementedError

    def prefill_chunks(self, tokens, starts, lasts, active):
        """One (B, C) launch prefilling every active lane's chunk at its
        own start offset; inactive lanes' cache rows pass through
        bitwise-untouched. Returns logits (B, 1, V)."""
        raise NotImplementedError

    def decode(self, tokens, lengths):
        """One global decode step over per-slot lengths; returns logits
        (B, 1, V)."""
        raise NotImplementedError

    def spec_window(self, tokens, lengths, k: int):
        """One speculative window: draft ``k`` greedy tokens per slot from
        the rank-truncated model (draft K/V never persist), then verify
        the whole window in one pass (window K/V inserted at
        ``lengths[b]..lengths[b]+k``). tokens: (B,) current token per
        slot; lengths: (B,) cached prefix per slot; caller guarantees
        ``max(lengths) + k + 1 <= max_seq``. Returns (draft (B, k) int32,
        logits (B, k+1, V)) — logits row j bitwise-identical to the j-th
        sequential ``decode`` step. The caller must ``rollback`` with the
        post-acceptance lengths afterward."""
        raise NotImplementedError

    def rollback(self, lengths) -> None:
        """Truncate per-slot lengths to the accepted window prefix after
        ``spec_window``. Rejected tokens' K/V stay past the new lengths
        as stale masked entries — both backends make this pure
        bookkeeping (the paged backend's up-front page reservation means
        no tail pages or refcounts ever moved during the window)."""
        raise NotImplementedError

    # fault-injection surface: the scheduler's "step"-site hook corrupts
    # whatever pytree this exposes (the dense cache / the page pools)
    @property
    def device_state(self):
        raise NotImplementedError

    @device_state.setter
    def device_state(self, value):
        raise NotImplementedError

    def stats(self) -> dict:
        raise NotImplementedError


def make_backend(engine) -> CacheBackend:
    cfg = engine.cfg.cache
    if cfg.backend == "paged":
        return PagedCacheBackend(engine)
    return DenseCacheBackend(engine)


# ---------------------------------------------------------------------------
# Dense reference backend
# ---------------------------------------------------------------------------

class DenseCacheBackend(CacheBackend):
    """The pre-paging cache, behind the backend protocol: one
    ``(L, B, max_seq, KV, hd)`` envelope, no sharing, ``alloc`` always a
    full-prefill miss. This is the parity oracle the paged backend is
    tested against."""

    name = "dense"

    def __init__(self, engine):
        self.engine = engine
        self._cache = None
        self._lengths = np.zeros(engine.cfg.max_slots, np.int64)
        # registry-backed accounting (old attribute names stay readable
        # as properties; the drain report and --metrics-json snapshot
        # read the SAME storage)
        self._c_launches = Counter()
        self._c_tokens = Counter()

    def _obs_counters(self) -> dict:
        return {"prefill_launches": self._c_launches,
                "prefill_tokens": self._c_tokens}

    @property
    def n_prefill_launches(self) -> int:
        return self._c_launches.value

    @property
    def n_prefill_tokens(self) -> int:
        return self._c_tokens.value

    def _legacy(self, name: str, impl):
        """Instance-level overrides of the deprecated Engine primitives
        (tests wrap them to audit cache threading) stay visible to the
        backend; otherwise skip the shim straight to the impl so the
        internal path never trips its own deprecation warning."""
        fn = self.engine.__dict__.get(name)
        return impl if fn is None else fn

    def start(self) -> None:
        self._cache = self.engine._new_cache_impl()
        self._lengths[:] = 0
        self._c_launches.reset()
        self._c_tokens.reset()
        _bind_backend_obs(self)

    def alloc(self, slot: int, prompt: np.ndarray, max_new: int) -> int:
        return 0

    def free(self, slot: int) -> None:
        self._lengths[slot] = 0

    def register_prompt(self, slot: int, prompt: np.ndarray) -> None:
        pass

    def prefill_chunk(self, slot: int, tokens, start: int, last: int):
        fn = self._legacy("prefill_slot_chunk",
                          self.engine._prefill_slot_impl)
        logits, self._cache = fn(self._cache, slot, tokens, start, last)
        self._c_launches.inc()
        self._c_tokens.inc(len(tokens))
        self._lengths[slot] = start + len(tokens)
        return logits

    def prefill_chunks(self, tokens, starts, lasts, active):
        logits, self._cache = self.engine._prefill_slots_impl(
            self._cache, tokens, starts, lasts, active)
        self._c_launches.inc()
        self._c_tokens.inc(int(np.sum(active)) * tokens.shape[1])
        for i, on in enumerate(active):
            if on:
                self._lengths[i] = int(starts[i]) + tokens.shape[1]
        return logits

    def decode(self, tokens, lengths):
        fn = self._legacy("decode_slots", self.engine._decode_slots_impl)
        logits, self._cache = fn(self._cache, tokens, lengths)
        self._lengths[:] = np.asarray(lengths)
        return logits

    def spec_window(self, tokens, lengths, k: int):
        lens = np.asarray(lengths, np.int64)
        # draft reads the cache without consuming it (no donation) — its
        # own K/V writes are internal to the executable and discarded
        draft = np.asarray(self.engine._draft_slots_impl(
            self._cache, tokens, lens, k))
        window = np.concatenate(
            [np.asarray(tokens, np.int32)[:, None], draft], axis=1)
        logits, self._cache = self.engine._verify_slots_impl(
            self._cache, window, lens)
        self._lengths[:] = lens + k + 1  # provisional; rollback() finalizes
        return draft, logits

    def rollback(self, lengths) -> None:
        # Dense rollback IS the length truncation: rejected tokens' K/V
        # sit past the accepted length in the (L, B, S, KV, hd) envelope,
        # i.e. in the standard stale-masked region every later write
        # overwrites.
        self._lengths[:] = np.asarray(lengths)

    @property
    def device_state(self):
        return self._cache

    @device_state.setter
    def device_state(self, value):
        self._cache = value

    def stats(self) -> dict:
        cap = self.engine.cfg.max_slots * self.engine.cfg.max_seq
        util = float(self._lengths.sum()) / max(cap, 1)
        if hasattr(self, "_g_util"):
            self._g_util.set(util)
            self._g_hit.set(0.0)
        return dict(
            backend=self.name,
            page_utilization=util,
            prefix_hit_rate=0.0,
            prefill_launches=self.n_prefill_launches,
            prefill_tokens=self.n_prefill_tokens,
        )


# ---------------------------------------------------------------------------
# Paged backend: block tables + radix prefix trie
# ---------------------------------------------------------------------------

class _TrieNode:
    """One full page of prompt KV in the radix prefix trie, keyed (in its
    parent's children dict) by the page's token tuple."""
    __slots__ = ("children", "phys", "parent", "key", "stamp")

    def __init__(self, phys: int, parent: "Optional[_TrieNode]",
                 key: Optional[Tuple[int, ...]]):
        self.children: Dict[Tuple[int, ...], _TrieNode] = {}
        self.phys = phys
        self.parent = parent
        self.key = key
        self.stamp = 0


class PagedCacheBackend(CacheBackend):
    """Block-table KV cache with radix prefix sharing (see module
    docstring). Host state: an int32 page table per slot (unallocated
    entries point at a scratch page that absorbs masked garbage writes),
    a free list, per-page refcounts, and the prefix trie. Device state:
    one pooled buffer per cache leaf, shaped ``(L, P, page, KV, hd)``."""

    name = "paged"

    def __init__(self, engine):
        self.engine = engine
        cfg = engine.cfg.cache
        self.page = cfg.page_size
        self.pps = cfg.pages_per_slot
        self.num_pages = cfg.total_pages
        self.max_slots = engine.cfg.max_slots
        self.max_seq = engine.cfg.max_seq
        self.prefix_cache = cfg.prefix_cache
        self._scratch = self.num_pages          # physical index P-1
        self._pools = None
        self._built = False
        # host-side tables (rebuilt by start())
        self._table = np.full((self.max_slots, self.pps), self._scratch,
                              np.int32)
        self._alloc_pages = np.zeros(self.max_slots, np.int64)
        self._free: List[int] = []
        self._ref = np.zeros(self.num_pages + 1, np.int64)
        self._trie_root = _TrieNode(-1, None, None)
        self._trie_pages: set = set()
        self._node_of: Dict[int, _TrieNode] = {}
        self._tick = 0
        self._lengths = np.zeros(self.max_slots, np.int64)
        self._kernel = False
        self._kernel_route = "unresolved (start() not called)"
        # registry-backed stats (old attribute names stay readable as
        # properties; one storage location shared with the snapshot)
        self._c_launches = Counter()
        self._c_tokens = Counter()
        self._c_hit = Counter()
        self._c_prompt = Counter()
        self._c_cow = Counter()
        self._c_evict = Counter()

    def _obs_counters(self) -> dict:
        return {"prefill_launches": self._c_launches,
                "prefill_tokens": self._c_tokens,
                "hit_tokens": self._c_hit,
                "prompt_tokens": self._c_prompt,
                "cow_copies": self._c_cow,
                "evictions": self._c_evict}

    @property
    def n_prefill_launches(self) -> int:
        return self._c_launches.value

    @property
    def n_prefill_tokens(self) -> int:
        return self._c_tokens.value

    @property
    def hit_tokens(self) -> int:
        return self._c_hit.value

    @property
    def prompt_tokens(self) -> int:
        return self._c_prompt.value

    @property
    def cow_copies(self) -> int:
        return self._c_cow.value

    @property
    def evictions(self) -> int:
        return self._c_evict.value

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """(Re)build the pool, tables, free list and trie. A supervisor
        restart lands here: page tables and the prefix trie are rebuilt
        from scratch and shared prefixes re-pin as the salvaged requests
        re-prefill (resume prompts re-register and re-share naturally)."""
        self._pools = self._init_pools()
        self._table[:] = self._scratch
        self._alloc_pages[:] = 0
        self._free = list(range(self.num_pages))
        self._ref[:] = 0
        self._trie_root = _TrieNode(-1, None, None)
        self._trie_pages = set()
        self._node_of = {}
        self._tick = 0
        self._lengths[:] = 0
        for c in self._obs_counters().values():
            c.reset()
        _bind_backend_obs(self)
        self._kernel = self._use_paged_kernel()
        if not self._built:
            self._build_helpers()
            self._built = True

    def _init_pools(self):
        """Pool pytree mirroring the dense cache's leaves: dense
        (L, B, S, ...) becomes (L, P+1, page, ...) (the +1 is the scratch
        page garbage sink)."""
        dense = jax.eval_shape(
            lambda: self.engine.model.init_cache(1, self.page))
        p = self.num_pages + 1
        return {
            k: jnp.zeros((leaf.shape[0], p) + leaf.shape[2:], leaf.dtype)
            for k, leaf in dense.items()
        }

    @property
    def s_padded(self) -> int:
        return self.pps * self.page

    def _build_helpers(self):
        """Jitted gather/scatter between pool and dense views. Views are
        cropped to EXACTLY max_seq so the compute executables see the
        same (L, B, S, ...) shapes (and therefore the same flash-block
        decomposition → bitwise-identical math) as the dense backend."""
        page, pps, s, sp = self.page, self.pps, self.max_seq, self.s_padded

        def gather(pools, flat):           # flat: (N*pps,) physical pages
            n = flat.shape[0] // pps

            def one(pool):
                v = pool[:, flat]          # (L, N*pps, page, ...)
                v = v.reshape((pool.shape[0], n, sp) + pool.shape[3:])
                return v[:, :, :s]
            return {k: one(v) for k, v in pools.items()}

        def pad_pages(view, pool):
            l, n = view.shape[0], view.shape[1]
            pad = [(0, 0), (0, 0), (0, sp - s)] + [(0, 0)] * (view.ndim - 3)
            v = jnp.pad(view, pad)
            return v.reshape((l, n * pps, page) + pool.shape[3:])

        def scatter(pools, view, flat):    # inverse of gather (donates pool)
            return {k: pools[k].at[:, flat].set(pad_pages(view[k], pools[k]))
                    for k in pools}

        def scatter_token_pages(pools, view, phys, pidx):
            """Persist, per slot, the single page containing its written
            decode position: phys (B,) physical targets, pidx (B,)
            logical page indices within each slot's row."""
            def one(pool, v):
                vp = pad_pages(v, pool).reshape(
                    (pool.shape[0], v.shape[1], pps, page) + pool.shape[3:])
                pick = jax.vmap(  # (L, B, pps, page, ...) -> (L, B, page, ..)
                    lambda vb, i: jax.lax.dynamic_index_in_dim(
                        vb, i, axis=1, keepdims=False),
                    in_axes=(1, 0), out_axes=1)(vp, pidx)
                return pool.at[:, phys].set(pick)
            return {k: one(pools[k], view[k]) for k in pools}

        donate = self.engine.cfg.resolve_donate()
        dn = dict(donate_argnums=(0,)) if donate else {}
        self._gather = jax.jit(gather)
        self._scatter = jax.jit(scatter, **dn)
        self._scatter_token = jax.jit(scatter_token_pages, **dn)
        self._copy_page = jax.jit(
            (lambda pools, src, dst:
             {k: v.at[:, dst].set(v[:, src]) for k, v in pools.items()}),
            **dn)

    # ------------------------------------------------------ page accounting
    def _evict(self, need: int) -> None:
        """LRU-evict trie-held pages with no live readers until ``need``
        pages are free (or nothing evictable remains). Leaf-first so a
        surviving chain never dangles. Pages an in-flight alloc must
        keep are pinned through ``_ref`` by the caller, which keeps
        them out of the victim set here."""
        while len(self._free) < need:
            victims = [n for n in self._node_of.values()
                       if not n.children and self._ref[n.phys] == 0]
            if not victims:
                return
            v = min(victims, key=lambda n: n.stamp)
            v.parent.children.pop(v.key, None)
            self._trie_pages.discard(v.phys)
            del self._node_of[v.phys]
            self._free.append(v.phys)
            self._c_evict.inc()

    def _take_page(self) -> int:
        return self._free.pop()

    # -------------------------------------------------------- prefix match
    def _match(self, prompt: np.ndarray):
        """Walk the trie with full prompt pages. Returns (shared physical
        pages, CoW source page or None, in-page common-prefix length)."""
        plen = len(prompt)
        f_max = (plen - 1) // self.page     # full pages strictly before
        node = self._trie_root              # the last live prompt position
        shared: List[int] = []
        self._tick += 1
        for j in range(f_max):
            key = tuple(int(t) for t in
                        prompt[j * self.page:(j + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = self._tick
            shared.append(child.phys)
            node = child
        # first divergent page: copy-on-write if it shares an in-page
        # prefix with some sibling (the copied entries are valid because
        # K/V are position-local; everything past cp is re-prefilled)
        m = len(shared)
        lo, hi = m * self.page, min((m + 1) * self.page, plen - 1)
        want = [int(t) for t in prompt[lo:min(lo + self.page, plen)]]
        best_src, best_cp = None, 0
        for key, child in node.children.items():
            cp = 0
            for a, b in zip(key, want):
                if a != b or lo + cp >= hi:
                    break
                cp += 1
            if cp > best_cp:
                best_src, best_cp = child.phys, cp
        return shared, best_src, best_cp

    # ------------------------------------------------------------ protocol
    def alloc(self, slot: int, prompt: np.ndarray, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32)
        plen = len(prompt)
        need_pages = -(-(plen + max_new) // self.page)
        if need_pages > self.num_pages:
            raise PageExhaustionError(
                f"request needs {need_pages} pages "
                f"({plen}+{max_new} tokens @ page={self.page}) but the "
                f"pool holds {self.num_pages} — can never fit",
                permanent=True)
        shared, cow_src, cow_cp = ([], None, 0) if not self.prefix_cache \
            else self._match(prompt)
        m = len(shared)
        fresh_needed = need_pages - m
        # Pin the matched pages *before* any eviction: a matched leaf
        # with no other live readers is otherwise an eligible victim, and
        # _take_page pops the free-list tail — the page this request is
        # about to map read-only would come straight back as its own
        # fresh writable page and prefill would clobber the shared prefix.
        # The CoW source is deliberately NOT pinned: it is read exactly
        # once, inside this alloc (the _copy_page below runs before any
        # write can touch the pool), so an evicted-and-recycled cow_src
        # still holds valid bytes at copy time — while pinning it would
        # livelock a pool-sized request whose only evictable pages are
        # its own prefix. With only matches pinned, every request that
        # passes the can-never-fit check above is admissible once live
        # slots drain: free + evictable = num_pages - held_live - m.
        for phys in shared:
            self._ref[phys] += 1
        if fresh_needed > len(self._free):
            self._evict(fresh_needed)
        if fresh_needed > len(self._free):
            for phys in shared:   # unpin: the request stays queued
                self._ref[phys] -= 1
            raise PageExhaustionError(
                f"pool exhausted: need {fresh_needed} fresh pages, "
                f"{len(self._free)} free (of {self.num_pages})",
                permanent=False)
        self._table[slot, :] = self._scratch
        for j, phys in enumerate(shared):
            self._table[slot, j] = phys   # ref already pinned above
        for j in range(m, need_pages):
            phys = self._take_page()
            self._table[slot, j] = phys
            self._ref[phys] += 1
        self._alloc_pages[slot] = need_pages
        matched = m * self.page
        if cow_src is not None and cow_cp > 0:
            self._pools = self._copy_page(
                self._pools, cow_src, int(self._table[slot, m]))
            self._c_cow.inc()
            matched += cow_cp
        matched = min(matched, plen - 1)
        self._lengths[slot] = matched
        self._c_hit.inc(matched)
        self._c_prompt.inc(plen)
        return matched

    def free(self, slot: int) -> None:
        for j in range(int(self._alloc_pages[slot])):
            phys = int(self._table[slot, j])
            if phys == self._scratch:
                continue
            self._ref[phys] -= 1
            if self._ref[phys] == 0 and phys not in self._trie_pages:
                self._free.append(phys)
        self._table[slot, :] = self._scratch
        self._alloc_pages[slot] = 0
        self._lengths[slot] = 0

    def register_prompt(self, slot: int, prompt: np.ndarray) -> None:
        """Register the slot's completed full prompt pages in the trie so
        later same-prefix requests share them."""
        if not self.prefix_cache:
            return
        prompt = np.asarray(prompt, np.int32)
        node = self._trie_root
        self._tick += 1
        for j in range(len(prompt) // self.page):
            key = tuple(int(t) for t in
                        prompt[j * self.page:(j + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                phys = int(self._table[slot, j])
                if phys == self._scratch or phys in self._trie_pages:
                    break  # overlap chunks may leave stale rows; bail
                child = _TrieNode(phys, node, key)
                node.children[key] = child
                self._trie_pages.add(phys)
                self._node_of[phys] = child
            child.stamp = self._tick
            node = child

    # --------------------------------------------------------- device views
    def _flat_table(self, rows) -> jnp.ndarray:
        return jnp.asarray(self._table[rows].reshape(-1), jnp.int32)

    def prefill_chunk(self, slot: int, tokens, start: int, last: int):
        row = self._gather(self._pools, self._flat_table([slot]))
        logits, row = self.engine._prefill_slot_impl(
            row, 0, tokens, start, last)
        self._pools = self._scatter(self._pools, row,
                                    self._flat_table([slot]))
        self._c_launches.inc()
        self._c_tokens.inc(len(tokens))
        self._lengths[slot] = start + len(tokens)
        return logits

    def prefill_chunks(self, tokens, starts, lasts, active):
        flat = self._flat_table(list(range(self.max_slots)))
        view = self._gather(self._pools, flat)
        logits, view = self.engine._prefill_slots_impl(
            view, tokens, starts, lasts, active)
        self._pools = self._scatter(self._pools, view, flat)
        self._c_launches.inc()
        self._c_tokens.inc(int(np.sum(active)) * tokens.shape[1])
        for i, on in enumerate(active):
            if on:
                self._lengths[i] = int(starts[i]) + tokens.shape[1]
        return logits

    def _use_paged_kernel(self) -> bool:
        """Resolve the decode route once per serve: the Pallas kernel
        route needs model support (no sliding window / softcap) and —
        under "auto" — a real TPU; interpret mode is a validation tool,
        orders of magnitude slower than the gather route on CPU. The
        resolution is recorded in stats() so a fallback is never
        silent."""
        want = self.engine.cfg.cache.decode_kernel
        if want == "gather":
            self._kernel_route = "gather (explicitly requested)"
            return False
        stack = self.engine.model.stack
        ok, why = stack.paged_kernel_supported() \
            if hasattr(stack, "paged_kernel_supported") \
            else (False, "model family has no paged decode path")
        if not ok:
            self._kernel_route = f"gather ({why})"
            return False
        if want == "paged":
            self._kernel_route = "paged (explicitly requested)"
            return True
        if jax.default_backend() == "tpu":
            self._kernel_route = "paged (auto: TPU)"
            return True
        self._kernel_route = ("gather (auto on "
                              f"{jax.default_backend()}: interpret-mode "
                              "kernel is validation-only)")
        return False

    def decode(self, tokens, lengths):
        lens = np.asarray(lengths, np.int64)
        if self._kernel:
            # kernel route: K/V land straight in the pools at page-table
            # positions and attention gathers by page inside the kernel —
            # no dense-footprint temporary. Allclose (not bitwise) to the
            # gather route; the bitwise-oracle paths (prefill, spec
            # window) stay on gather.
            logits, self._pools = self.engine._decode_paged_impl(
                self._pools, tokens, jnp.asarray(self._table, jnp.int32),
                lens)
            self._lengths[:] = lens
            return logits
        flat = self._flat_table(list(range(self.max_slots)))
        view = self._gather(self._pools, flat)
        logits, view = self.engine._decode_slots_impl(view, tokens, lens)
        # persist exactly the page each slot wrote its token into (its
        # own exclusive page — or scratch for slots with nothing live)
        page_idx = np.minimum(lens // self.page, self.pps - 1)
        phys = self._table[np.arange(self.max_slots), page_idx]
        self._pools = self._scatter_token(
            self._pools, view, jnp.asarray(phys, jnp.int32),
            jnp.asarray(page_idx, jnp.int32))
        self._lengths[:] = lens
        return logits

    def spec_window(self, tokens, lengths, k: int):
        """One gather serves the whole window: draft k tokens on the
        dense view (the draft's K/V writes are internal to its executable
        and discarded — the view is not consumed), verify on the same
        view, scatter everything back once. Verify's window writes land
        at positions >= each slot's prefix length, which up-front page
        reservation places in the slot's own exclusive pages (shared
        prefix pages cover only full pages strictly before the last live
        prompt position; positions past the reservation route to the
        scratch sink) — so the full scatter writes shared pages back
        byte-identical and never needs a CoW or table change."""
        lens = np.asarray(lengths, np.int64)
        flat = self._flat_table(list(range(self.max_slots)))
        view = self._gather(self._pools, flat)
        draft = np.asarray(self.engine._draft_slots_impl(
            view, tokens, lens, k))
        window = np.concatenate(
            [np.asarray(tokens, np.int32)[:, None], draft], axis=1)
        logits, view = self.engine._verify_slots_impl(view, window, lens)
        self._pools = self._scatter(self._pools, view, flat)
        self._lengths[:] = lens + k + 1  # provisional; rollback() finalizes
        return draft, logits

    def rollback(self, lengths) -> None:
        # Length bookkeeping ONLY — and that is a tested invariant, not
        # an optimization: alloc() reserved every page this request can
        # touch (prompt + max_new) before its first token, so the window
        # allocated no tail pages and bumped no refcounts. The rollback
        # tests assert _table/_ref are bitwise-identical to a
        # never-drafted run's.
        self._lengths[:] = np.asarray(lengths)

    @property
    def device_state(self):
        return self._pools

    @device_state.setter
    def device_state(self, value):
        self._pools = value

    def stats(self) -> dict:
        live = int(np.sum(self._ref[:self.num_pages] > 0))
        resident = len(self._trie_pages)
        used = self.num_pages - len(self._free)
        if hasattr(self, "_g_util"):
            self._g_util.set(used / max(self.num_pages, 1))
            self._g_hit.set(self.hit_tokens / max(self.prompt_tokens, 1))
        return dict(
            backend=self.name,
            page_size=self.page,
            decode_route=self._kernel_route,
            num_pages=self.num_pages,
            pages_live=live,
            pages_resident=resident,
            page_utilization=used / max(self.num_pages, 1),
            prefix_hit_rate=self.hit_tokens / max(self.prompt_tokens, 1),
            hit_tokens=self.hit_tokens,
            prompt_tokens=self.prompt_tokens,
            cow_copies=self.cow_copies,
            evictions=self.evictions,
            prefill_launches=self.n_prefill_launches,
            prefill_tokens=self.n_prefill_tokens,
        )
