"""Serving runtime: slot-batched engine + continuous-batching scheduler."""
from .engine import Engine, Request, Result, ServeConfig
from .scheduler import ContinuousScheduler, SchedResult, StepTrace, bucket_sizes

__all__ = [
    "Engine", "Request", "Result", "ServeConfig",
    "ContinuousScheduler", "SchedResult", "StepTrace", "bucket_sizes",
]
