"""Serving runtime: slot-batched engine, continuous-batching scheduler,
deterministic fault injection, the multi-replica supervisor (in-process
or worker subprocesses over framed RPC), and the durable request
journal that makes recovery exactly-once."""
from .engine import Engine, Request, Result, ServeConfig
from .faults import (PROC_KINDS, CacheCorruptionError, Clock, FaultInjector,
                     FaultPlan, FaultSpec, InjectedFault, VirtualClock)
from .journal import Journal, JournalCorruptionError, ReplayEntry, replay_state
from .kv_cache import (CacheBackend, CacheConfig, DenseCacheBackend,
                       PagedCacheBackend, PageExhaustionError)
from .scheduler import (STATUSES, ContinuousScheduler, SchedResult, StepTrace,
                        bucket_sizes)
from .supervisor import (InprocReplica, Outcome, ProcessReplica, StepEvents,
                         Supervisor, SupervisorConfig, SupervisorCrash,
                         SupervisorReport)
from .transport import (FramedConnection, RPCClient, TransportConfig,
                        TransportError, WorkerError)
from .worker import (WorkerSpec, build_replica, model_config_from_dict,
                     model_config_to_dict)

__all__ = [
    "Engine", "Request", "Result", "ServeConfig",
    "CacheConfig", "CacheBackend", "DenseCacheBackend", "PagedCacheBackend",
    "PageExhaustionError",
    "ContinuousScheduler", "SchedResult", "StepTrace", "bucket_sizes",
    "STATUSES",
    "FaultPlan", "FaultSpec", "FaultInjector", "InjectedFault",
    "CacheCorruptionError", "Clock", "VirtualClock", "PROC_KINDS",
    "Supervisor", "SupervisorConfig", "SupervisorReport", "Outcome",
    "SupervisorCrash", "InprocReplica", "ProcessReplica", "StepEvents",
    "Journal", "JournalCorruptionError", "ReplayEntry", "replay_state",
    "FramedConnection", "RPCClient", "TransportConfig", "TransportError",
    "WorkerError",
    "WorkerSpec", "build_replica", "model_config_to_dict",
    "model_config_from_dict",
]
