"""Serving runtime: slot-batched engine, continuous-batching scheduler,
deterministic fault injection, and the multi-replica supervisor."""
from .engine import Engine, Request, Result, ServeConfig
from .faults import (CacheCorruptionError, Clock, FaultInjector, FaultPlan,
                     FaultSpec, InjectedFault, VirtualClock)
from .kv_cache import (CacheBackend, CacheConfig, DenseCacheBackend,
                       PagedCacheBackend, PageExhaustionError)
from .scheduler import (STATUSES, ContinuousScheduler, SchedResult, StepTrace,
                        bucket_sizes)
from .supervisor import Outcome, Supervisor, SupervisorConfig, SupervisorReport

__all__ = [
    "Engine", "Request", "Result", "ServeConfig",
    "CacheConfig", "CacheBackend", "DenseCacheBackend", "PagedCacheBackend",
    "PageExhaustionError",
    "ContinuousScheduler", "SchedResult", "StepTrace", "bucket_sizes",
    "STATUSES",
    "FaultPlan", "FaultSpec", "FaultInjector", "InjectedFault",
    "CacheCorruptionError", "Clock", "VirtualClock",
    "Supervisor", "SupervisorConfig", "SupervisorReport", "Outcome",
]
