"""Subprocess replica: one Engine + ContinuousScheduler behind framed RPC.

    REPRO_WORKER_SPEC='<json>' python -m repro.serve.worker

The supervisor's ``ProcessReplica`` spawns this entrypoint with a
``WorkerSpec`` (model config, seed, quantization, serve config, fault
plan) in the environment and drives it over stdin/stdout frames
(``serve.transport``). Design points that make the fleet survivable:

  * **stdout is the wire** — the first thing ``main`` does is dup the
    real stdout aside for frames and point fd 1 at stderr, so a stray
    ``print`` (JAX warnings, debug output) can never corrupt framing.
  * **Deterministic construction** — params come from
    ``model.init(PRNGKey(seed))`` (+ the same stacked FLRQ quantization
    the launcher runs), so a respawned worker is bit-identical to the
    one that died and to the in-process oracle; no weight shipping.
  * **Idempotent replies** — the last reply is cached by call id and
    retransmitted on a duplicate id instead of re-executing, so a
    partition that eats a reply cannot double-step the scheduler (which
    would duplicate emitted tokens).
  * **SIGTERM = graceful drain** — the handler only flips a flag: new
    submits are refused (the supervisor re-routes them), assigned work
    finishes normally, and once drained the worker replies
    ``exiting: true`` and exits 0. SIGKILL needs no handler — the
    supervisor detects EOF/exit and respawns; the journal +
    resume-prefill protocol makes the tokens safe, not the worker.
  * **Orphan cleanup** — EOF on stdin (the supervisor died) exits the
    worker, so a supervisor crash never leaks a process tree.
  * **Fault step offsets** — the ``start`` call carries the replica's
    lifetime step count, which offsets the fresh ``FaultInjector`` so a
    one-shot engine-fault coordinate never re-trips after a respawn
    (the same discipline the in-process injector keeps via its
    monotonic step counter).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import sys
from typing import List, Optional, Tuple

import numpy as np

SPEC_ENV = "REPRO_WORKER_SPEC"


@dataclasses.dataclass
class WorkerSpec:
    """Everything a worker needs to rebuild its replica from scratch —
    JSON-safe by construction (``ModelConfig.dtype`` rides as a string),
    so respawns and cross-process determinism cost one env var."""
    model: dict                 # ModelConfig fields (dtype as string)
    serve: dict                 # ServeConfig.to_dict()
    seed: int = 0
    scan: bool = True
    quantize_bits: int = 0      # 0 = serve fp weights
    blc_epochs: int = 0         # 0 = derive from bits (launcher default)
    max_rank: Optional[int] = None
    prefill_chunk: int = 32
    replica: int = 0
    fault_plan: str = ""        # full CLI plan; the worker's injector
                                # keeps only engine-level kinds
    nan_guard: bool = True
    trace: bool = False         # buffer scheduler spans and ship them in
                                # step replies ("ev") for supervisor-side
                                # timeline stitching

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "WorkerSpec":
        return cls(**json.loads(s))


def model_config_to_dict(cfg) -> dict:
    import jax.numpy as jnp
    d = dataclasses.asdict(cfg)
    d["dtype"] = jnp.dtype(cfg.dtype).name
    return d


def model_config_from_dict(d: dict):
    import jax.numpy as jnp

    from ..models.config import ModelConfig
    d = dict(d)
    d["dtype"] = jnp.dtype(d["dtype"]).type
    d["global_layers"] = tuple(d.get("global_layers", ()))
    return ModelConfig(**d)


def build_replica(spec: WorkerSpec):
    """Deterministically rebuild (engine, scheduler) from the spec —
    shared by the worker process and any test that wants the bit-exact
    in-process twin of a worker."""
    import jax

    from ..models import LM
    from .engine import Engine, ServeConfig
    from .faults import FaultPlan
    from .scheduler import ContinuousScheduler
    cfg = model_config_from_dict(spec.model)
    model = LM(cfg)
    if not spec.scan:
        model = model.with_scan(False)
    params = model.init(jax.random.PRNGKey(spec.seed))
    if spec.quantize_bits:
        from ..core.flrq import FLRQConfig
        from ..quant.stacked import quantize_model_stacked
        epochs = spec.blc_epochs or (2 if spec.quantize_bits > 2 else 8)
        fq = FLRQConfig(bits=spec.quantize_bits, blc_epochs=epochs)
        if spec.max_rank is not None:
            fq = dataclasses.replace(fq, max_rank=spec.max_rank)
        params, _ = quantize_model_stacked(params, None, fq)
    engine = Engine(model, params, ServeConfig.from_dict(spec.serve))
    injector = None
    plan = FaultPlan.parse(spec.fault_plan) if spec.fault_plan else None
    if plan:
        injector = plan.injector(spec.replica)
    obs = None
    if spec.trace:
        from ..obs import Obs
        obs = Obs(trace=True, process_name=f"worker-{spec.replica}")
    scheduler = ContinuousScheduler(
        engine, prefill_chunk=spec.prefill_chunk, faults=injector,
        nan_guard=spec.nan_guard, obs=obs)
    return engine, scheduler


class WorkerServer:
    """Method dispatch over one replica. Token events buffer between
    ``step`` calls and ride out in the step reply (the supervisor owns
    streaming and journaling; the worker owns compute)."""

    def __init__(self, spec: WorkerSpec):
        self.spec = spec
        self.engine, self.scheduler = build_replica(spec)
        self.scheduler.on_token = self._buffer
        self._events: List[Tuple[int, int, bool]] = []
        self._consumed = 0
        self.draining = False
        self.exit_after_reply = False

    def _buffer(self, req_id: int, tok: int, done: bool) -> None:
        self._events.append((req_id, tok, done))

    def drain(self, *_a) -> None:
        """SIGTERM: stop accepting, finish what's assigned, then exit."""
        self.draining = True

    # ------------------------------------------------------------- handlers
    def dispatch(self, method: str, p: dict):
        return getattr(self, f"_h_{method}")(p)

    def _h_ping(self, p):
        return {"pong": True, "draining": self.draining}

    def _h_start(self, p):
        if self.scheduler.faults is not None:
            # lifetime step offset: one-shot coordinates already spent by
            # the previous incarnation must not re-trip in this one
            self.scheduler.faults.step = int(p.get("fault_step_offset",
                                                   0)) - 1
        tracer = self.scheduler.obs.tracer
        if tracer.enabled and p.get("trace_id"):
            tracer.trace_id = str(p["trace_id"])
        self.scheduler.start()
        self._events = []
        self._consumed = 0
        rep = {"started": True}
        if tracer.enabled:
            # worker clock zero for supervisor-side offset stitching
            rep["t0_us"] = int(round(self.scheduler.obs.clock.now() * 1e6))
        return rep

    def _h_submit(self, p):
        if self.draining:
            return {"accepted": False, "draining": True}
        from .engine import Request
        req = Request(np.asarray(p["prompt"], np.int32),
                      max_new_tokens=int(p["new"]), id=int(p["id"]),
                      deadline_s=p.get("dl"))
        accepted = self.scheduler.submit(req)
        return {"accepted": bool(accepted), "draining": False}

    def _h_step(self, p):
        admitted_before = len(self.scheduler.admission_order)
        progressed = self.scheduler.step()
        events, self._events = self._events, []
        results = self.scheduler.results[self._consumed:]
        self._consumed = len(self.scheduler.results)
        done = self.scheduler.done
        if self.draining and done:
            self.exit_after_reply = True
        rep_extra = {}
        tracer = self.scheduler.obs.tracer
        if tracer.enabled:
            # spans recorded since the last step ride the reply; the
            # supervisor adopts them under this replica's pid
            rep_extra["ev"] = tracer.drain()
        return {
            **rep_extra,
            "progressed": bool(progressed),
            "events": [[int(r), int(t), bool(d)] for r, t, d in events],
            "results": [[int(r.id), r.status] for r in results],
            "admitted": [int(i) for i in
                         self.scheduler.admission_order[admitted_before:]],
            "progress": {str(k): int(v)
                         for k, v in self.scheduler.progress().items()},
            "free_slots": int(self.scheduler.free_slots),
            "done": bool(done),
            "draining": self.draining,
            "exiting": self.exit_after_reply,
        }

    def _h_shutdown(self, p):
        self.exit_after_reply = True
        return {"bye": True}


def serve_forever(spec: WorkerSpec, conn) -> int:
    from .transport import TransportError
    server = WorkerServer(spec)
    signal.signal(signal.SIGTERM, server.drain)
    last_id, last_reply = None, None
    while True:
        try:
            frame = conn.recv(timeout=None)
        except TransportError:
            return 0            # supervisor gone (EOF): orphan cleanup
        if frame.get("t") != "call":
            continue
        cid = frame.get("id")
        if cid == last_id and last_reply is not None:
            conn.send(last_reply)   # duplicate id: retransmit, never
            continue                # re-execute (exactly-once steps)
        try:
            result = server.dispatch(frame.get("m", ""),
                                     frame.get("p") or {})
            reply = {"t": "reply", "id": cid, "ok": True, "r": result}
        except Exception as e:  # noqa: BLE001 — a replica failure is a
            # reply, not a worker death: the pipe stays healthy and the
            # supervisor routes it through salvage-and-respawn
            reply = {"t": "reply", "id": cid, "ok": False, "err": repr(e)}
        last_id, last_reply = cid, reply
        try:
            conn.send(reply)
        except TransportError:
            return 0
        if server.exit_after_reply:
            return 0


def main(argv=None) -> int:
    # frames ride the REAL stdout; fd 1 then aliases stderr so stray
    # prints (library warnings) can never corrupt the wire
    wire_fd = os.dup(1)
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    from .transport import FramedConnection
    raw = os.environ.get(SPEC_ENV)
    if not raw and argv:
        raw = pathlib_read(argv[0])
    if not raw:
        print(f"worker: no spec ({SPEC_ENV} unset)", file=sys.stderr)
        return 2
    spec = WorkerSpec.from_json(raw)
    conn = FramedConnection(read_fd=0, write_fd=wire_fd)
    return serve_forever(spec, conn)


def pathlib_read(path: str) -> str:
    import pathlib
    return pathlib.Path(path).read_text()


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
