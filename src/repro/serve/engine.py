"""Batched serving engine with KV-cache management and FLRQ-quantized
weights as a first-class path.

The engine serves a fixed-shape decode slot-batch (continuous batching):
requests occupy slots; prefill fills a slot's cache region; every decode
step advances all active slots by one token. Fixed shapes keep a single
compiled executable for the whole serving lifetime (no recompiles at scale).

Quantized serving: pass ``params`` whose matrices are QuantizedLinear
(from ``quant.stacked.quantize_model_stacked``) — the stacked tensors ride
``lax.scan`` through the layer body (one compiled body per executable) and
every quantized matmul routes through the backend-dispatch layer
(``quant.apply``): ``ServeConfig.backend`` picks the pure-jnp reference
("ref"), the fused Pallas kernel ("fused"; the paper's Fig. 3 deployment
y = deq(W_q)·x + U(V·x)), or "auto" (kernel on TPU when supported, ref
elsewhere — bit-identical to ref off-TPU). Fallback decisions are recorded
in ``quant.apply.dispatch_log`` — never silent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import LM
from ..quant.apply import backend_scope


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 8          # decode batch size
    max_seq: int = 1024         # cache capacity per slot
    eos_token: int = 1
    temperature: float = 0.0    # 0 = greedy
    backend: str = "auto"       # quantized-matmul backend: ref|fused|auto
    interpret: Optional[bool] = None  # force Pallas interpret (CPU testing)
    donate_cache: Optional[bool] = None  # None: donate where XLA supports it


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 32
    id: int = 0


@dataclasses.dataclass
class Result:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


class Engine:
    def __init__(self, model: LM, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg

        # The backend scope lives INSIDE the jitted callables so the policy
        # binds at trace time; each Engine owns its wrappers (and therefore
        # its trace cache), so two engines with different backends coexist.
        def prefill(p, toks):
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill(p, toks)

        def decode(p, tok, cache, length):
            with backend_scope(cfg.backend, cfg.interpret):
                return model.decode_step(p, tok, cache, length)

        # Donate the decode cache: each step's cache update then reuses the
        # previous step's buffers instead of allocating a second full-size
        # KV cache (the decode-memory floor at long context). XLA:CPU
        # ignores donation with a warning, so default it off there.
        donate = cfg.donate_cache
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self._decode = jax.jit(decode, donate_argnums=(2,)) if donate \
            else jax.jit(decode)
        self._prefill = jax.jit(prefill)

    # -------------------------------------------------------------- serving
    def generate(self, requests: List[Request]) -> List[Result]:
        """Slot-batched generation. Requests are padded/batched to the
        engine's fixed shapes; same-length prompt groups share one prefill."""
        out = []
        for chunk_start in range(0, len(requests), self.cfg.max_slots):
            chunk = requests[chunk_start:chunk_start + self.cfg.max_slots]
            out.extend(self._generate_chunk(chunk))
        return out

    def _generate_chunk(self, chunk: List[Request]) -> List[Result]:
        cfg = self.cfg
        b = cfg.max_slots
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(chunk):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # move prefill cache into the full-size decode cache
        full = self.model.init_cache(b, cfg.max_seq)

        def place(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)

        cache = jax.tree.map(place, full, cache)
        prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in chunk)
        cur = self._sample(logits)
        generated = [[int(cur[i])] for i in range(b)]
        length = plen
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, cur, cache, jnp.int32(length))
            length += 1
            cur = self._sample(logits)
            for i in range(b):
                generated[i].append(int(cur[i]))
        decode_s = time.perf_counter() - t0

        results = []
        for i, r in enumerate(chunk):
            toks_i = generated[i][: r.max_new_tokens]
            if self.cfg.eos_token in toks_i:
                toks_i = toks_i[: toks_i.index(self.cfg.eos_token) + 1]
            results.append(Result(r.id, toks_i, prefill_s, decode_s))
        return results

    def _sample(self, logits) -> jax.Array:
        lg = logits[:, -1, :]
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(int(time.time_ns()) & 0x7FFFFFFF)
        return jax.random.categorical(
            key, lg / self.cfg.temperature).astype(jnp.int32)
