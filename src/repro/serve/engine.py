"""Batched serving engine with KV-cache management and FLRQ-quantized
weights as a first-class path.

The engine serves a fixed-shape decode slot-batch (continuous batching):
requests occupy slots; prefill fills a slot's cache region; every decode
step advances all active slots by one token. Fixed shapes keep a single
compiled executable for the whole serving lifetime (no recompiles at scale).

Quantized serving: pass ``params`` whose matrices are QuantizedLinear
(from ``core.flrq.quantize_model``) — the model stacks route matmuls
through the low-rank-corrected dequant path automatically (see
``models.layers.mm``), matching the paper's fused-kernel deployment
(Fig. 3): y = deq(W_q)·x + U(V·x).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import LM


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 8          # decode batch size
    max_seq: int = 1024         # cache capacity per slot
    eos_token: int = 1
    temperature: float = 0.0    # 0 = greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 32
    id: int = 0


@dataclasses.dataclass
class Result:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


class Engine:
    def __init__(self, model: LM, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)

    # -------------------------------------------------------------- serving
    def generate(self, requests: List[Request]) -> List[Result]:
        """Slot-batched generation. Requests are padded/batched to the
        engine's fixed shapes; same-length prompt groups share one prefill."""
        out = []
        for chunk_start in range(0, len(requests), self.cfg.max_slots):
            chunk = requests[chunk_start:chunk_start + self.cfg.max_slots]
            out.extend(self._generate_chunk(chunk))
        return out

    def _generate_chunk(self, chunk: List[Request]) -> List[Result]:
        cfg = self.cfg
        b = cfg.max_slots
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(chunk):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # move prefill cache into the full-size decode cache
        full = self.model.init_cache(b, cfg.max_seq)

        def place(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)

        cache = jax.tree.map(place, full, cache)
        prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in chunk)
        cur = self._sample(logits)
        generated = [[int(cur[i])] for i in range(b)]
        length = plen
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, cur, cache, jnp.int32(length))
            length += 1
            cur = self._sample(logits)
            for i in range(b):
                generated[i].append(int(cur[i]))
        decode_s = time.perf_counter() - t0

        results = []
        for i, r in enumerate(chunk):
            toks_i = generated[i][: r.max_new_tokens]
            if self.cfg.eos_token in toks_i:
                toks_i = toks_i[: toks_i.index(self.cfg.eos_token) + 1]
            results.append(Result(r.id, toks_i, prefill_s, decode_s))
        return results

    def _sample(self, logits) -> jax.Array:
        lg = logits[:, -1, :]
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(int(time.time_ns()) & 0x7FFFFFFF)
        return jax.random.categorical(
            key, lg / self.cfg.temperature).astype(jnp.int32)
