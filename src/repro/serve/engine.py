"""Batched serving engine with KV-cache management and FLRQ-quantized
weights as a first-class path.

Two serving modes share the engine's compiled executables:

  * **Slot-chunked** (``generate``): requests are batched into fixed
    slot-chunks that prefill together and decode until the whole chunk
    drains. Simple, and kept as the A/B oracle for the scheduler.
  * **Slot-granular** (``serve.scheduler.ContinuousScheduler``): the
    engine exposes a pluggable cache surface — ``engine.cache_backend``
    (``serve.kv_cache.CacheBackend``: dense oracle or paged block-table
    with radix prefix sharing) wrapping the private slot executables
    (chunked/batched slot prefill via ``dynamic_update_slice``, one
    global decode step over per-slot lengths) — so a continuous-batching
    scheduler can admit/retire requests per slot without ever changing
    the compiled decode executable's shapes. (The PR 7 deprecation shims
    ``new_cache`` / ``prefill_slot_chunk`` / ``decode_slots`` completed
    their one-release cycle and are gone; the ``*_impl`` primitives are
    the only raw surface.)

Self-speculative decoding (``ServeConfig.speculative``): the FLRQ
decomposition means the quantized model contains its own draft model —
``truncate_rank`` of every QuantizedLinear (down to the rank-0 int4
backbone) is a strictly cheaper forward pass with high agreement to the
full target. The engine compiles, per window size k, a DRAFT executable
(k greedy decode steps against the rank-``draft_rank`` view; its cache
updates are internal to the call and discarded, so draft tokens never
pollute the real cache) and a VERIFY executable (``model.verify_slots``:
all k+1 window positions scored in ONE batched pass whose per-row logits
are bitwise identical to sequential decode steps). Greedy acceptance of
the longest agreeing prefix + the target's correction token then yields
token streams bitwise-identical to non-speculative decode — the parity
oracle the tests pin.

Quantized serving: pass ``params`` whose matrices are QuantizedLinear
(from ``quant.stacked.quantize_model_stacked``) — the stacked tensors ride
``lax.scan`` through the layer body (one compiled body per executable) and
every quantized matmul routes through the backend-dispatch layer
(``quant.apply``): ``ServeConfig.backend`` picks the pure-jnp reference
("ref"), the fused Pallas kernel ("fused"; the paper's Fig. 3 deployment
y = deq(W_q)·x + U(V·x)), or "auto" (kernel on TPU when supported, ref
elsewhere — bit-identical to ref off-TPU). Fallback decisions are recorded
in ``quant.apply.dispatch_log`` — never silent.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import LM
from ..quant.apply import backend_scope, draft_scope
from ..quant.qtensor import QuantizedLinear, dequantize_stacked, truncate_rank
from .kv_cache import CacheConfig, make_backend


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 8          # decode batch size
    max_seq: int = 1024         # cache capacity per slot
    eos_token: int = 1
    temperature: float = 0.0    # 0 = greedy
    backend: str = "auto"       # quantized-matmul backend: ref|fused|auto
    interpret: Optional[bool] = None  # force Pallas interpret (CPU testing)
    donate_cache: Optional[bool] = None  # None: donate where XLA supports it
    cache: Optional[CacheConfig] = None  # cache knobs; None = dense backend
                                         # built from the legacy fields above
    batched_prefill: bool = True  # one (B, C) launch per scheduler step
    # --- self-speculative decoding (greedy serving only) -------------------
    speculative: bool = False   # draft with the rank-truncated model, verify
                                # the window in one pass; tokens stay bitwise
                                # identical to non-speculative greedy decode
    draft_rank: int = 0         # low-rank columns kept in the draft view
                                # (0 = int4 backbone only; clamped to the
                                # stored rank). The R1-FLR quality knob.
    spec_k: int = 4             # draft-window target; per-slot adaptive
                                # windows stay <= this
    spec_adaptive: bool = True  # grow/shrink per-slot windows from recent
                                # acceptance (deterministic)
    spec_hoist: Optional[bool] = None  # materialize dense draft weights once
                                # per draft call (in-graph) instead of
                                # re-dequantizing inside the layer scan.
                                # None: hoist off-TPU (where the dequant
                                # dominates the draft step), serve the
                                # truncated QTensors through the normal
                                # kernel dispatch on TPU (keeps weights int4)

    def __post_init__(self):
        if self.speculative:
            if self.temperature > 0:
                raise ValueError(
                    "speculative decoding serves greedy only: acceptance "
                    "compares argmax tokens, temperature>0 has no bitwise "
                    "oracle (got temperature="
                    f"{self.temperature})")
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
            if self.draft_rank < 0:
                raise ValueError(
                    f"draft_rank must be >= 0, got {self.draft_rank}")
        # One source of truth for cache knobs. An explicit CacheConfig wins
        # (legacy fields mirror it so engine/scheduler/supervisor keep
        # reading cfg.max_slots etc.); otherwise the legacy fields build it.
        if self.cache is None:
            self.cache = CacheConfig(max_slots=self.max_slots,
                                     max_seq=self.max_seq,
                                     donate_cache=self.donate_cache)
        else:
            self.max_slots = self.cache.max_slots
            self.max_seq = self.cache.max_seq
            self.donate_cache = self.cache.donate_cache

    def to_dict(self) -> dict:
        """JSON-safe serialization (every field is a primitive; the
        nested CacheConfig flattens to a dict) — how a ``serve.worker``
        subprocess receives its engine configuration."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        d = dict(d)
        cache = d.pop("cache", None)
        return cls(**d, cache=CacheConfig(**cache)
                   if cache is not None else None)

    def resolve_donate(self) -> bool:
        """Whether the cache-threading executables donate their cache
        argument. ``None`` resolves from the backend ONCE (in
        ``CacheConfig.resolve_donate``) — every executable (chunked decode,
        slot prefill, slot decode) must agree, or the scheduler's
        long-lived cache would be consumed by one step and then handed,
        deleted, to the next. XLA:CPU ignores donation (with a warning)
        but JAX still invalidates the donated buffer, so default it off
        there; an explicit True/False always wins (tests force True on CPU
        to exercise the invalidation discipline)."""
        return self.cache.resolve_donate()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 32
    id: int = 0
    deadline_s: Optional[float] = None  # arrival-relative; expired requests
                                        # retire with status "timeout"


@dataclasses.dataclass
class Result:
    id: int
    tokens: List[int]
    prefill_s: float            # this request's batched-prefill wall time
    decode_s: float             # first-token -> ITS last token (duration)
    queue_s: float = 0.0        # wait before its prefill started
    ttft_s: float = 0.0         # queue_s + prefill_s: submit -> first token


class Engine:
    def __init__(self, model: LM, params, cfg: ServeConfig, obs=None):
        if cfg.cache.kv_cache_bits is not None and \
                cfg.cache.kv_cache_bits != model.cfg.kv_cache_bits:
            # CacheConfig owns the cache-precision knob: rebuild the model
            # view with the requested kv bits (params are unaffected — the
            # KV quantizer is static, not learned).
            model = type(model)(dataclasses.replace(
                model.cfg, kv_cache_bits=cfg.cache.kv_cache_bits))
        self.model = model
        self.params = params
        self.cfg = cfg
        # observability bundle (obs.Obs). None means "adopt the first
        # scheduler's obs" — ContinuousScheduler.start() fills it before
        # the lazy cache backend builds, so cache counters land in the
        # same registry the drain report snapshots.
        self.obs = obs
        self._cache_backend = None
        # trace-time counters: the scheduler's length-bucketing claim
        # ("compile count bounded by the bucket set") is asserted on these.
        self.prefill_slot_traces = 0
        self.decode_traces = 0
        # speculative executables compile per window size k (draft) / k+1
        # (verify); adaptive windows stay in a small power-of-two bucket
        # set, so these counters bound the compile count like the prefill
        # buckets do.
        self.spec_draft_traces = 0
        self.verify_traces = 0
        self._draft_fns: Dict[int, Any] = {}
        # fault-injection hook point (serve.faults.FaultInjector.check):
        # called as hook(site, cache) -> cache inside the public slot
        # primitives, so injected faults fire exactly where real ones
        # would — inside the engine step. None in production.
        self.fault_hook = None

        # The backend scope lives INSIDE the jitted callables so the policy
        # binds at trace time; each Engine owns its wrappers (and therefore
        # its trace cache), so two engines with different backends coexist.
        def prefill(p, toks):
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill(p, toks)

        def decode(p, tok, cache, length):
            self.decode_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.decode_step(p, tok, cache, length)

        def prefill_slot(p, toks, cache, slot, start, last):
            self.prefill_slot_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill_slot(p, toks, cache, slot, start, last)

        def prefill_slots(p, toks, cache, starts, lasts, active):
            self.prefill_slot_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill_slots(p, toks, cache, starts, lasts,
                                           active)

        # Donate the cache through every cache-threading executable: each
        # step's update then reuses the previous step's buffers instead of
        # allocating a second full-size KV cache (the decode-memory floor
        # at long context). One resolution (cfg.resolve_donate) covers the
        # chunked decode AND the scheduler's prefill-chunk/decode pair —
        # the cache is consumed exactly once per call, and callers must
        # rebind to the returned cache (the donated input is deleted).
        donate = cfg.resolve_donate()
        self._donate = donate
        self._decode = jax.jit(decode, donate_argnums=(2,)) if donate \
            else jax.jit(decode)
        self._prefill = jax.jit(prefill)
        self._prefill_slot = jax.jit(prefill_slot, donate_argnums=(2,)) \
            if donate else jax.jit(prefill_slot)
        self._prefill_slots = jax.jit(prefill_slots, donate_argnums=(2,)) \
            if donate else jax.jit(prefill_slots)

        def verify(p, toks, cache, lengths):
            self.verify_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.verify_slots(p, toks, cache, lengths)

        # verify threads (and may donate) the cache like decode; jit
        # re-traces per window width C = k+1, bounded by the k bucket set.
        self._verify = jax.jit(verify, donate_argnums=(2,)) if donate \
            else jax.jit(verify)

        # Paged-kernel decode route (CacheConfig.decode_kernel): interpret
        # resolves once at engine build, like the quant-matmul kernels —
        # explicit cfg.interpret wins, else interpret anywhere but a TPU.
        paged_interp = cfg.interpret if cfg.interpret is not None \
            else jax.default_backend() != "tpu"

        def decode_paged(p, tok, pools, table, lengths):
            self.decode_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.decode_step_paged(p, tok, pools, table,
                                               lengths,
                                               interpret=paged_interp)

        self._decode_paged = jax.jit(decode_paged, donate_argnums=(2,)) \
            if donate else jax.jit(decode_paged)

    # ------------------------------------------------ speculative executables
    def _resolve_spec_hoist(self) -> bool:
        if self.cfg.spec_hoist is not None:
            return self.cfg.spec_hoist
        # Off-TPU the per-step dequant dominates the draft pass, so paying
        # one up-front dense materialization per draft call wins; on TPU
        # the fused kernel serves the truncated int4 view directly and a
        # dense copy of the weights would defeat the quantized memory
        # footprint.
        return jax.default_backend() != "tpu"

    def _draft_weights(self, p):
        """In-graph draft view of the params: every QuantizedLinear becomes
        its rank-``draft_rank`` DENSE (in, out) matrix in the model dtype —
        computed once per draft call and shared by all k steps (the hoisted
        path; without it the dequant re-runs inside every layer-scan step
        and the draft is no cheaper than the target). Plain fp leaves pass
        through, so under unquantized params the draft IS the target."""
        dt = self.model.cfg.dtype

        def leaf(x):
            if isinstance(x, QuantizedLinear):
                w = dequantize_stacked(truncate_rank(x, self.cfg.draft_rank),
                                       dtype=jnp.float32)  # (..., m, n)
                return jnp.swapaxes(w, -1, -2).astype(dt)  # mm wants (in, out)
            return x

        return jax.tree.map(leaf, p,
                            is_leaf=lambda x: isinstance(x, QuantizedLinear))

    def _draft_fn(self, k: int):
        """The compiled draft executable for window size ``k``: k greedy
        decode steps against the draft model. The threaded cache is
        internal to the call and DISCARDED — draft K/V never reach the
        backend's cache, so rejected tokens need no device-side rollback.
        Never donates its cache argument (verify reuses the same buffers
        right after)."""
        fn = self._draft_fns.get(k)
        if fn is not None:
            return fn
        cfg = self.cfg
        model = self.model
        hoist = self._resolve_spec_hoist()

        def draft(p, toks, cache, lengths):
            self.spec_draft_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                if hoist:
                    p = self._draft_weights(p)
                    scope = contextlib.nullcontext()
                else:
                    scope = draft_scope(cfg.draft_rank)
                with scope:
                    cur, lens, outs = toks, lengths, []
                    for _ in range(k):
                        logits, cache = model.decode_step(p, cur, cache,
                                                          lens)
                        cur = jnp.argmax(logits[:, -1, :],
                                         axis=-1).astype(jnp.int32)
                        outs.append(cur)
                        lens = lens + 1
                    return jnp.stack(outs, axis=1)  # (B, k)

        fn = jax.jit(draft)
        self._draft_fns[k] = fn
        return fn

    # ----------------------------------------------- slot-granular serving
    # The scheduler reaches these THROUGH the cache backend (self.
    # cache_backend), which owns the long-lived cache state. The private
    # ``*_impl`` methods are the raw executables: their cache argument is
    # DONATED when resolve_donate() says so — after a call returns, the
    # passed-in cache is dead, always thread the returned one. (Tests may
    # still install per-INSTANCE overrides under the historical names
    # ``prefill_slot_chunk`` / ``decode_slots`` — the backends check
    # ``engine.__dict__`` for those — but the class-level deprecation
    # shims are gone.)
    @property
    def cache_backend(self):
        """The engine's cache surface (serve.kv_cache.CacheBackend):
        "dense" (reference oracle) or "paged" (block-table pool + radix
        prefix sharing), per cfg.cache.backend. Built lazily so engines
        used only through ``generate`` never allocate backend state."""
        if self._cache_backend is None:
            self._cache_backend = make_backend(self)
        return self._cache_backend

    def _new_cache_impl(self):
        """One long-lived decode cache covering all slots."""
        return self.model.init_cache(self.cfg.max_slots, self.cfg.max_seq)

    def _prefill_slot_impl(self, cache, slot: int, tokens, start: int,
                           last: int):
        """Prefill one bucketed chunk of one prompt into ``slot`` at offset
        ``start``. tokens: (C,) int32 (C must be a bucket size — the caller
        pads the final partial chunk); ``last`` is the chunk index of the
        last real token, whose unembedded logits seed the first sampled
        token on a final chunk. Returns (logits (1, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("prefill", cache)
        toks = jnp.asarray(np.asarray(tokens, np.int32))[None]
        return self._prefill_slot(self.params, toks, cache,
                                  jnp.int32(slot), jnp.int32(start),
                                  jnp.int32(last))

    def _prefill_slots_impl(self, cache, tokens, starts, lasts, active):
        """Batched slot prefill: one (B, C) launch writing every active
        lane's chunk at its own start offset (lane b <-> slot b). tokens:
        (B, C) int32; starts/lasts: (B,) int32; active: (B,) bool — rows
        with active=False compute garbage but their cache rows pass
        through bitwise-untouched (the write is masked per lane), so idle
        slots are unaffected. Returns (logits (B, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("prefill", cache)
        return self._prefill_slots(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(starts, np.int32)),
            jnp.asarray(np.asarray(lasts, np.int32)),
            jnp.asarray(np.asarray(active, bool)))

    def _decode_slots_impl(self, cache, tokens, lengths):
        """One global decode step over per-slot lengths. tokens: (B,) int32
        current token per slot; lengths: (B,) int32 per-slot cache lengths
        (= each slot's write position; idle slots pass their length too, so
        their masked garbage write lands exactly where the slot's next real
        write will overwrite it). Returns (logits (B, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("decode", cache)
        return self._decode(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(lengths, np.int32)))

    def _decode_paged_impl(self, pools, tokens, table, lengths):
        """Paged-kernel decode step: writes each slot's K/V straight into
        the (L, P+1, page, KV, hd) pools at its page-table position and
        attends via ``flash_decode_gqa_paged`` — no dense-view gather.
        Returns (logits (B, 1, V), pools). Allclose (not bitwise) to
        ``_decode_slots_impl`` on a gathered view."""
        if self.fault_hook is not None:
            pools = self.fault_hook("decode", pools)
        return self._decode_paged(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), pools,
            jnp.asarray(np.asarray(table, np.int32)),
            jnp.asarray(np.asarray(lengths, np.int32)))

    def _draft_slots_impl(self, cache, tokens, lengths, k: int):
        """Draft ``k`` greedy tokens per slot from the rank-truncated
        model. tokens: (B,) current token per slot; lengths: (B,) cached
        prefix per slot. Returns (B, k) int32 draft tokens. The cache
        argument is read, threaded internally and discarded — the caller's
        cache is NEVER consumed or mutated (no donation), so the same
        buffers go straight into verify. No fault hook here: draft work is
        disposable by construction, a fault that matters fires at the
        verify site."""
        return self._draft_fn(k)(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(lengths, np.int32)))

    def _verify_slots_impl(self, cache, tokens, lengths):
        """Score the whole draft window in one pass. tokens: (B, C) =
        [cur_tok, draft_1..draft_{C-1}]; lengths: (B,) cached prefix per
        slot. Returns (logits (B, C, V), cache) — row j bitwise-identical
        to the j-th sequential decode step, with all C tokens' K/V
        inserted (rejected ones stay past the accepted length as stale
        masked entries; rollback is length bookkeeping in the backend)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("verify", cache)
        return self._verify(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(lengths, np.int32)))

    # -------------------------------------------------------------- serving
    def generate(self, requests: List[Request]) -> List[Result]:
        """Slot-batched generation. Requests are padded/batched to the
        engine's fixed shapes; a chunk prefills together and decodes until
        the whole chunk drains (the scheduler's A/B oracle)."""
        out = []
        t_submit = time.perf_counter()
        for chunk_start in range(0, len(requests), self.cfg.max_slots):
            chunk = requests[chunk_start:chunk_start + self.cfg.max_slots]
            out.extend(self._generate_chunk(chunk, t_submit))
        return out

    def _generate_chunk(self, chunk: List[Request],
                        t_submit: Optional[float] = None) -> List[Result]:
        cfg = self.cfg
        b = cfg.max_slots
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(chunk):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        # queue time: how long this chunk sat behind earlier chunks still
        # draining (0 for the first chunk) — per-request truth, where the
        # old shared prefill_s silently absorbed it.
        queue_s = 0.0 if t_submit is None else t0 - t_submit
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # move prefill cache into the full-size decode cache
        full = self.model.init_cache(b, cfg.max_seq)
        if "k_scale" in full and "k_scale" not in cache:
            # int8 KV cache: prefill returns fp K/V — quantize per
            # (token, head) into codes+scales with the serving stack's own
            # quantizer, like its decode step does (the fp cache
            # previously crashed the tree_map below).
            quant_kv = self.model.stack._quant_kv
            kc, ks = quant_kv(cache["k"])
            vc, vs = quant_kv(cache["v"])
            cache = {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}

        def place(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)

        cache = jax.tree.map(place, full, cache)
        # prefill_s must cover EXECUTION, not JAX's async dispatch — without
        # the block the timestamp lands in microseconds and the first decode
        # step silently absorbs the real prefill wall time.
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in chunk)
        cur = self._sample(logits)
        generated = [[int(cur[i])] for i in range(b)]
        # per-token timestamps (decode-relative): token i of a request that
        # stops early was emitted at step_s[i], not at full-drain time.
        step_s = [0.0]
        length = plen
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, cur, cache, jnp.int32(length))
            length += 1
            cur = self._sample(logits)
            for i in range(b):
                generated[i].append(int(cur[i]))
            step_s.append(time.perf_counter() - t0)

        results = []
        for i, r in enumerate(chunk):
            toks_i = generated[i][: r.max_new_tokens]
            if self.cfg.eos_token in toks_i:
                toks_i = toks_i[: toks_i.index(self.cfg.eos_token) + 1]
            results.append(Result(
                r.id, toks_i, prefill_s,
                decode_s=step_s[len(toks_i) - 1] if toks_i else 0.0,
                queue_s=queue_s, ttft_s=queue_s + prefill_s))
        return results

    def _sample_window(self, logits) -> jax.Array:
        """Greedy tokens for EVERY window position: (B, C, V) -> (B, C).
        Per-row argmax is independent, so row j equals ``_sample`` on the
        j-th sequential decode logits — the acceptance comparison side of
        the bitwise oracle. Speculative serving is greedy-only (enforced
        in ServeConfig), so there is no temperature path here."""
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sample(self, logits) -> jax.Array:
        lg = logits[:, -1, :]
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(int(time.time_ns()) & 0x7FFFFFFF)
        return jax.random.categorical(
            key, lg / self.cfg.temperature).astype(jnp.int32)
