"""Batched serving engine with KV-cache management and FLRQ-quantized
weights as a first-class path.

Two serving modes share the engine's compiled executables:

  * **Slot-chunked** (``generate``): requests are batched into fixed
    slot-chunks that prefill together and decode until the whole chunk
    drains. Simple, and kept as the A/B oracle for the scheduler.
  * **Slot-granular** (``serve.scheduler.ContinuousScheduler``): the
    engine exposes a pluggable cache surface — ``engine.cache_backend``
    (``serve.kv_cache.CacheBackend``: dense oracle or paged block-table
    with radix prefix sharing) wrapping the private slot executables
    (chunked/batched slot prefill via ``dynamic_update_slice``, one
    global decode step over per-slot lengths) — so a continuous-batching
    scheduler can admit/retire requests per slot without ever changing
    the compiled decode executable's shapes. The old raw primitives
    (``new_cache`` / ``prefill_slot_chunk`` / ``decode_slots``) remain as
    one-release deprecation shims.

Quantized serving: pass ``params`` whose matrices are QuantizedLinear
(from ``quant.stacked.quantize_model_stacked``) — the stacked tensors ride
``lax.scan`` through the layer body (one compiled body per executable) and
every quantized matmul routes through the backend-dispatch layer
(``quant.apply``): ``ServeConfig.backend`` picks the pure-jnp reference
("ref"), the fused Pallas kernel ("fused"; the paper's Fig. 3 deployment
y = deq(W_q)·x + U(V·x)), or "auto" (kernel on TPU when supported, ref
elsewhere — bit-identical to ref off-TPU). Fallback decisions are recorded
in ``quant.apply.dispatch_log`` — never silent.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import LM
from ..quant.apply import backend_scope
from .kv_cache import CacheConfig, make_backend


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 8          # decode batch size
    max_seq: int = 1024         # cache capacity per slot
    eos_token: int = 1
    temperature: float = 0.0    # 0 = greedy
    backend: str = "auto"       # quantized-matmul backend: ref|fused|auto
    interpret: Optional[bool] = None  # force Pallas interpret (CPU testing)
    donate_cache: Optional[bool] = None  # None: donate where XLA supports it
    cache: Optional[CacheConfig] = None  # cache knobs; None = dense backend
                                         # built from the legacy fields above
    batched_prefill: bool = True  # one (B, C) launch per scheduler step

    def __post_init__(self):
        # One source of truth for cache knobs. An explicit CacheConfig wins
        # (legacy fields mirror it so engine/scheduler/supervisor keep
        # reading cfg.max_slots etc.); otherwise the legacy fields build it.
        if self.cache is None:
            self.cache = CacheConfig(max_slots=self.max_slots,
                                     max_seq=self.max_seq,
                                     donate_cache=self.donate_cache)
        else:
            self.max_slots = self.cache.max_slots
            self.max_seq = self.cache.max_seq
            self.donate_cache = self.cache.donate_cache

    def resolve_donate(self) -> bool:
        """Whether the cache-threading executables donate their cache
        argument. ``None`` resolves from the backend ONCE (in
        ``CacheConfig.resolve_donate``) — every executable (chunked decode,
        slot prefill, slot decode) must agree, or the scheduler's
        long-lived cache would be consumed by one step and then handed,
        deleted, to the next. XLA:CPU ignores donation (with a warning)
        but JAX still invalidates the donated buffer, so default it off
        there; an explicit True/False always wins (tests force True on CPU
        to exercise the invalidation discipline)."""
        return self.cache.resolve_donate()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 32
    id: int = 0
    deadline_s: Optional[float] = None  # arrival-relative; expired requests
                                        # retire with status "timeout"


@dataclasses.dataclass
class Result:
    id: int
    tokens: List[int]
    prefill_s: float            # this request's batched-prefill wall time
    decode_s: float             # first-token -> ITS last token (duration)
    queue_s: float = 0.0        # wait before its prefill started
    ttft_s: float = 0.0         # queue_s + prefill_s: submit -> first token


class Engine:
    def __init__(self, model: LM, params, cfg: ServeConfig):
        if cfg.cache.kv_cache_bits is not None and \
                cfg.cache.kv_cache_bits != model.cfg.kv_cache_bits:
            # CacheConfig owns the cache-precision knob: rebuild the model
            # view with the requested kv bits (params are unaffected — the
            # KV quantizer is static, not learned).
            model = type(model)(dataclasses.replace(
                model.cfg, kv_cache_bits=cfg.cache.kv_cache_bits))
        self.model = model
        self.params = params
        self.cfg = cfg
        self._cache_backend = None
        # trace-time counters: the scheduler's length-bucketing claim
        # ("compile count bounded by the bucket set") is asserted on these.
        self.prefill_slot_traces = 0
        self.decode_traces = 0
        # fault-injection hook point (serve.faults.FaultInjector.check):
        # called as hook(site, cache) -> cache inside the public slot
        # primitives, so injected faults fire exactly where real ones
        # would — inside the engine step. None in production.
        self.fault_hook = None

        # The backend scope lives INSIDE the jitted callables so the policy
        # binds at trace time; each Engine owns its wrappers (and therefore
        # its trace cache), so two engines with different backends coexist.
        def prefill(p, toks):
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill(p, toks)

        def decode(p, tok, cache, length):
            self.decode_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.decode_step(p, tok, cache, length)

        def prefill_slot(p, toks, cache, slot, start, last):
            self.prefill_slot_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill_slot(p, toks, cache, slot, start, last)

        def prefill_slots(p, toks, cache, starts, lasts, active):
            self.prefill_slot_traces += 1  # runs at trace time only
            with backend_scope(cfg.backend, cfg.interpret):
                return model.prefill_slots(p, toks, cache, starts, lasts,
                                           active)

        # Donate the cache through every cache-threading executable: each
        # step's update then reuses the previous step's buffers instead of
        # allocating a second full-size KV cache (the decode-memory floor
        # at long context). One resolution (cfg.resolve_donate) covers the
        # chunked decode AND the scheduler's prefill-chunk/decode pair —
        # the cache is consumed exactly once per call, and callers must
        # rebind to the returned cache (the donated input is deleted).
        donate = cfg.resolve_donate()
        self._donate = donate
        self._decode = jax.jit(decode, donate_argnums=(2,)) if donate \
            else jax.jit(decode)
        self._prefill = jax.jit(prefill)
        self._prefill_slot = jax.jit(prefill_slot, donate_argnums=(2,)) \
            if donate else jax.jit(prefill_slot)
        self._prefill_slots = jax.jit(prefill_slots, donate_argnums=(2,)) \
            if donate else jax.jit(prefill_slots)

    # ----------------------------------------------- slot-granular serving
    # The scheduler reaches these THROUGH the cache backend (self.
    # cache_backend), which owns the long-lived cache state. The private
    # ``*_impl`` methods are the raw executables: their cache argument is
    # DONATED when resolve_donate() says so — after a call returns, the
    # passed-in cache is dead, always thread the returned one. The old
    # public names (new_cache / prefill_slot_chunk / decode_slots) remain
    # as deprecation shims for one release.
    @property
    def cache_backend(self):
        """The engine's cache surface (serve.kv_cache.CacheBackend):
        "dense" (reference oracle) or "paged" (block-table pool + radix
        prefix sharing), per cfg.cache.backend. Built lazily so engines
        used only through ``generate`` never allocate backend state."""
        if self._cache_backend is None:
            self._cache_backend = make_backend(self)
        return self._cache_backend

    def _new_cache_impl(self):
        """One long-lived decode cache covering all slots."""
        return self.model.init_cache(self.cfg.max_slots, self.cfg.max_seq)

    def _prefill_slot_impl(self, cache, slot: int, tokens, start: int,
                           last: int):
        """Prefill one bucketed chunk of one prompt into ``slot`` at offset
        ``start``. tokens: (C,) int32 (C must be a bucket size — the caller
        pads the final partial chunk); ``last`` is the chunk index of the
        last real token, whose unembedded logits seed the first sampled
        token on a final chunk. Returns (logits (1, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("prefill", cache)
        toks = jnp.asarray(np.asarray(tokens, np.int32))[None]
        return self._prefill_slot(self.params, toks, cache,
                                  jnp.int32(slot), jnp.int32(start),
                                  jnp.int32(last))

    def _prefill_slots_impl(self, cache, tokens, starts, lasts, active):
        """Batched slot prefill: one (B, C) launch writing every active
        lane's chunk at its own start offset (lane b <-> slot b). tokens:
        (B, C) int32; starts/lasts: (B,) int32; active: (B,) bool — rows
        with active=False compute garbage but their cache rows pass
        through bitwise-untouched (the write is masked per lane), so idle
        slots are unaffected. Returns (logits (B, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("prefill", cache)
        return self._prefill_slots(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(starts, np.int32)),
            jnp.asarray(np.asarray(lasts, np.int32)),
            jnp.asarray(np.asarray(active, bool)))

    def _decode_slots_impl(self, cache, tokens, lengths):
        """One global decode step over per-slot lengths. tokens: (B,) int32
        current token per slot; lengths: (B,) int32 per-slot cache lengths
        (= each slot's write position; idle slots pass their length too, so
        their masked garbage write lands exactly where the slot's next real
        write will overwrite it). Returns (logits (B, 1, V), cache)."""
        if self.fault_hook is not None:
            cache = self.fault_hook("decode", cache)
        return self._decode(
            self.params, jnp.asarray(np.asarray(tokens, np.int32)), cache,
            jnp.asarray(np.asarray(lengths, np.int32)))

    # Deprecation shims (one release): the raw slot primitives moved behind
    # the CacheBackend protocol — migrate callers to engine.cache_backend.
    def _deprecated(self, name: str, repl: str):
        warnings.warn(
            f"Engine.{name} is deprecated and will be removed next "
            f"release; use engine.cache_backend.{repl} (serve.kv_cache) "
            f"instead", DeprecationWarning, stacklevel=3)

    def new_cache(self):
        """Deprecated: use ``engine.cache_backend.start()``."""
        self._deprecated("new_cache", "start()")
        return self._new_cache_impl()

    def prefill_slot_chunk(self, cache, slot: int, tokens, start: int,
                           last: int):
        """Deprecated: use ``engine.cache_backend.prefill_chunk``."""
        self._deprecated("prefill_slot_chunk", "prefill_chunk(...)")
        return self._prefill_slot_impl(cache, slot, tokens, start, last)

    def decode_slots(self, cache, tokens, lengths):
        """Deprecated: use ``engine.cache_backend.decode``."""
        self._deprecated("decode_slots", "decode(...)")
        return self._decode_slots_impl(cache, tokens, lengths)

    # -------------------------------------------------------------- serving
    def generate(self, requests: List[Request]) -> List[Result]:
        """Slot-batched generation. Requests are padded/batched to the
        engine's fixed shapes; a chunk prefills together and decodes until
        the whole chunk drains (the scheduler's A/B oracle)."""
        out = []
        t_submit = time.perf_counter()
        for chunk_start in range(0, len(requests), self.cfg.max_slots):
            chunk = requests[chunk_start:chunk_start + self.cfg.max_slots]
            out.extend(self._generate_chunk(chunk, t_submit))
        return out

    def _generate_chunk(self, chunk: List[Request],
                        t_submit: Optional[float] = None) -> List[Result]:
        cfg = self.cfg
        b = cfg.max_slots
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(chunk):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        # queue time: how long this chunk sat behind earlier chunks still
        # draining (0 for the first chunk) — per-request truth, where the
        # old shared prefill_s silently absorbed it.
        queue_s = 0.0 if t_submit is None else t0 - t_submit
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # move prefill cache into the full-size decode cache
        full = self.model.init_cache(b, cfg.max_seq)
        if "k_scale" in full and "k_scale" not in cache:
            # int8 KV cache: prefill returns fp K/V — quantize per
            # (token, head) into codes+scales with the serving stack's own
            # quantizer, like its decode step does (the fp cache
            # previously crashed the tree_map below).
            quant_kv = self.model.stack._quant_kv
            kc, ks = quant_kv(cache["k"])
            vc, vs = quant_kv(cache["v"])
            cache = {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}

        def place(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)

        cache = jax.tree.map(place, full, cache)
        # prefill_s must cover EXECUTION, not JAX's async dispatch — without
        # the block the timestamp lands in microseconds and the first decode
        # step silently absorbs the real prefill wall time.
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in chunk)
        cur = self._sample(logits)
        generated = [[int(cur[i])] for i in range(b)]
        # per-token timestamps (decode-relative): token i of a request that
        # stops early was emitted at step_s[i], not at full-drain time.
        step_s = [0.0]
        length = plen
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, cur, cache, jnp.int32(length))
            length += 1
            cur = self._sample(logits)
            for i in range(b):
                generated[i].append(int(cur[i]))
            step_s.append(time.perf_counter() - t0)

        results = []
        for i, r in enumerate(chunk):
            toks_i = generated[i][: r.max_new_tokens]
            if self.cfg.eos_token in toks_i:
                toks_i = toks_i[: toks_i.index(self.cfg.eos_token) + 1]
            results.append(Result(
                r.id, toks_i, prefill_s,
                decode_s=step_s[len(toks_i) - 1] if toks_i else 0.0,
                queue_s=queue_s, ttft_s=queue_s + prefill_s))
        return results

    def _sample(self, logits) -> jax.Array:
        lg = logits[:, -1, :]
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(int(time.time_ns()) & 0x7FFFFFFF)
        return jax.random.categorical(
            key, lg / self.cfg.temperature).astype(jnp.int32)
