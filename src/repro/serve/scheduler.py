"""Continuous-batching request scheduler over the slot-granular Engine.

The missing piece between "quantization engine" and production serving:
the slot-chunked ``Engine.generate`` prefills a whole chunk together and
decodes until the LAST request drains, so mixed-length workloads idle most
slots most of the time. This scheduler keeps the quantized stacks
saturated instead:

  * **Per-slot admission** — requests queue with arrival times and are
    admitted into an individual slot the moment one frees (FIFO by
    arrival), not when a whole chunk forms.
  * **Chunked prefill** — prompts prefill ``prefill_chunk`` tokens per
    scheduler step, each chunk length-bucketed (powers of two up to
    ``prefill_chunk``) so compile count is bounded by the bucket set, and
    interleaved with the global decode step so a long prompt never stalls
    in-flight decodes for its whole prefill.
  * **Immediate retirement** — EOS / max-token completion frees the slot
    this step; the next queued request is admitted at the next step's
    admission pass.
  * **Fixed decode shapes** — all cache writes go through
    ``dynamic_update_slice`` on the one long-lived (donated) decode cache,
    and per-slot lengths ride a (B,) vector, so the compiled decode
    executable never changes shape over the serve's lifetime.

Scheduling changes WHEN a request's tokens are computed, never WHAT they
are: each slot's cache region is isolated (attention masks to the slot's
own length; batched matmuls are row-independent), so per-request tokens
are bitwise-identical to the chunked engine's under greedy sampling —
tested in tests/test_scheduler.py.

Cache-write invariant (why idle/prefilling slots are safe inside the
global decode step): every slot's length entry is its NEXT write
position, so the decode step's masked garbage write for a non-decoding
slot lands exactly where that slot's next real write (its next prefill
chunk, or an admitted prompt's first chunk at 0) overwrites it — and
attention never reads past a slot's length.

Streaming: ``on_token(request_id, token, done)`` fires per sampled token;
``on_drain()`` fires whenever the system goes idle (queue empty, all
slots free) — long-running serves flush e.g. the quant dispatch report
there. Metrics: per-request TTFT / queue / inter-token latency / tok/s
(``SchedResult``) plus a step-level utilization trace.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from .engine import Engine, Request

# slot states
_FREE, _PREFILL, _DECODE = 0, 1, 2


def bucket_sizes(prefill_chunk: int) -> Tuple[int, ...]:
    """The chunk-length bucket set: powers of two from 8 up to (and always
    including) ``prefill_chunk``. Every prefill call pads its chunk to the
    smallest covering bucket, so the number of prefill executables is
    bounded by ``len(bucket_sizes(prefill_chunk))`` regardless of how many
    distinct prompt lengths the workload brings."""
    if prefill_chunk < 1:
        raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
    sizes = []
    b = 8
    while b < prefill_chunk:
        sizes.append(b)
        b *= 2
    sizes.append(prefill_chunk)
    return tuple(sorted(set(sizes)))


def _bucket(c: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if b >= c:
            return b
    return buckets[-1]


def nearest_percentile(values: Sequence[float], q: float) -> float:
    """Nearest-index percentile over unsorted values (0.0 for an empty
    sequence). One definition shared by the serve CLI and the serving
    benchmark so reported TTFT percentiles cannot silently diverge."""
    if not values:
        return 0.0
    vs = sorted(values)
    return float(vs[min(len(vs) - 1, int(q * len(vs)))])


@dataclasses.dataclass
class SchedResult:
    """Per-request outcome + latency metrics (times relative to run start,
    except the *_s durations)."""
    id: int
    tokens: List[int]
    arrival_s: float            # when the request entered the queue
    queue_s: float              # arrival -> slot admission
    ttft_s: float               # arrival -> first token emitted
    finish_s: float             # arrival -> last token emitted
    token_times: List[float]    # run-relative emission time per token

    @property
    def decode_s(self) -> float:
        """First token -> last token."""
        return self.token_times[-1] - self.token_times[0]

    @property
    def itl_s(self) -> List[float]:
        """Inter-token latencies."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def tok_s(self) -> float:
        """Decode tokens/s (0.0 for single-token results — no decode
        interval exists, and an inf would poison workload aggregates)."""
        dt = self.decode_s
        return (len(self.tokens) - 1) / dt if dt > 0 else 0.0


@dataclasses.dataclass
class StepTrace:
    """One scheduler step of the utilization trace."""
    t_s: float                  # run-relative step start
    queued: int
    prefilling: int
    decoding: int
    free: int


@dataclasses.dataclass
class _Slot:
    state: int = _FREE
    req: Optional[Request] = None
    arrival: float = 0.0
    admit_t: float = 0.0
    pos: int = 0                # prompt tokens prefilled so far
    length: int = 0             # cache length == next write position
    cur_tok: int = 0            # last sampled token (decode input)
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    ttft_t: float = 0.0


class ContinuousScheduler:
    """Drives a slot-granular ``Engine``. Each ``run`` creates one
    long-lived decode cache, drains a workload through it and returns
    per-request results in completion order (key by ``.id``); the
    ``trace``/``admission_order`` diagnostics are reset per run."""

    def __init__(self, engine: Engine, prefill_chunk: int = 32,
                 on_token: Optional[Callable[[int, int, bool], None]] = None,
                 on_drain: Optional[Callable[[], None]] = None):
        self.engine = engine
        self.prefill_chunk = int(prefill_chunk)
        self.buckets = bucket_sizes(self.prefill_chunk)
        self.on_token = on_token
        self.on_drain = on_drain
        self.trace: List[StepTrace] = []
        self.admission_order: List[int] = []   # request ids, admission order

    # ------------------------------------------------------------ validate
    def validate(self, req: Request) -> None:
        """Reject a request the cache cannot hold — CLEANLY, before any
        slot state exists for it (the chunked engine would silently write
        past the cache)."""
        plen = len(req.prompt)
        need = plen + req.max_new_tokens
        if plen < 1:
            raise ValueError(f"request {req.id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.id}: max_new_tokens="
                f"{req.max_new_tokens} must be >= 1")
        if need > self.engine.cfg.max_seq:
            raise ValueError(
                f"request {req.id}: prompt_len={plen} + "
                f"max_new_tokens={req.max_new_tokens} = {need} exceeds "
                f"max_seq={self.engine.cfg.max_seq} — rejected")

    # ----------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[float]] = None) -> List[SchedResult]:
        """Serve ``requests``; ``arrivals[i]`` (seconds, relative to run
        start) replays an arrival process — a request is admissible only
        once the wall clock passes its arrival (None = all at t=0)."""
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrivals must match requests 1:1")
        for r in requests:
            self.validate(r)
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        queue: Deque[Tuple[float, Request]] = deque(
            (arrivals[i], requests[i]) for i in order)
        self.trace, self.admission_order = [], []

        eng = self.engine
        n_slots = eng.cfg.max_slots
        slots = [_Slot() for _ in range(n_slots)]
        cache = eng.new_cache()   # donated through every step: always rebind
        results: List[SchedResult] = []
        was_busy = False
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0

        def emit(slot: _Slot, tok: int, t: float) -> bool:
            """Record one sampled token; returns True if the slot retires."""
            slot.tokens.append(tok)
            slot.token_times.append(t)
            done = (tok == eng.cfg.eos_token
                    or len(slot.tokens) >= slot.req.max_new_tokens)
            if self.on_token is not None:
                self.on_token(slot.req.id, tok, done)
            return done

        def retire(slot: _Slot) -> None:
            results.append(SchedResult(
                id=slot.req.id, tokens=slot.tokens,
                arrival_s=slot.arrival,
                queue_s=slot.admit_t - slot.arrival,
                ttft_s=slot.ttft_t - slot.arrival,
                finish_s=slot.token_times[-1] - slot.arrival,
                token_times=slot.token_times))
            # free immediately — the next admission pass hands this slot to
            # the next queued request. Cache needs no reset: the newcomer
            # overwrites from position 0 and never reads past its length.
            slot.state, slot.req = _FREE, None
            slot.pos = slot.length = slot.cur_tok = 0
            slot.tokens, slot.token_times = [], []

        while queue or any(s.state != _FREE for s in slots):
            t_step = now()
            # -- admission: free slots take arrived requests, FIFO
            for slot in slots:
                if slot.state != _FREE or not queue:
                    continue
                arr, req = queue[0]
                if arr > t_step:
                    break  # queue is arrival-sorted
                queue.popleft()
                slot.state = _PREFILL
                slot.req = req
                slot.arrival, slot.admit_t = arr, t_step
                slot.pos = slot.length = 0
                self.admission_order.append(req.id)

            active = [s for s in slots if s.state != _FREE]
            if not active:
                if was_busy and self.on_drain is not None:
                    self.on_drain()
                was_busy = False
                if not queue:
                    break
                time.sleep(max(0.0, queue[0][0] - now()))
                continue
            was_busy = True
            self.trace.append(StepTrace(
                t_s=t_step, queued=len(queue),
                prefilling=sum(s.state == _PREFILL for s in slots),
                decoding=sum(s.state == _DECODE for s in slots),
                free=sum(s.state == _FREE for s in slots)))

            # -- chunked prefill: every prefilling slot advances one chunk
            for idx, slot in enumerate(slots):
                if slot.state != _PREFILL:
                    continue
                prompt = np.asarray(slot.req.prompt, np.int32)
                c = min(self.prefill_chunk, len(prompt) - slot.pos)
                cb = _bucket(c, self.buckets)
                start = slot.pos
                if start + cb > eng.cfg.max_seq:
                    # a padded tail would write past the cache (and
                    # dynamic_update_slice would clamp the start, corrupting
                    # earlier entries). K/V are position-local, so the final
                    # chunk can instead cover the LAST cb prompt tokens —
                    # re-prefilling the overlap with bitwise-identical
                    # values. When even that is impossible (the prompt so
                    # far is shorter than the covering bucket), advance by
                    # the largest bucket that divides off unpadded — the
                    # tail continues next step, and after one such chunk
                    # the overlap path is always reachable. Both keep the
                    # executable count bounded by the bucket set; the
                    # exact-size escape below is only reachable when
                    # max_seq is smaller than the smallest bucket.
                    if start + c >= cb:
                        start = slot.pos + c - cb
                    else:
                        fit = [b for b in self.buckets if b <= c]
                        c = cb = fit[-1] if fit else c
                chunk = np.zeros((cb,), np.int32)
                n_real = slot.pos + c - start
                chunk[:n_real] = prompt[start:start + n_real]
                logits, cache = eng.prefill_slot_chunk(
                    cache, idx, chunk, start, n_real - 1)
                slot.pos += c
                slot.length = slot.pos
                if slot.pos == len(prompt):
                    # final chunk: its last REAL position seeds the first
                    # token (the padded tail carries no information)
                    tok = int(eng._sample(logits)[0])
                    slot.state = _DECODE
                    slot.cur_tok = tok
                    slot.ttft_t = now()
                    if emit(slot, tok, slot.ttft_t):
                        retire(slot)

            # -- global decode step over every decoding slot
            if any(s.state == _DECODE for s in slots):
                toks = np.array([s.cur_tok for s in slots], np.int32)
                lens = np.array([s.length for s in slots], np.int32)
                logits, cache = eng.decode_slots(cache, toks, lens)
                sampled = np.asarray(eng._sample(logits))
                t_tok = now()
                for i, slot in enumerate(slots):
                    if slot.state != _DECODE:
                        continue
                    slot.length += 1
                    tok = int(sampled[i])
                    slot.cur_tok = tok
                    if emit(slot, tok, t_tok):
                        retire(slot)

        if was_busy and self.on_drain is not None:
            self.on_drain()
        return results

    # -------------------------------------------------------------- metrics
    def utilization(self) -> float:
        """Mean fraction of slots doing useful work across trace steps."""
        if not self.trace:
            return 0.0
        n = self.engine.cfg.max_slots
        return float(np.mean([(t.prefilling + t.decoding) / n
                              for t in self.trace]))
