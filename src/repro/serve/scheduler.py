"""Continuous-batching request scheduler over the slot-granular Engine.

The missing piece between "quantization engine" and production serving:
the slot-chunked ``Engine.generate`` prefills a whole chunk together and
decodes until the LAST request drains, so mixed-length workloads idle most
slots most of the time. This scheduler keeps the quantized stacks
saturated instead:

  * **Per-slot admission** — requests queue with arrival times and are
    admitted into an individual slot the moment one frees (FIFO by
    arrival), not when a whole chunk forms.
  * **Chunked prefill** — prompts prefill ``prefill_chunk`` tokens per
    scheduler step, each chunk length-bucketed (powers of two up to
    ``prefill_chunk``) so compile count is bounded by the bucket set, and
    interleaved with the global decode step so a long prompt never stalls
    in-flight decodes for its whole prefill.
  * **Immediate retirement** — EOS / max-token completion frees the slot
    this step; the next queued request is admitted at the next step's
    admission pass.
  * **Fixed decode shapes** — all cache writes go through
    ``dynamic_update_slice`` on the one long-lived (donated) decode cache,
    and per-slot lengths ride a (B,) vector, so the compiled decode
    executable never changes shape over the serve's lifetime.

  * **Self-speculative windows** — with ``ServeConfig.speculative`` on,
    the global decode step becomes a draft+verify window: k tokens are
    drafted with the rank-truncated FLRQ model, verified in ONE batched
    target pass, and each slot emits its longest agreeing prefix plus
    the target's correction token (1..k+1 tokens per step, variable per
    slot). A per-slot adaptive window target (``_Slot.spec_k``) doubles
    on full acceptance and halves when under half the window pays off;
    ``spec_stats()`` reports acceptance rate / accepted-per-step /
    wasted-draft fraction.

Scheduling changes WHEN a request's tokens are computed, never WHAT they
are: each slot's cache region is isolated (attention masks to the slot's
own length; batched matmuls are row-independent), so per-request tokens
are bitwise-identical to the chunked engine's under greedy sampling —
and speculative windows verify with the decode-formula attention (the
same function per row as sequential decode, within ~1 ulp of fused
reductions — far below greedy argmax margins), so their emitted tokens
match the plain sequential greedy decode token-for-token — tested in
tests/test_scheduler.py and tests/test_speculative.py.

Cache-write invariant (why idle/prefilling slots are safe inside the
global decode step): every slot's length entry is its NEXT write
position, so the decode step's masked garbage write for a non-decoding
slot lands exactly where that slot's next real write (its next prefill
chunk, or an admitted prompt's first chunk at 0) overwrites it — and
attention never reads past a slot's length.

Fault tolerance (the serving-facing contract; see ``serve.supervisor``
for the multi-replica layer on top):

  * **Step-driven API** — ``start()``/``step()``/``done`` decompose the
    drain loop so a supervisor can interleave N replicas in one
    deterministic thread and catch per-step failures; ``run()`` is the
    single-replica composition of the same pieces. ``submit()`` admits
    requests dynamically; ``pending()``/``inflight()`` expose exactly
    what a failed replica was holding, so a restart re-admits every
    request (resume state = prompt + tokens emitted so far).
  * **Terminal statuses** — every request ends ``ok | timeout |
    rejected | failed``; nothing is ever silently dropped. ``timeout``:
    the per-request ``deadline_s`` expired (checked at admission AND
    mid-flight, with whatever tokens were emitted). ``rejected``: shed
    by the bounded admission queue (``queue_cap``) or queued at
    ``stop()``. ``failed``: abandoned by ``stop(drain=False)`` or by a
    supervisor whose restart budget is exhausted.
  * **Graceful drain** — ``stop(drain=True)`` stops admitting (queued
    requests get ``rejected`` results immediately) but finishes every
    in-flight request; ``drain=False`` also retires in-flight work as
    ``failed`` at the next step.
  * **Injected clock + faults** — all timing (arrivals, deadlines,
    metrics) reads the injectable ``clock``; a ``FaultInjector`` threads
    through the step loop and the Engine's hook points; the optional
    ``nan_guard`` refuses to sample non-finite logits
    (``CacheCorruptionError``) so corrupted cache state surfaces as a
    replica failure instead of garbage tokens.

Streaming: ``on_token(request_id, token, done)`` fires per sampled token;
``on_drain()`` fires whenever the system goes idle (queue empty, all
slots free) — long-running serves flush e.g. the quant dispatch report
there. Metrics: per-request TTFT / queue / inter-token latency / tok/s
(``SchedResult``) plus a step-level utilization trace.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import Obs
from ..obs.stats import nearest_percentile  # noqa: F401 — canonical home
#                       is obs.stats; re-exported here for existing callers
from .engine import Engine, Request
from .faults import CacheCorruptionError, Clock, FaultInjector
from .kv_cache import PageExhaustionError

# slot states
_FREE, _PREFILL, _DECODE = 0, 1, 2

# terminal request statuses — the full glossary; every request that
# enters the serving system ends in exactly one of these.
STATUSES = ("ok", "timeout", "rejected", "failed")


def bucket_sizes(prefill_chunk: int) -> Tuple[int, ...]:
    """The chunk-length bucket set: powers of two from 8 up to (and always
    including) ``prefill_chunk``. Every prefill call pads its chunk to the
    smallest covering bucket, so the number of prefill executables is
    bounded by ``len(bucket_sizes(prefill_chunk))`` regardless of how many
    distinct prompt lengths the workload brings."""
    if prefill_chunk < 1:
        raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 1")
    sizes = []
    b = 8
    while b < prefill_chunk:
        sizes.append(b)
        b *= 2
    sizes.append(prefill_chunk)
    return tuple(sorted(set(sizes)))


def _bucket(c: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if b >= c:
            return b
    return buckets[-1]


@dataclasses.dataclass
class SchedResult:
    """Per-request outcome + latency metrics (times relative to run start,
    except the *_s durations). ``status``: ok | timeout | rejected |
    failed — ``tokens`` holds whatever was emitted before a non-ok end
    (empty for rejected / timeout-at-admission)."""
    id: int
    tokens: List[int]
    arrival_s: float            # when the request entered the queue
    queue_s: float              # arrival -> slot admission
    ttft_s: float               # arrival -> first token emitted
    finish_s: float             # arrival -> last token emitted (or the
                                # retirement time for token-less ends)
    token_times: List[float]    # run-relative emission time per token
    status: str = "ok"

    @property
    def decode_s(self) -> float:
        """First token -> last token (0.0 when fewer than one token)."""
        if not self.token_times:
            return 0.0
        return self.token_times[-1] - self.token_times[0]

    @property
    def itl_s(self) -> List[float]:
        """Inter-token latencies."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def tok_s(self) -> float:
        """Decode tokens/s (0.0 for single-token results — no decode
        interval exists, and an inf would poison workload aggregates)."""
        dt = self.decode_s
        return (len(self.tokens) - 1) / dt if dt > 0 else 0.0


@dataclasses.dataclass
class StepTrace:
    """One scheduler step of the utilization trace."""
    t_s: float                  # run-relative step start
    queued: int
    prefilling: int
    decoding: int
    free: int
    spec_k: int = 0             # speculative window size this step
                                # (0 = plain one-token decode)


@dataclasses.dataclass
class _Slot:
    state: int = _FREE
    req: Optional[Request] = None
    arrival: float = 0.0
    admit_t: float = 0.0
    pos: int = 0                # prompt tokens prefilled so far
    length: int = 0             # cache length == next write position
    cur_tok: int = 0            # last sampled token (decode input)
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    ttft_t: float = 0.0
    spec_k: int = 0             # adaptive per-slot draft-window target


class ContinuousScheduler:
    """Drives a slot-granular ``Engine``. ``run`` is the one-replica
    drain loop: ``start`` + ``step`` until ``done`` — a supervisor calls
    those pieces directly to interleave replicas and catch per-step
    failures. Results collect in completion order (key by ``.id``); the
    ``trace``/``admission_order`` diagnostics are reset per ``start``."""

    def __init__(self, engine: Engine, prefill_chunk: int = 32,
                 on_token: Optional[Callable[[int, int, bool], None]] = None,
                 on_drain: Optional[Callable[[], None]] = None,
                 queue_cap: Optional[int] = None,
                 clock: Optional[Clock] = None,
                 faults: Optional[FaultInjector] = None,
                 nan_guard: bool = False,
                 obs: Optional[Obs] = None,
                 obs_labels: Optional[dict] = None):
        self.engine = engine
        self.prefill_chunk = int(prefill_chunk)
        self.buckets = bucket_sizes(self.prefill_chunk)
        self.on_token = on_token
        self.on_drain = on_drain
        self.queue_cap = queue_cap
        self.clock = clock or Clock()
        self.faults = faults
        self.nan_guard = nan_guard
        # observability: obs=None means "own default Obs" (metrics on,
        # tracing off), never a silent no-op — spec_stats()/token counters
        # must keep working out of the box. A supervisor passes its shared
        # bundle plus replica labels so fleet counters never collide.
        self.obs = obs if obs is not None else Obs()
        self.trace_tid = 0   # timeline lane for this scheduler's spans
        labels = dict(obs_labels or {})
        self._obs_labels = labels
        reg = self.obs.registry
        self._c_tokens = reg.counter("serve.decode.tokens", **labels)
        self._c_status = {s: reg.counter("serve.requests", status=s,
                                         **labels) for s in STATUSES}
        self._c_spec = {k: reg.counter(f"serve.spec.{k}", **labels)
                        for k in ("windows", "slot_steps", "draft_tokens",
                                  "accepted_tokens", "emitted_tokens")}
        self._h_ttft = reg.histogram("serve.ttft_s", **labels)
        self._h_queue = reg.histogram("serve.queue_s", **labels)
        self.trace: List[StepTrace] = []
        self.admission_order: List[int] = []   # request ids, admission order
        self.results: List[SchedResult] = []
        self._queue: Deque[Tuple[float, Request]] = deque()
        self._slots: List[_Slot] = []
        self._backend = None
        self._t0 = 0.0
        self._was_busy = False
        self._stop_admissions = False
        self._kill_inflight = False

    # ------------------------------------------------- registry-backed views
    # The speculative counters used to be plain ints; they are now registry
    # counters (one storage location for spec_stats(), drain reports and
    # --metrics-json snapshots) with the old attribute names kept as views.
    @property
    def spec_windows(self) -> int:
        return self._c_spec["windows"].value

    @property
    def spec_slot_steps(self) -> int:
        return self._c_spec["slot_steps"].value

    @property
    def spec_draft_tokens(self) -> int:
        return self._c_spec["draft_tokens"].value

    @property
    def spec_accepted_tokens(self) -> int:
        return self._c_spec["accepted_tokens"].value

    @property
    def spec_emitted_tokens(self) -> int:
        return self._c_spec["emitted_tokens"].value

    # ------------------------------------------------------------ validate
    def validate(self, req: Request) -> None:
        """Reject a request the cache cannot hold — CLEANLY, before any
        slot state exists for it (the chunked engine would silently write
        past the cache)."""
        plen = len(req.prompt)
        need = plen + req.max_new_tokens
        if plen < 1:
            raise ValueError(f"request {req.id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.id}: max_new_tokens="
                f"{req.max_new_tokens} must be >= 1")
        if need > self.engine.cfg.max_seq:
            raise ValueError(
                f"request {req.id}: prompt_len={plen} + "
                f"max_new_tokens={req.max_new_tokens} = {need} exceeds "
                f"max_seq={self.engine.cfg.max_seq} — rejected")

    # ------------------------------------------------------------ lifecycle
    def start(self, requests: Sequence[Request] = (),
              arrivals: Optional[Sequence[float]] = None) -> None:
        """Initialize a serve: fresh cache state (``CacheBackend.start``
        — the paged backend rebuilds its page pool, tables and prefix trie
        here, which is also how a supervisor restart re-pins shared
        prefixes), empty slots, the given workload queued. Validation
        happens before ANY state is touched, so a rejected workload leaves
        no partial serve."""
        requests = list(requests)
        if arrivals is None:
            arrivals = [0.0] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrivals must match requests 1:1")
        for r in requests:
            self.validate(r)
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        self._queue = deque((arrivals[i], requests[i]) for i in order)
        self.trace, self.admission_order, self.results = [], [], []
        # per-serve accounting restarts with the serve (registry counters
        # are the storage — spec_stats()/properties are views over them)
        for c in self._c_spec.values():
            c.reset()
        self._c_tokens.reset()
        for c in self._c_status.values():
            c.reset()
        self._slots = [_Slot() for _ in range(self.engine.cfg.max_slots)]
        # the backend owns the (donated) cache state end to end; an engine
        # without its own obs bundle inherits the scheduler's BEFORE the
        # backend is (lazily) built, so cache counters land in one registry
        if self.engine.obs is None:
            self.engine.obs = self.obs
            self.engine.obs_labels = dict(self._obs_labels)
        self._backend = self.engine.cache_backend
        self._backend.start()
        self._t0 = self.clock.now()
        self._was_busy = False
        self._stop_admissions = False
        self._kill_inflight = False
        # thread the injector through the Engine's own hook points so
        # prefill/decode-site faults fire inside the engine call; an engine
        # reused by a fault-free scheduler must shed any stale hook
        self.engine.fault_hook = self.faults.check \
            if self.faults is not None else None

    def _now(self) -> float:
        return self.clock.now() - self._t0

    @property
    def done(self) -> bool:
        return not self._queue and all(s.state == _FREE for s in self._slots)

    @property
    def free_slots(self) -> int:
        return sum(s.state == _FREE for s in self._slots)

    def has_arrived_work(self) -> bool:
        """Work that can progress NOW (vs queued future arrivals)."""
        if any(s.state != _FREE for s in self._slots):
            return True
        return bool(self._queue) and self._queue[0][0] <= self._now()

    def submit(self, req: Request, arrival: Optional[float] = None) -> bool:
        """Dynamically enqueue one request (arrival defaults to now,
        run-relative). Backpressure: with ``queue_cap`` set, a submit
        that would overflow the queue is LOAD-SHED — the request gets an
        immediate ``rejected`` result (never a silent drop) and submit
        returns False. Invalid requests still raise (caller bug, not
        load)."""
        self.validate(req)
        arr = self._now() if arrival is None else float(arrival)
        if self._stop_admissions or (
                self.queue_cap is not None
                and len(self._queue) >= self.queue_cap):
            self.results.append(self._terminal(req, arr, "rejected"))
            return False
        if self._queue and arr < self._queue[-1][0]:
            # keep the queue arrival-sorted for out-of-order submits
            items = sorted([*self._queue, (arr, req)], key=lambda t: t[0])
            self._queue = deque(items)
        else:
            self._queue.append((arr, req))
        return True

    def stop(self, drain: bool = True) -> None:
        """Stop admitting. Queued (never-admitted) requests are retired
        ``rejected`` immediately; with ``drain=True`` in-flight requests
        finish normally, with ``drain=False`` they retire ``failed`` at
        the next step (partial tokens kept)."""
        self._stop_admissions = True
        now = self._now()
        while self._queue:
            arr, req = self._queue.popleft()
            self.results.append(self._terminal(req, arr, "rejected", now))
        if not drain:
            self._kill_inflight = True

    def pending(self) -> List[Tuple[float, Request]]:
        """Queued-but-unadmitted (arrival, request) pairs — what a
        supervisor re-admits elsewhere after a replica failure."""
        return list(self._queue)

    def inflight(self) -> List[Tuple[float, Request, List[int], int]]:
        """Admitted-but-unfinished (arrival, request, tokens_emitted,
        prompt_pos) tuples — the resume state after a replica failure:
        re-prefilling ``prompt + tokens_emitted`` continues the greedy
        decode bitwise-identically. ``prompt_pos`` (prompt tokens already
        prefilled) is the supervisor's wasted-work accounting: positions
        computed here that a resume must recompute."""
        return [(s.arrival, s.req, list(s.tokens), s.pos)
                for s in self._slots if s.state != _FREE]

    def progress(self) -> Dict[int, int]:
        """Prompt positions prefilled per admitted request — the compact
        form of ``inflight`` a process worker ships in every step reply
        so the supervisor can account wasted work for a replica it can
        no longer query (SIGKILL leaves nothing to ask)."""
        return {s.req.id: s.pos for s in self._slots if s.state != _FREE}

    def _terminal(self, req: Request, arrival: float, status: str,
                  now: Optional[float] = None) -> SchedResult:
        """A token-less terminal result (rejected / timeout-at-admission)."""
        now = self._now() if now is None else now
        self._c_status[status].inc()
        self.obs.tracer.instant("retire", tid=self.trace_tid,
                                request_id=req.id, status=status)
        return SchedResult(
            id=req.id, tokens=[], arrival_s=arrival,
            queue_s=max(0.0, now - arrival), ttft_s=0.0,
            finish_s=max(0.0, now - arrival), token_times=[], status=status)

    def status_counts(self) -> Counter:
        return Counter(r.status for r in self.results)

    # ----------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[float]] = None) -> List[SchedResult]:
        """Serve ``requests``; ``arrivals[i]`` (seconds, relative to run
        start) replays an arrival process — a request is admissible only
        once the clock passes its arrival (None = all at t=0)."""
        self.start(requests, arrivals)
        while not self.done:
            if not self.step() and self._queue:
                # idle with future arrivals: wait out the gap
                self.clock.sleep(max(0.0, self._queue[0][0] - self._now()))
        self._set_idle()
        return self.results

    def _set_idle(self) -> None:
        if self._was_busy and self.on_drain is not None:
            self.on_drain()
        self._was_busy = False

    def _retire(self, slot: _Slot, status: str = "ok") -> None:
        has_toks = bool(slot.tokens)
        self._c_status[status].inc()
        self._h_queue.observe(max(0.0, slot.admit_t - slot.arrival))
        if has_toks:
            self._h_ttft.observe(max(0.0, slot.ttft_t - slot.arrival))
        self.obs.tracer.instant("retire", tid=self.trace_tid,
                                request_id=slot.req.id, status=status,
                                tokens=len(slot.tokens))
        self.results.append(SchedResult(
            id=slot.req.id, tokens=slot.tokens,
            arrival_s=slot.arrival,
            queue_s=slot.admit_t - slot.arrival,
            ttft_s=(slot.ttft_t - slot.arrival) if has_toks else 0.0,
            finish_s=(slot.token_times[-1] if has_toks else self._now())
            - slot.arrival,
            token_times=slot.token_times, status=status))
        # free immediately — the next admission pass hands this slot to
        # the next queued request. The dense cache needs no reset (the
        # newcomer overwrites from position 0 and never reads past its
        # length); the paged backend recycles the slot's pages into the
        # free list right here.
        if self._backend is not None:
            idx = next(i for i, s in enumerate(self._slots) if s is slot)
            self._backend.free(idx)
        slot.state, slot.req = _FREE, None
        slot.pos = slot.length = slot.cur_tok = 0
        slot.tokens, slot.token_times = [], []

    def _emit(self, slot: _Slot, tok: int, t: float) -> bool:
        """Record one sampled token; returns True if the slot retires."""
        slot.tokens.append(tok)
        slot.token_times.append(t)
        self._c_tokens.inc()
        done = (tok == self.engine.cfg.eos_token
                or len(slot.tokens) >= slot.req.max_new_tokens)
        if self.on_token is not None:
            self.on_token(slot.req.id, tok, done)
        return done

    def _expired(self, req: Request, arrival: float, now: float) -> bool:
        dl = getattr(req, "deadline_s", None)
        return dl is not None and now > arrival + dl

    def _guard(self, logits, slot_mask=None) -> None:
        """NaN guard: corrupted cache state must surface as a replica
        failure BEFORE any garbage token is sampled/streamed. ``logits``
        is (B, C, V) — C=1 for plain decode/prefill, C=k+1 for a
        speculative verify window (every window position is checked: any
        of them may be sampled into an emitted token); ``slot_mask[i]``
        selects which rows carry real requests (idle slots legitimately
        compute on garbage regions)."""
        if not self.nan_guard:
            return
        lg = np.asarray(logits)
        finite = np.isfinite(lg).all(axis=(-2, -1))
        for i, ok in enumerate(finite):
            if not ok and (slot_mask is None or slot_mask[i]):
                raise CacheCorruptionError(
                    f"non-finite logits for slot {i} — refusing to sample "
                    "from corrupted cache state")

    def step(self) -> bool:
        """One scheduler iteration: faults/deadlines/admission, one
        prefill chunk per prefilling slot, ONE global decode step.
        Returns False when there is nothing to do right now (idle)."""
        eng = self.engine
        slots = self._slots
        t_step = self._now()
        if self.faults is not None:
            self.faults.begin_step()
            self._backend.device_state = self.faults.check(
                "step", self._backend.device_state)
        # -- stop(drain=False): abandon in-flight work, visibly
        if self._kill_inflight:
            self._kill_inflight = False
            for slot in slots:
                if slot.state != _FREE:
                    self._retire(slot, "failed")
        # -- deadline sweep: expired in-flight requests retire as timeout
        #    (mid-prefill or mid-decode, keeping tokens emitted so far);
        #    expired QUEUED requests time out without waiting for a slot —
        #    a full queue must not defer a deadline
        for slot in slots:
            if slot.state != _FREE and \
                    self._expired(slot.req, slot.arrival, t_step):
                self._retire(slot, "timeout")
        if self._queue:
            kept: Deque[Tuple[float, Request]] = deque()
            for arr, req in self._queue:
                if self._expired(req, arr, t_step):
                    self.results.append(
                        self._terminal(req, arr, "timeout", t_step))
                else:
                    kept.append((arr, req))
            self._queue = kept
        # -- admission: free slots take arrived requests, FIFO. The
        #    backend reserves capacity per request (paged: pages + prefix
        #    match): a request that can NEVER fit the pool retires
        #    ``rejected`` (typed, never a crash); one that merely can't
        #    fit RIGHT NOW stays queued for a later step's freed pages.
        for i, slot in enumerate(slots):
            if slot.state != _FREE or not queue_head_arrived(
                    self._queue, t_step):
                continue
            arr, req = self._queue[0]
            try:
                matched = self._backend.alloc(
                    i, np.asarray(req.prompt, np.int32), req.max_new_tokens)
            except PageExhaustionError as e:
                if e.permanent:
                    self._queue.popleft()
                    self.results.append(
                        self._terminal(req, arr, "rejected", t_step))
                    continue
                break  # transient: pages busy — retry next step
            self._queue.popleft()
            slot.state = _PREFILL
            slot.req = req
            slot.arrival, slot.admit_t = arr, t_step
            # a prefix-cache hit resumes prefill past the shared tokens
            slot.pos = slot.length = matched
            # adaptive draft-window target resets per request
            slot.spec_k = eng.cfg.spec_k if eng.cfg.speculative else 0
            self.admission_order.append(req.id)
            self.obs.tracer.instant("admit", tid=self.trace_tid,
                                    request_id=req.id, slot=i,
                                    prefix_hit=int(matched))

        active = [s for s in slots if s.state != _FREE]
        if not active:
            self._set_idle()
            return False
        self._was_busy = True
        self.trace.append(StepTrace(
            t_s=t_step, queued=len(self._queue),
            prefilling=sum(s.state == _PREFILL for s in slots),
            decoding=sum(s.state == _DECODE for s in slots),
            free=sum(s.state == _FREE for s in slots)))

        # -- chunked prefill: every prefilling slot advances one chunk.
        #    Plan each slot's chunk first (chunk length, covering bucket,
        #    start offset — including the near-max_seq overlap rewind),
        #    then launch: ONE batched (B, C) call covering every
        #    prefilling lane at its own start (PR 5 follow-up (b)), or
        #    the per-slot loop when batching is off, a test has wrapped
        #    the legacy per-slot primitive, or any lane needs the
        #    exact-size escape below.
        plan = {}
        fallback = not eng.cfg.batched_prefill or \
            "prefill_slot_chunk" in eng.__dict__
        common = 0  # the batched launch pads every lane to one bucket
        for idx, slot in enumerate(slots):
            if slot.state != _PREFILL:
                continue
            c = min(self.prefill_chunk, len(slot.req.prompt) - slot.pos)
            common = max(common, _bucket(c, self.buckets))
            plan[idx] = c

        def chunk_start(slot, c, cb):
            """Where a ``cb``-padded chunk advancing ``c`` tokens must
            start. Normally slot.pos; near max_seq a padded tail would
            write past the cache (and dynamic_update_slice would clamp
            the start, corrupting earlier entries) — K/V are
            position-local, so the chunk instead covers the LAST cb
            prompt tokens, re-prefilling the overlap with
            bitwise-identical values. When even that is impossible (the
            prompt so far is shorter than the covering bucket), returns
            None: the caller advances by the largest bucket that divides
            off unpadded — the tail continues next step, and after one
            such chunk the overlap path is always reachable. Both keep
            the executable count bounded by the bucket set; the
            exact-size escape is only reachable when max_seq is smaller
            than the smallest bucket."""
            start = slot.pos
            if start + cb > eng.cfg.max_seq:
                if start + c >= cb:
                    return slot.pos + c - cb
                return None
            return start

        starts = {}
        for idx, c in plan.items():
            st = chunk_start(slots[idx], c, common)
            if st is None:
                fallback = True
                break
            starts[idx] = st

        if plan and not fallback:
            b = eng.cfg.max_slots
            toks = np.zeros((b, common), np.int32)
            st_v = np.zeros((b,), np.int32)
            last_v = np.zeros((b,), np.int32)
            act_v = np.zeros((b,), bool)
            for idx, c in plan.items():
                slot = slots[idx]
                prompt = np.asarray(slot.req.prompt, np.int32)
                start = starts[idx]
                n_real = slot.pos + c - start
                toks[idx, :n_real] = prompt[start:start + n_real]
                st_v[idx], last_v[idx], act_v[idx] = start, n_real - 1, True
            for idx, slot in enumerate(slots):
                if idx not in plan:  # idle lanes ride along, writes masked
                    st_v[idx] = max(0, min(slot.length,
                                           eng.cfg.max_seq - common))
            with self.obs.tracer.span("prefill_chunks", tid=self.trace_tid,
                                      slots=len(plan),
                                      tokens=sum(plan.values())):
                logits = self._backend.prefill_chunks(toks, st_v, last_v,
                                                      act_v)
            sampled = None
            for idx, c in plan.items():
                slot = slots[idx]
                slot.pos += c
                slot.length = slot.pos
                if slot.pos == len(slot.req.prompt):
                    # final chunk: its last REAL position seeds the
                    # first token (per-lane logits row — argmax per row
                    # is bitwise the single-slot sample)
                    self._guard(logits, [i == idx for i in range(b)])
                    if sampled is None:
                        sampled = np.asarray(eng._sample(logits))
                    tok = int(sampled[idx])
                    self._backend.register_prompt(
                        idx, np.asarray(slot.req.prompt, np.int32))
                    slot.state = _DECODE
                    slot.cur_tok = tok
                    slot.ttft_t = self._now()
                    if self._emit(slot, tok, slot.ttft_t):
                        self._retire(slot)
        elif plan:
            for idx in sorted(plan):
                slot = slots[idx]
                prompt = np.asarray(slot.req.prompt, np.int32)
                c = min(self.prefill_chunk, len(prompt) - slot.pos)
                cb = _bucket(c, self.buckets)
                start = chunk_start(slot, c, cb)
                if start is None:
                    fit = [bk for bk in self.buckets if bk <= c]
                    c = cb = fit[-1] if fit else c
                    start = slot.pos
                chunk = np.zeros((cb,), np.int32)
                n_real = slot.pos + c - start
                chunk[:n_real] = prompt[start:start + n_real]
                with self.obs.tracer.span("prefill_chunk",
                                          tid=self.trace_tid,
                                          request_id=slot.req.id,
                                          slot=idx, tokens=c):
                    logits = self._backend.prefill_chunk(
                        idx, chunk, start, n_real - 1)
                slot.pos += c
                slot.length = slot.pos
                if slot.pos == len(prompt):
                    # final chunk: its last REAL position seeds the first
                    # token (the padded tail carries no information)
                    self._guard(logits)
                    tok = int(eng._sample(logits)[0])
                    self._backend.register_prompt(idx, prompt)
                    slot.state = _DECODE
                    slot.cur_tok = tok
                    slot.ttft_t = self._now()
                    if self._emit(slot, tok, slot.ttft_t):
                        self._retire(slot)

        # -- global decode step over every decoding slot: one plain
        #    token step, or (speculative mode) one draft+verify window
        #    emitting a variable 1..k+1 tokens per slot
        if any(s.state == _DECODE for s in slots):
            toks = np.array([s.cur_tok for s in slots], np.int32)
            lens = np.array([s.length for s in slots], np.int32)
            k_eff = self._plan_spec_k(slots)
            self.trace[-1].spec_k = k_eff
            if k_eff >= 1:
                self._spec_step(slots, toks, lens, k_eff)
            else:
                with self.obs.tracer.span(
                        "decode_step", tid=self.trace_tid,
                        slots=sum(s.state == _DECODE for s in slots)):
                    logits = self._backend.decode(toks, lens)
                self._guard(logits, [s.state == _DECODE for s in slots])
                sampled = np.asarray(eng._sample(logits))
                t_tok = self._now()
                for i, slot in enumerate(slots):
                    if slot.state != _DECODE:
                        continue
                    slot.length += 1
                    tok = int(sampled[i])
                    slot.cur_tok = tok
                    if self._emit(slot, tok, t_tok):
                        self._retire(slot)
        return True

    # ----------------------------------------------------------- speculative
    def _plan_spec_k(self, slots: List[_Slot]) -> int:
        """Window size for this step's decode: 0 = plain decode. The
        global window is the max of the decoding slots' adaptive targets
        (a slot drafting conservatively still verifies the full window —
        extra verify rows are nearly free, the draft loop is the cost),
        clamped so the window's k+1 cache writes at
        ``length..length+k`` stay inside max_seq for EVERY non-free slot
        (riding prefill lanes write garbage there too, and a clamped
        ``dynamic_update_slice`` would corrupt their real prefix
        instead). Near-full slots degrade to plain decode (k=0), which
        only ever writes at ``length`` — safe for any admitted
        request."""
        eng = self.engine
        if not eng.cfg.speculative:
            return 0
        targets = [s.spec_k for s in slots if s.state == _DECODE]
        if not targets:
            return 0
        occupied = max(s.length for s in slots if s.state != _FREE)
        return min(max(targets), eng.cfg.spec_k,
                   eng.cfg.max_seq - 1 - occupied)

    def _spec_step(self, slots: List[_Slot], toks: np.ndarray,
                   lens: np.ndarray, k: int) -> None:
        """One speculative window: draft k tokens per slot with the
        rank-truncated model, verify all of them in ONE batched target
        pass, emit each slot's longest agreeing prefix plus the target's
        first correction token (1..k+1 tokens). Greedy verification makes
        every emitted token identical to the plain sequential decode —
        speculation changes WHEN tokens are computed, never WHAT they
        are. EOS or the per-request token budget truncates a slot's
        emission mid-window (``_emit`` retires the slot; surplus window
        tokens are discarded). Finally ``rollback`` truncates each slot's
        cache length to its accepted prefix — rejected positions stay as
        stale masked entries the next window overwrites."""
        eng = self.engine
        self._c_spec["windows"].inc()
        decoding = [s.state == _DECODE for s in slots]
        with self.obs.tracer.span("spec_window", tid=self.trace_tid, k=k,
                                  slots=sum(decoding)):
            draft, logits = self._backend.spec_window(toks, lens, k)
        self._guard(logits, decoding)
        outs = np.asarray(eng._sample_window(logits))   # (B, k+1)
        t_tok = self._now()
        final = np.asarray(lens, np.int64).copy()
        for i, slot in enumerate(slots):
            if not decoding[i]:
                continue
            self._c_spec["slot_steps"].inc()
            self._c_spec["draft_tokens"].inc(k)
            # longest prefix where draft agrees with the target's greedy
            # choice: draft[j] must equal the target token AFTER the
            # first j window inputs — i.e. outs[:, j] (window input j is
            # the token BEFORE position j's logits)
            a = 0
            while a < k and int(draft[i, a]) == int(outs[i, a]):
                a += 1
            self._c_spec["accepted_tokens"].inc(a)
            target = slot.spec_k
            retired = False
            for j in range(a + 1):
                tok = int(draft[i, j]) if j < a else int(outs[i, a])
                slot.length += 1
                slot.cur_tok = tok
                self._c_spec["emitted_tokens"].inc()
                if self._emit(slot, tok, t_tok):
                    self._retire(slot)   # resets backend length to 0
                    retired = True
                    break
            final[i] = 0 if retired else slot.length
            if not retired and eng.cfg.spec_adaptive:
                # deterministic per-slot window adaptation: double on
                # full acceptance, halve when under half the window paid
                # off — pure arithmetic on the acceptance count, so a
                # replayed workload adapts identically
                if a == k and target < eng.cfg.spec_k:
                    slot.spec_k = min(eng.cfg.spec_k, max(1, target) * 2)
                elif a + 1 < target // 2 + target % 2:
                    slot.spec_k = max(1, target // 2)
        self._backend.rollback(final)

    # -------------------------------------------------------------- metrics
    def utilization(self) -> float:
        """Mean fraction of slots doing useful work across trace steps."""
        if not self.trace:
            return 0.0
        n = self.engine.cfg.max_slots
        return float(np.mean([(t.prefilling + t.decoding) / n
                              for t in self.trace]))

    def spec_stats(self) -> dict:
        """Speculative-decode effectiveness over the serve so far.

        ``acceptance_rate``: fraction of drafted tokens the target
        verified; ``accepted_per_step``: tokens emitted per decoding slot
        per window (plain decode would score exactly 1.0 — this is the
        step-count compression factor); ``wasted_draft_fraction``:
        drafted-but-rejected work, the overhead knob adaptive k
        minimizes. All zero when speculation is off or no window ran."""
        drafted = self.spec_draft_tokens
        steps = self.spec_slot_steps
        return dict(
            spec_windows=self.spec_windows,
            spec_slot_steps=steps,
            draft_tokens=drafted,
            accepted_tokens=self.spec_accepted_tokens,
            emitted_tokens=self.spec_emitted_tokens,
            acceptance_rate=(self.spec_accepted_tokens / drafted
                            if drafted else 0.0),
            accepted_per_step=(self.spec_emitted_tokens / steps
                              if steps else 0.0),
            wasted_draft_fraction=(
                (drafted - self.spec_accepted_tokens) / drafted
                if drafted else 0.0),
        )


def queue_head_arrived(queue: Deque[Tuple[float, Request]],
                       now: float) -> bool:
    return bool(queue) and queue[0][0] <= now
