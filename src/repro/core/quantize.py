"""Group-wise low-bit quantization primitives used by FLRQ and all baselines.

Everything here is pure-functional, jittable JAX. Weight matrices are
quantized along the *input* (last) dimension in groups of ``group_size``
(paper setting: 128), either symmetrically (signed codes, zero-point-free)
or asymmetrically (unsigned codes + zero point). The paper's Eq. 8 uses a
symmetric clamp; at 2 bits symmetric quantization only has 3 useful levels,
so — like AWQ/GPTQ implementations — we default to asymmetric min/max with a
searched clip ratio and expose symmetric as an option.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

DEFAULT_GROUP_SIZE = 128


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantization format."""

    bits: int = 4
    group_size: int = DEFAULT_GROUP_SIZE
    symmetric: bool = False

    @property
    def n_levels(self) -> int:
        return (1 << self.bits) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.symmetric else self.n_levels


def _group(w: jax.Array, group_size: int) -> jax.Array:
    """(m, n) -> (m, n // g, g). Requires n % g == 0 (configs guarantee it;
    odd shapes are padded by callers)."""
    m, n = w.shape
    if n % group_size:
        raise ValueError(f"n={n} not divisible by group_size={group_size}")
    return w.reshape(m, n // group_size, group_size)


def _ungroup(wg: jax.Array) -> jax.Array:
    m, ng, g = wg.shape
    return wg.reshape(m, ng * g)


def group_stats(w: jax.Array, spec: QuantSpec):
    """Per-group range statistics — the only full reduction over W that
    qparams need. Returns ``(amax,)`` (symmetric) or ``(wmin, wmax)``
    (asymmetric), each (m, n//g, 1). Everything downstream of the clip grid
    is a cheap rescale of these, so the clip search computes them ONCE per
    epoch instead of once per grid point."""
    wg = _group(w.astype(jnp.float32), spec.group_size)
    if spec.symmetric:
        return (jnp.max(jnp.abs(wg), axis=-1, keepdims=True),)
    return (jnp.min(wg, axis=-1, keepdims=True),
            jnp.max(wg, axis=-1, keepdims=True))


def qparams_from_stats(
    stats, spec: QuantSpec, clip_ratio: jax.Array | float = 1.0
):
    """(scale, zero_point) from precomputed ``group_stats`` — no pass over
    W. Bitwise-identical to ``compute_qparams`` (same op order: stats are
    scaled by the clip ratio first, exactly as the unfactored code did)."""
    if spec.symmetric:
        amax = stats[0] * clip_ratio
        scale = amax / spec.qmax
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.zeros_like(scale)
    else:
        wmin = stats[0] * clip_ratio
        wmax = stats[1] * clip_ratio
        scale = (wmax - wmin) / spec.n_levels
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.round(-wmin / scale)
    return scale, zp


def compute_qparams(
    w: jax.Array, spec: QuantSpec, clip_ratio: jax.Array | float = 1.0
):
    """Per-group (scale, zero_point). ``clip_ratio`` may be a scalar or a
    per-output-row (m, 1, 1)-broadcastable array (BLC searches it)."""
    return qparams_from_stats(group_stats(w, spec), spec, clip_ratio)


def quantize_codes(
    w: jax.Array, spec: QuantSpec, scale: jax.Array, zp: jax.Array
) -> jax.Array:
    """float weights -> integer codes (int32, grouped layout (m, n//g, g))."""
    wg = _group(w.astype(jnp.float32), spec.group_size)
    q = jnp.round(wg / scale) + zp
    return jnp.clip(q, spec.qmin, spec.qmax).astype(jnp.int32)


def dequantize_codes(
    codes: jax.Array, spec: QuantSpec, scale: jax.Array, zp: jax.Array,
    dtype=jnp.float32,
) -> jax.Array:
    wg = (codes.astype(jnp.float32) - zp) * scale
    return _ungroup(wg).astype(dtype)


def pseudo_quantize(
    w: jax.Array, spec: QuantSpec, clip_ratio: jax.Array | float = 1.0
) -> jax.Array:
    """Quantize-dequantize roundtrip (the `Quant()` of the paper)."""
    scale, zp = compute_qparams(w, spec, clip_ratio)
    codes = quantize_codes(w, spec, scale, zp)
    return dequantize_codes(codes, spec, scale, zp, dtype=w.dtype)


def pseudo_quantize_from_stats(
    w: jax.Array, stats, spec: QuantSpec,
    clip_ratio: jax.Array | float = 1.0,
) -> jax.Array:
    """``pseudo_quantize`` reusing precomputed ``group_stats`` — the clip
    grid's inner body: only the per-element round/clamp/dequant runs per
    grid point, never the range reduction."""
    scale, zp = qparams_from_stats(stats, spec, clip_ratio)
    codes = quantize_codes(w, spec, scale, zp)
    return dequantize_codes(codes, spec, scale, zp, dtype=w.dtype)


# ---------------------------------------------------------------------------
# Clipping search (paper: "setting a portion of the numbers with the largest
# absolute values to zero by clipping can improve quantization accuracy";
# implemented — as in AWQ — as a grid search over group-range shrink ratios
# minimizing output reconstruction error).
# ---------------------------------------------------------------------------

DEFAULT_CLIP_GRID = tuple(1.0 - 0.05 * i for i in range(8))  # 1.0 .. 0.65


def clip_errors_from_stats(w, x, spec: QuantSpec, stats, grid: jax.Array):
    """Error ||W X - Q(W; c) X||² for every clip ratio c in ``grid``,
    reusing precomputed ``group_stats`` — THE one definition of the hoisted
    sweep objective (``_clip_errors`` and BLC's ``_best_clip_quant`` both
    score through it). ``x``: (n, b) column batch, or None for the plain
    Frobenius weight error Σd² (scored directly — no eye(n) batch).
    """

    def err(c):
        wq = pseudo_quantize_from_stats(w, stats, spec, c)
        d = (w - wq).astype(jnp.float32)
        if x is None:
            return jnp.sum(d * d)
        dx = d @ x.astype(jnp.float32)
        return jnp.sum(dx * dx)

    return jax.lax.map(err, grid)


@partial(jax.jit, static_argnames=("spec",))
def _clip_errors(w, x, spec: QuantSpec, grid: jax.Array):
    """Error ||W X - Q(W; c) X||^2 for every clip ratio c in grid.

    x: (n, b) column-batch of calibration activations, or None-sentinel of
    shape (n, 0) meaning plain Frobenius weight error.

    One pass of group range stats for the WHOLE grid (hoisted out of the
    map — clipping only rescales the same per-group min/max), then one
    round-trip + objective GEMM per grid point. The seed computed the full
    reduction once per grid point; ``kernels.ref.clip_errors_ref`` keeps
    that formulation as the parity oracle.
    """
    stats = group_stats(w, spec)
    return clip_errors_from_stats(w, None if x.shape[1] == 0 else x,
                                  spec, stats, grid)


def search_clip_ratio(
    w: jax.Array,
    x: Optional[jax.Array],
    spec: QuantSpec,
    grid=DEFAULT_CLIP_GRID,
) -> jax.Array:
    """Return the scalar clip ratio minimizing reconstruction error."""
    if x is None:
        x = jnp.zeros((w.shape[1], 0), jnp.float32)
    g = jnp.asarray(grid, jnp.float32)
    errs = _clip_errors(w, x, spec, g)
    return g[jnp.argmin(errs)]


# ---------------------------------------------------------------------------
# Activation-aware scaling (paper Eq. 10-11, AWQ-like)
# ---------------------------------------------------------------------------

def awq_scale(x_mean: jax.Array, eps: float = 1e-6) -> jax.Array:
    """alpha = Xbar^2.5 / sqrt(max(Xbar) * min(Xbar)).   (paper Eq. 11)

    x_mean: per-input-channel mean of |activations| (n,), "per-token
    normalized mean" in the paper. Returns per-channel alpha (n,), clipped
    into a sane dynamic range so degenerate calibration cannot blow up the
    weights.
    """
    xb = jnp.abs(x_mean.astype(jnp.float32)) + eps
    denom = jnp.sqrt(jnp.max(xb) * jnp.min(xb))
    alpha = xb ** 2.5 / denom
    # Normalize to geometric mean 1 so overall weight magnitude is preserved,
    # then clamp: alpha multiplies W columns, alpha^-1 folds into W_L / the
    # previous layer.
    alpha = alpha / jnp.exp(jnp.mean(jnp.log(alpha)))
    return jnp.clip(alpha, 1e-2, 1e2)


def channel_mean_abs(x: jax.Array) -> jax.Array:
    """Per-channel mean |x| over a (tokens, n) calibration batch, with
    per-token normalization as in the paper."""
    x = x.astype(jnp.float32)
    tok_norm = jnp.linalg.norm(x, axis=-1, keepdims=True) / jnp.sqrt(x.shape[-1])
    x = x / jnp.maximum(tok_norm, 1e-6)
    return jnp.mean(jnp.abs(x), axis=0)


# ---------------------------------------------------------------------------
# Error metrics
# ---------------------------------------------------------------------------

def recon_error(w: jax.Array, w_hat: jax.Array, x: Optional[jax.Array] = None):
    """Relative L2 output error  ||WX - What X|| / ||WX||  (paper's E)."""
    w = w.astype(jnp.float32)
    w_hat = w_hat.astype(jnp.float32)
    if x is None:
        num = jnp.linalg.norm(w - w_hat)
        den = jnp.linalg.norm(w)
    else:
        x = x.astype(jnp.float32)
        num = jnp.linalg.norm(w @ x - w_hat @ x)
        den = jnp.linalg.norm(w @ x)
    return num / jnp.maximum(den, 1e-12)
